"""Shared benchmark helpers."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from repro.sim import PAPER_DEFAULT, energy_report, run_simulation
from repro.sim.requests import WorkloadConfig


def sim_with(qps=None, n_requests=None, model=None, batch_cap=None,
             pd_ratio=None, min_len=None, max_len=None, tp=None, pp=None,
             device=None, seed=None, base=None):
    """PAPER_DEFAULT with overrides."""
    cfg = base or PAPER_DEFAULT
    wl = cfg.workload
    wl_kw = {}
    if qps is not None:
        wl_kw["qps"] = qps
    if n_requests is not None:
        wl_kw["n_requests"] = n_requests
    if pd_ratio is not None:
        wl_kw["pd_ratio"] = pd_ratio
    if min_len is not None:
        wl_kw["min_len"] = min_len
    if max_len is not None:
        wl_kw["max_len"] = max_len
    if seed is not None:
        wl_kw["seed"] = seed
    if wl_kw:
        wl = dataclasses.replace(wl, **wl_kw)
    kw = {"workload": wl}
    if model is not None:
        kw["model"] = model
    if tp is not None:
        kw["tp"] = tp
    if pp is not None:
        kw["pp"] = pp
    if device is not None:
        kw["device"] = device
    if batch_cap is not None:
        kw["scheduler"] = dataclasses.replace(cfg.scheduler,
                                              batch_cap=batch_cap)
    return dataclasses.replace(cfg, **kw)


def run_and_report(cfg, pue: float = 1.2) -> Dict[str, float]:
    res = run_simulation(cfg)
    rep = energy_report(res, pue=pue)
    return {
        "avg_mfu": res.avg_mfu(),
        "avg_power_w": rep.avg_power_w,
        "energy_wh": rep.energy_wh,
        "duration_s": rep.duration_s,
        "throughput_qps": res.throughput_qps(),
        "gpu_hours": rep.gpu_hours,
        "n_stages": len(res.stages.dur_s),
        "avg_batch": float(np.mean(res.stages.batch_size))
        if len(res.stages.batch_size) else 0.0,
        "_result": res,
    }


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed_us = (time.time() - self.t0) * 1e6
