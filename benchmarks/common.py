"""Shared benchmark helpers: timing + the bridge to the sweep engine.

The actual grid declarations and paper-claim checks live in
``repro.sweep.scenarios``; the per-figure scripts in this package are
thin entry points that keep the historical ``run() -> (rows, derived,
us)`` contract for ``benchmarks.run``.

Environment knobs:
  REPRO_SWEEP_WORKERS   scenario-level process parallelism (default 1)
  REPRO_SWEEP_NO_CACHE  set to disable result memoization
  REPRO_SWEEP_CACHE     cache root (default results/sweep_cache)
"""
from __future__ import annotations

import os
import sys
import time

from repro.sweep import ResultCache, SWEEPS, run_sweep


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed_us = (time.time() - self.t0) * 1e6


def run_paper_sweep(name: str, smoke: bool = False, n_requests=None,
                    workers=None):
    """Execute one named paper sweep; returns (rows, derived, us)."""
    if workers is None:
        workers = int(os.environ.get("REPRO_SWEEP_WORKERS", "1"))
    cache = (None if os.environ.get("REPRO_SWEEP_NO_CACHE")
             else ResultCache())
    with Timer() as t:
        records, _stats, derived = run_sweep(
            name, smoke=smoke, n_requests=n_requests, workers=workers,
            cache=cache)
    return SWEEPS[name].make_rows(records), derived, t.elapsed_us


def bench_main(name: str) -> None:
    """Default __main__ body for the per-figure scripts."""
    from repro.sweep.report import format_rows
    args = sys.argv[1:]
    bad = [a for a in args if a != "--smoke"]
    if bad:
        print(f"unknown argument(s): {' '.join(bad)} "
              f"(only --smoke is supported)", file=sys.stderr)
        sys.exit(2)
    smoke = "--smoke" in args
    rows, derived, _ = run_paper_sweep(name, smoke=smoke)
    if isinstance(rows, dict):
        for k, v in rows.items():
            print(f"{k:28s} {v:10.2f}")
    else:
        print(format_rows(rows))
    print(derived)
