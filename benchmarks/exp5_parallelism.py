"""Exp. 5: TP x PP parallelism configurations (CodeLlama-34B, 4xA100).

Paper claims: average per-GPU power ranges 213.2-355.3 W, peaking at
TP=2/PP=1 and dropping with higher parallelism; energy 0.16-0.56 kWh;
most efficient setups are TP=2/PP=1 and TP=1/PP=2 — runtime reduction
beats power minimization.

Grid declaration: ``repro.sweep.scenarios`` ("exp5").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("exp5", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("exp5")
