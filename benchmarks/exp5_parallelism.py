"""Exp. 5: TP x PP parallelism configurations (CodeLlama-34B, 4xA100).

Paper claims: average per-GPU power ranges 213.2-355.3 W, peaking at
TP=2/PP=1 and dropping with higher parallelism; energy 0.16-0.56 kWh;
most efficient setups are TP=2/PP=1 and TP=1/PP=2 — runtime reduction
beats power minimization.
"""
from __future__ import annotations

from benchmarks.common import Timer, run_and_report, sim_with
from repro.configs.paper_models import CODELLAMA_34B

GRID = [(1, 1), (1, 2), (1, 4), (2, 1), (2, 2), (2, 4), (4, 1), (4, 2),
        (4, 4)]


def run(n_requests: int = 256):
    rows = []
    with Timer() as t:
        for tp, pp in GRID:
            r = run_and_report(sim_with(model=CODELLAMA_34B, tp=tp, pp=pp,
                                        n_requests=n_requests, qps=3.0))
            rows.append({"tp": tp, "pp": pp,
                         "avg_power_w": r["avg_power_w"],
                         "energy_wh": r["energy_wh"],
                         "duration_s": r["duration_s"]})
    best = min(rows, key=lambda r: r["energy_wh"])
    pmax = max(rows, key=lambda r: r["avg_power_w"])
    derived = (f"P_range={min(r['avg_power_w'] for r in rows):.0f}-"
               f"{max(r['avg_power_w'] for r in rows):.0f}W"
               f"(paper:213-355);peak_at=TP{pmax['tp']}PP{pmax['pp']}"
               f"(paper:TP2PP1);best=TP{best['tp']}PP{best['pp']}"
               f"(paper:TP2PP1 or TP1PP2)")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        print(f"TP={r['tp']} PP={r['pp']}: P={r['avg_power_w']:6.1f}W "
              f"E={r['energy_wh']:8.2f}Wh dur={r['duration_s']:7.1f}s")
    print(derived)
