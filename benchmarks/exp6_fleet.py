"""Exp 6 (beyond-paper): multi-region fleet carbon-offset comparison.

Sweeps a two-site fleet over device mix x router policy x CI trace
pair through ``repro.fleet`` (requests geo-routed inside the simulation
loop against each site's live CI signal). The headline derived check:
on the divergent hydro-vs-coal pair, the carbon-greedy geo-router cuts
fleet operational emissions versus round-robin — the request-level
analogue of the paper's Section 5 multi-region policy discussion.

Grid declaration: ``repro/sweep/scenarios.py`` ("fleet").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fleet", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fleet")
