"""Exp 7 (beyond-paper): request-level temporal carbon-aware shifting.

Sweeps admission policy (immediate / threshold_defer / forecast_window)
x CI forecaster (oracle / persistence / diurnal template) x deferral
deadline x CI trace set x solar sizing through ``repro.schedule`` +
``repro.fleet`` — the request-granularity reproduction of the paper's
renewable-offset analysis: how much operational carbon temporal
deferral saves, priced against the latency each workload class pays.
Every scenario pins the same co-sim horizon so idle energy cancels
across the policy axis.

Headline derived check: on the divergent evening-ramp pair with oracle
forecasts, deferral cuts emissions vs immediate admission while the
interactive class's p99 TTFT stays within its SLO.

Grid declaration: ``repro/sweep/scenarios.py`` ("shift").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("shift", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("shift")
