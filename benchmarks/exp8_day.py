"""Day-scale hybrid benchmark: a 24 h, 2M-request fleet day.

Times ``repro.fleet.day.run_fleet_day`` over a full diurnal+bursty
day on a two-site autoscaled fleet in the fluid/request hybrid mode
and writes the wall-clock/throughput baseline to ``BENCH_day.json``
at the repo root. The acceptance bar this file pins: the 2M-request
day completes in under 60 s wall-clock, event-stepping only a few
percent of the requests (transient epochs + fluid pilots).

The hybrid-vs-exact *agreement* bar lives in the ``day`` sweep
(``python -m repro.sweep.cli day --smoke``) and tests/test_day.py;
this benchmark tracks scale and speed. The timed run executes under
the ``repro.obs`` wall-clock profiler, so the bench JSON also carries
a ``phases`` breakdown (workload gen, admission, epoch planning and
evaluation, per-site microgrid co-sim).

Usage: python -m benchmarks.exp8_day [--smoke] [--check MAX_WALL_S]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATHS = {True: _ROOT / "BENCH_day_smoke.json",
               False: _ROOT / "BENCH_day.json"}

DAY_N = 2_000_000
DAY_SPAN_S = 24 * 3600.0


def build_config(n_requests: int = DAY_N, span_s: float = DAY_SPAN_S,
                 mode: str = "hybrid"):
    """The benchmark day: sinusoidal diurnal envelope + MMPP bursts
    over a two-site fleet with carbon-aware deferral and the replica
    autoscaler on both sites."""
    from repro.configs.paper_models import LLAMA3_8B
    from repro.fleet.autoscale import AutoscalerConfig
    from repro.fleet.config import FleetConfig, SiteConfig
    from repro.schedule.config import ScheduleConfig
    from repro.sim.hybrid import DayConfig
    from repro.sim.requests import WorkloadConfig
    from repro.sim.scheduler import SchedulerConfig

    epoch_s = 900.0 if span_s >= 8 * 3600.0 else span_s / 12.0
    wl = WorkloadConfig(
        n_requests=n_requests, qps=n_requests / span_s,
        min_len=192, max_len=192, seed=0,
        envelope="diurnal", envelope_amplitude=0.35,
        # one-epoch bursts a few times a day: each marks its epoch
        # transient (exact) without event-stepping hours of the day
        burst_gain=2.0, burst_mean_s=epoch_s,
        burst_idle_mean_s=span_s / 3.0,
        deferrable_frac=0.05, deferrable_deadline_s=4 * epoch_s,
        interactive_slo_s=30.0)
    # planner capacity estimate: one replica sustains ~4500 tok/s at
    # full batch on this model/device; plan against a conservative
    # 3500 so the diurnal peak needs 2 replicas, the trough 1, and
    # bursts 3 — the plan breathes with the envelope while steady
    # epochs stay under the saturation threshold (only genuine
    # transients — bursts, autoscales, drains — go exact)
    asc = AutoscalerConfig(
        enabled=True, min_replicas=1, max_replicas=4, target_util=0.6,
        scale_up_latency_s=epoch_s / 5.0, warm_spares=1,
        tokens_per_s=3500.0, ci_scale_down_g=0.0)
    sites = tuple(
        SiteConfig(name=f"s{i}-{trace}", ci_trace=trace, autoscaler=asc,
                   scheduler=SchedulerConfig(batch_cap=64))
        for i, trace in enumerate(("caiso-night", "coal-night")))
    return FleetConfig(
        model=LLAMA3_8B, sites=sites, workload=wl, router="round_robin",
        schedule=ScheduleConfig(policy="forecast_window",
                                forecaster="oracle",
                                policy_params={"margin": 0.01}),
        day=DayConfig(mode=mode, epoch_s=epoch_s, util_threshold=0.6))


def measure(smoke: bool = False, n_requests=None) -> dict:
    from repro.fleet.day import run_fleet_day
    from repro.obs.spans import PROFILER
    from repro.sweep import SCHEMA_VERSION

    n = n_requests or (20_000 if smoke else DAY_N)
    span = 2 * 3600.0 if smoke else DAY_SPAN_S
    cfg = build_config(n_requests=n, span_s=span)
    # the timed run doubles as the wall-clock phase breakdown (day
    # drivers carry repro.obs spans: workload gen, admission, epoch
    # planning/eval, per-site co-sim)
    PROFILER.enable(reset=True)
    t0 = time.perf_counter()
    try:
        res = run_fleet_day(cfg)
    finally:
        wall_s = time.perf_counter() - t0
        PROFILER.disable()
    phases = {name: {"count": int(a["count"]),
                     "total_s": round(a["total_s"], 3)}
              for name, a in sorted(PROFILER.aggregate().items())}
    m = res.summary()
    return {
        "bench": "exp8_day",
        "smoke": smoke,
        "schema": SCHEMA_VERSION,
        "mode": cfg.day.mode,
        "span_h": span / 3600.0,
        "n_requests": int(m["n_requests"]),
        "n_simulated": int(m["n_simulated"]),
        "sim_fraction": round(m["sim_fraction"], 4),
        "n_epochs": int(m["n_epochs"]),
        "n_exact_epochs": int(m["n_exact_epochs"]),
        "n_fluid_epochs": int(m["n_fluid_epochs"]),
        "wall_s": round(wall_s, 2),
        "requests_per_s": round(m["n_requests"] / wall_s, 1),
        "energy_kwh": round(m["energy_wh"] / 1e3, 3),
        "energy_idle_frac": round(m["energy_idle_wh"] / m["energy_wh"], 4),
        "carbon_operational_kg": round(
            m["carbon_operational_g"] / 1e3, 4),
        "carbon_offset_pct": round(m["carbon_offset_pct"], 2),
        "ttft_p99_s": round(m["ttft_p99_s"], 4),
        "e2e_p99_s": round(m["e2e_p99_s"], 4),
        "n_deferred": int(m["n_deferred"]),
        "scale_ups": int(m["scale_ups"]),
        "scale_downs": int(m["scale_downs"]),
        "replica_peak": int(m["replica_peak"]),
        "phases": phases,
    }


def run(smoke: bool = False):
    """``benchmarks.run`` entry: (rows, derived, us_per_call)."""
    t0 = time.time()
    result = measure(smoke=smoke)
    BENCH_PATHS[smoke].write_text(json.dumps(result, indent=1) + "\n")
    derived = (f"n={result['n_requests']};wall={result['wall_s']}s"
               f"(target<60);req_per_s={result['requests_per_s']};"
               f"sim_fraction={result['sim_fraction']};"
               f"exact_epochs={result['n_exact_epochs']}/"
               f"{result['n_epochs']};"
               f"scale_ups={result['scale_ups']}")
    return [result], derived, (time.time() - t0) * 1e6


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    check = None
    if "--check" in args:
        i = args.index("--check")
        check = float(args[i + 1]) if i + 1 < len(args) else 60.0
    rows, derived, _ = run(smoke=smoke)
    result = rows[0]
    print(json.dumps(result, indent=1))
    print(f"wrote {BENCH_PATHS[smoke]}")
    if check is not None and result["wall_s"] > check:
        print(f"FAIL: wall {result['wall_s']}s > allowed {check}s",
              file=sys.stderr)
        return 1
    if not smoke and result["n_requests"] < DAY_N:
        print(f"FAIL: day covered {result['n_requests']} < {DAY_N} "
              "requests", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
