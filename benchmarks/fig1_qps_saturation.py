"""Fig. 1: Simulated QPS saturation for Meta-Llama-3-8B.

Paper claim: MFU increases with QPS and plateaus near mfu_sat = 0.45 at
5-7.9 QPS on A100.

Grid declaration: ``repro.sweep.scenarios`` ("fig1").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fig1", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fig1")
