"""Fig. 1: Simulated QPS saturation for Meta-Llama-3-8B.

Paper claim: MFU increases with QPS and plateaus near mfu_sat = 0.45 at
5-7.9 QPS on A100.
"""
from __future__ import annotations

from benchmarks.common import Timer, run_and_report, sim_with


def run(n_requests: int = 512):
    qps_grid = [0.5, 1.0, 2.0, 3.0, 5.0, 6.45, 7.9, 10.0, 12.6]
    rows = []
    with Timer() as t:
        for qps in qps_grid:
            r = run_and_report(sim_with(qps=qps, n_requests=n_requests))
            rows.append({"qps": qps, "avg_mfu": r["avg_mfu"],
                         "avg_power_w": r["avg_power_w"]})
    sat = [r["avg_mfu"] for r in rows if 5.0 <= r["qps"] <= 7.9]
    derived = (f"mfu@5-7.9qps={min(sat):.3f}-{max(sat):.3f}"
               f";paper=saturates~0.45")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        print(f"qps={r['qps']:5.2f} mfu={r['avg_mfu']:.3f} "
              f"P={r['avg_power_w']:.0f}W")
    print(derived)
