"""Fig. 2 / Exp. 1: request count vs average power and total energy.

Paper claims: avg power stable at 135-155 W (models <= 34B, TP1/PP1) and
125-127.5 W (70B+, TP2/PP2); energy linear in request count; at 2^16
requests CodeLlama-34B ~16 kWh, 70B+ > 80 kWh.

Energy linearity is verified on 2^8..2^12 and extrapolated to 2^16 (the
full 65k-request sims are minutes each on CPU; the extrapolation slope is
the claim under test anyway).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, run_and_report, sim_with
from repro.configs.paper_models import (CODELLAMA_34B, LLAMA3_8B, LLAMA3_70B,
                                        PHI2_2_7B, QWEN_72B)

MODELS = [
    ("phi2-2.7b", PHI2_2_7B, 1, 1),
    ("llama3-8b", LLAMA3_8B, 1, 1),
    ("codellama-34b", CODELLAMA_34B, 1, 1),
    ("llama3-70b", LLAMA3_70B, 2, 2),
    ("qwen-72b", QWEN_72B, 2, 2),
]


def run(counts=(256, 1024, 4096)):
    rows = []
    with Timer() as t:
        for name, model, tp, pp in MODELS:
            energies, powers = [], []
            for n in counts:
                r = run_and_report(sim_with(model=model, tp=tp, pp=pp,
                                            n_requests=n))
                energies.append(r["energy_wh"])
                powers.append(r["avg_power_w"])
                rows.append({"model": name, "n_requests": n, **{
                    k: v for k, v in r.items() if not k.startswith("_")}})
            # linear fit through origin -> extrapolate to 2^16
            slope = float(np.polyfit(counts, energies, 1)[0])
            e_64k = slope * 65536
            rows.append({"model": name, "n_requests": 65536,
                         "energy_wh": e_64k, "extrapolated": True,
                         "avg_power_w": float(np.mean(powers))})
    small = [r for r in rows if r["model"] in
             ("phi2-2.7b", "llama3-8b", "codellama-34b")
             and not r.get("extrapolated")]
    big = [r for r in rows if r["model"] in ("llama3-70b", "qwen-72b")
           and not r.get("extrapolated")]
    extr = {r["model"]: r["energy_wh"] for r in rows if r.get("extrapolated")}
    derived = (f"P_small={min(x['avg_power_w'] for x in small):.0f}-"
               f"{max(x['avg_power_w'] for x in small):.0f}W(paper:135-155);"
               f"P_big={min(x['avg_power_w'] for x in big):.0f}-"
               f"{max(x['avg_power_w'] for x in big):.0f}W(paper:125-127);"
               f"E64k_34b={extr['codellama-34b']/1e3:.1f}kWh(paper~16);"
               f"E64k_70b={extr['llama3-70b']/1e3:.1f}kWh(paper>80)")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        e = r.get("energy_wh", 0)
        print(f"{r['model']:16s} n={r['n_requests']:6d} "
              f"P={r.get('avg_power_w', 0):6.1f}W E={e:9.1f}Wh"
              + (" (extrapolated)" if r.get("extrapolated") else ""))
    print(derived)
