"""Fig. 2 / Exp. 1: request count vs average power and total energy.

Paper claims: avg power stable at 135-155 W (models <= 34B, TP1/PP1) and
125-127.5 W (70B+, TP2/PP2); energy linear in request count; at 2^16
requests CodeLlama-34B ~16 kWh, 70B+ > 80 kWh.

Energy linearity is verified on the simulated counts and extrapolated to
2^16 (the full 65k-request sims are minutes each on CPU; the
extrapolation slope is the claim under test anyway).

Grid declaration: ``repro.sweep.scenarios`` ("fig2").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fig2", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fig2")
