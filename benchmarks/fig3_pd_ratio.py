"""Fig. 3 / Exp. 2: prefill-to-decode ratio vs power and energy.

Paper claims: at fixed P:D, power & energy increase with request length;
at fixed length, decode-heavier mixes (lower P:D) raise power and energy
especially for long requests; short requests barely change.

Note on conventions: the paper plots "increasing P:D (more decode-heavy)"
— we parameterize pd_ratio = prefill:decode, so decode-heavy = small
pd_ratio.
"""
from __future__ import annotations

from benchmarks.common import Timer, run_and_report, sim_with

PD_RATIOS = [50.0, 10.0, 2.0, 1.0, 0.5, 0.1, 0.02]
LENGTHS = [128, 512, 1024, 4096]


def run(n_requests: int = 256):
    rows = []
    with Timer() as t:
        for L in LENGTHS:
            for pd in PD_RATIOS:
                r = run_and_report(sim_with(pd_ratio=pd, min_len=L, max_len=L,
                                            n_requests=n_requests))
                rows.append({"length": L, "pd_ratio": pd,
                             "avg_power_w": r["avg_power_w"],
                             "energy_wh": r["energy_wh"]})
    # checks: energy grows with length at fixed pd; decode-heavy > prefill-
    # heavy energy at long lengths
    e_by_len = {L: [r["energy_wh"] for r in rows if r["length"] == L]
                for L in LENGTHS}
    mono_len = all(sum(e_by_len[LENGTHS[i]]) < sum(e_by_len[LENGTHS[i + 1]])
                   for i in range(len(LENGTHS) - 1))
    long_rows = [r for r in rows if r["length"] == 4096]
    decode_heavier = (long_rows[-1]["energy_wh"] > long_rows[0]["energy_wh"])
    derived = (f"energy_monotonic_in_length={mono_len}(paper:yes);"
               f"decode_heavy_costs_more_at_4k={decode_heavier}(paper:yes)")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        print(f"len={r['length']:5d} P:D={r['pd_ratio']:6.2f} "
              f"P={r['avg_power_w']:6.1f}W E={r['energy_wh']:8.2f}Wh")
    print(derived)
