"""Fig. 3 / Exp. 2: prefill-to-decode ratio vs power and energy.

Paper claims: at fixed P:D, power & energy increase with request length;
at fixed length, decode-heavier mixes (lower P:D) raise power and energy
especially for long requests; short requests barely change.

Note on conventions: the paper plots "increasing P:D (more decode-heavy)"
— we parameterize pd_ratio = prefill:decode, so decode-heavy = small
pd_ratio.

Grid declaration: ``repro.sweep.scenarios`` ("fig3").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fig3", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fig3")
