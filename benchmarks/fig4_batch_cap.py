"""Fig. 4 / Exp. 3: batch-size cap vs power and energy.

Paper claims: actual batch size grows sublinearly with the cap; average
power rises with cap and plateaus above ~64; total energy drops with
larger caps with diminishing returns past ~16.
"""
from __future__ import annotations

from benchmarks.common import Timer, run_and_report, sim_with

CAPS = [1, 2, 4, 8, 16, 32, 64, 128]


def run(n_requests: int = 256):
    rows = []
    with Timer() as t:
        for cap in CAPS:
            r = run_and_report(sim_with(batch_cap=cap, qps=50.0,
                                        n_requests=n_requests))
            rows.append({"cap": cap, "actual_batch": r["avg_batch"],
                         "avg_power_w": r["avg_power_w"],
                         "energy_wh": r["energy_wh"]})
    sub = all(rows[i]["actual_batch"] <= CAPS[i] for i in range(len(rows)))
    power_up = rows[-1]["avg_power_w"] > rows[0]["avg_power_w"]
    energy_down = rows[-1]["energy_wh"] < rows[0]["energy_wh"]
    gain_16 = rows[0]["energy_wh"] / rows[4]["energy_wh"]
    gain_128 = rows[4]["energy_wh"] / rows[-1]["energy_wh"]
    derived = (f"batch_sublinear={sub};power_rises={power_up}(paper:yes);"
               f"energy_drops={energy_down}(paper:yes);"
               f"gain1->16={gain_16:.1f}x;gain16->128={gain_128:.2f}x"
               f"(paper:diminishing past 16)")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        print(f"cap={r['cap']:4d} batch={r['actual_batch']:6.1f} "
              f"P={r['avg_power_w']:6.1f}W E={r['energy_wh']:8.2f}Wh")
    print(derived)
