"""Fig. 4 / Exp. 3: batch-size cap vs power and energy.

Paper claims: actual batch size grows sublinearly with the cap; average
power rises with cap and plateaus above ~64; total energy drops with
larger caps with diminishing returns past ~16.

Grid declaration: ``repro.sweep.scenarios`` ("fig4").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fig4", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fig4")
