"""Fig. 5 / Exp. 4: query throughput (QPS) vs power and energy.

Paper claims (fixed workload size): average power increases with QPS and
saturates near 360 W beyond ~5 QPS; total energy decreases with QPS and
converges toward ~0.5 kWh beyond ~8 QPS (their 2^14-request workload).
"""
from __future__ import annotations

from benchmarks.common import Timer, run_and_report, sim_with

QPS_GRID = [0.5, 1.0, 2.0, 3.2, 5.0, 7.9, 10.0, 12.6]


def run(n_requests: int = 2048):
    rows = []
    with Timer() as t:
        for qps in QPS_GRID:
            r = run_and_report(sim_with(qps=qps, n_requests=n_requests))
            rows.append({"qps": qps, "avg_power_w": r["avg_power_w"],
                         "energy_wh": r["energy_wh"],
                         "duration_s": r["duration_s"]})
    p_sat = [r["avg_power_w"] for r in rows if r["qps"] >= 5.0]
    e_hi = [r["energy_wh"] for r in rows if r["qps"] >= 7.9]
    # scale the paper's 2^14-request 0.5 kWh convergence to our n
    scale = n_requests / 16384
    derived = (f"P_sat={min(p_sat):.0f}-{max(p_sat):.0f}W(paper:~360);"
               f"E_converged={min(e_hi):.1f}Wh"
               f"(paper~{500 * scale:.0f}Wh at this workload scale)")
    return rows, derived, t.elapsed_us


if __name__ == "__main__":
    rows, derived, _ = run()
    for r in rows:
        print(f"qps={r['qps']:5.1f} P={r['avg_power_w']:6.1f}W "
              f"E={r['energy_wh']:8.2f}Wh dur={r['duration_s']:7.1f}s")
    print(derived)
