"""Fig. 5 / Exp. 4: query throughput (QPS) vs power and energy.

Paper claims (fixed workload size): average power increases with QPS and
saturates near 360 W beyond ~5 QPS; total energy decreases with QPS and
converges toward ~0.5 kWh beyond ~8 QPS (their 2^14-request workload).

Grid declaration: ``repro.sweep.scenarios`` ("fig5").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("fig5", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("fig5")
