"""Sweep-engine perf trajectory: device vs vectorized vs event loop.

Times the ``perf`` smoke grid (plane A: 4 workloads x 16 PUE x 16
grid-CI; plane B: a device x TP x PP family over one isolated-arrival
stream) through all three runner modes with the cache disabled, checks
the equivalence contract — vectorized records bit-identical to the
event loop, device records within ``DEVICE_MODE_RTOL`` — and writes
the scenarios/sec baseline to ``BENCH_sweep.json`` at the repo root so
future PRs can compare against it. CI runs
``--smoke --check 5 --check-device 2`` and fails if vectorized drops
below 5x the event-loop throughput or device below 2x vectorized.

Vectorized and device are timed best-of-2 so the device number
reflects steady-state dispatch, not the one-time jit compile (the
compile cost is reported separately as ``device_first_call_s``).

The mode runs execute under the ``repro.obs`` wall-clock profiler, so
the bench JSON carries a ``phases`` breakdown (cache lookup, event
loops, stacked passes, device compile vs execute). The probe-
neutrality *cost* contract is measured too: one persistent probe per
trial side (matching how ``SweepRunner`` attaches a single probe for
a whole sweep), each scenario timed back to back under both sides
with alternating order so machine drift cancels pairwise, and the
overhead estimated as median(paired deltas) / median(baseline times)
over 3 trials — the paired-median estimator is robust to the
scheduler-noise spikes any single sample can take. The probe cost is
always measured on a stratified subset of the FULL-SIZE grid (even
under ``--smoke``): the pin is a statement about production sweeps,
and smoke scenarios are ~3-15x shorter than the grid's real
workloads, so their percentage is dominated by per-scenario fixed
costs (rollup, finalize, run reset) rather than the per-event audit
scaling the pin is meant to bound. Probe-off vs ``NULL_PROBE`` is
reported as ``obs_probe_overhead_pct`` and bounded by ``--check-obs``
(CI pins <= 2%); ``NULL_PROBE`` vs ``AuditProbe`` isolates the
streaming-invariant checks from the hook dispatch both sides share —
reported as ``audit_probe_overhead_pct`` and bounded by
``--check-audit`` (CI pins <= 3%).

The ``remote`` entry times the distributed backend on the FULL perf
grid (like the probe-cost protocol, the pin is a statement about
production sweeps), with each side measured at its own operational
steady state. The baseline is the single-process vectorized backend
in a fresh process per run (best of 2, timed inside the subprocess
around ``run()``) — exactly how ``python -m repro.sweep.cli``
executes a sweep, paying the per-process numpy/eager-jax warm-up on
every invocation. The remote side is a coordinator plus a resident
2-worker fleet (``repro.sweep.remote``): workers spawn and warm once,
then serve successive jobs (fresh result cache each; best of 5 job
times reported, since racing shard claims mean a few jobs pass before
every worker has warmed every trace-group shape) — exactly how a worker fleet amortizes process
start-up across the jobs of a campaign, and the remote analogue of
the best-of-2 steady-state convention the jit-dispatch numbers
already use. Both sides persist records into a fresh cache — apples
to apples, since writing records into the shared cache IS how the
remote backend returns results. ``--check-remote`` (CI pins >= 1.5x)
fails on speedup below the bound, non-bit-identical records, or any
expired lease on the happy path.

Usage: python -m benchmarks.perf_sweep [--smoke] [--check MIN_SPEEDUP]
                                       [--check-device MIN_SPEEDUP]
                                       [--check-obs MAX_OVERHEAD_PCT]
                                       [--check-audit MAX_OVERHEAD_PCT]
                                       [--check-remote MIN_SPEEDUP]
"""
from __future__ import annotations

import gc
import json
import os
import statistics
import sys
import time
from pathlib import Path

# device_first_call_s must stay an honest per-process compile cost:
# a warm persistent compilation cache would report disk-replay time
# instead (an explicit env value still wins)
os.environ.setdefault("REPRO_JAX_CACHE_DIR", "off")

# the committed/CI baseline is the smoke grid (by design: ~1k scenarios
# in seconds); a full-scale run writes its own file so it never
# clobbers — nor is clobbered by — the smoke baseline
_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATHS = {True: _ROOT / "BENCH_sweep.json",
               False: _ROOT / "BENCH_sweep_full.json"}


def _best_of(fn, reps: int):
    best, out = float("inf"), None
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        times.append(dt)
        best = min(best, dt)
    return best, times, out


_LOCAL_BASELINE_SCRIPT = """
import json, sys, time
from repro.sweep import ResultCache, SweepRunner, SWEEPS
scenarios = SWEEPS["perf"].build(False)
cache = ResultCache(sys.argv[1])
t0 = time.perf_counter()
records, stats = SweepRunner(cache=cache, mode="vectorized").run(scenarios)
print(json.dumps({"s": time.perf_counter() - t0, "n": len(records)}))
"""


def _measure_remote(scenarios) -> dict:
    """Coordinator + 2 resident workers vs the single-process
    vectorized backend, each measured at its own operational steady
    state: the baseline is a FRESH process per run (exactly how
    ``python -m repro.sweep.cli`` executes a sweep — every invocation
    pays the per-process numpy/eager-jax warm-up; timed inside the
    subprocess around ``run()``, imports excluded, best of 2 runs),
    while the remote side is a long-lived fleet — workers spawn, warm,
    register alive, then serve several jobs (fresh result cache each,
    best of 3) and the steady-state job time is reported, matching the
    bench's existing best-of-N convention for jit dispatch. Both sides
    persist records into a fresh cache (writing into the shared cache
    IS how the remote backend returns results). Records are compared
    key-by-key for bit-identity."""
    import os as _os
    import shutil
    import subprocess
    import tempfile

    from repro.sweep import ResultCache, SweepRunner
    from repro.sweep.remote import (RemoteOptions, spawn_worker,
                                    wait_for_workers)

    td = Path(tempfile.mkdtemp(prefix="bench_remote_"))
    try:
        import repro
        pkg_root = str(Path(repro.__file__).resolve().parent.parent)
        env = dict(_os.environ)
        env["PYTHONPATH"] = pkg_root + (
            _os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        local_s = float("inf")
        for rep in range(2):
            cache_dir = td / f"cache_local{rep}"
            out = subprocess.run(
                [sys.executable, "-c", _LOCAL_BASELINE_SCRIPT,
                 str(cache_dir)],
                env=env, capture_output=True, text=True, check=True)
            local_s = min(local_s,
                          json.loads(out.stdout.strip().splitlines()[-1])["s"])
        local_cache = ResultCache(cache_dir)
        local_recs = [local_cache.get(sc.key) for sc in scenarios]
        assert all(local_recs), "baseline cache is missing records"

        queue = td / "queue"
        procs = [spawn_worker(queue, f"bench-w{i}",
                              log_path=td / f"w{i}.log")
                 for i in range(2)]
        try:
            wait_for_workers(queue, 2, timeout_s=300)
            opts = RemoteOptions(queue_dir=queue, spawn_workers=0,
                                 lease_s=60.0, timeout_s=900.0)
            rep_times = []
            for rep in range(5):
                cache_remote = ResultCache(td / f"cache_remote{rep}")
                t0 = time.perf_counter()
                remote_recs, stats = SweepRunner(
                    cache=cache_remote, backend="remote",
                    remote=opts).run(scenarios)
                rep_times.append(round(time.perf_counter() - t0, 3))
            remote_s = min(rep_times)
        finally:
            (queue / "stop").touch()
            for p in procs:
                try:
                    p.wait(timeout=15)
                except Exception:
                    p.terminate()
                    p.wait(timeout=10)

        by_key = {r["key"]: r for r in local_recs}
        bit_identical = all(
            r["metrics"] == by_key[r["key"]]["metrics"]
            for r in remote_recs)
        n = len(scenarios)
        return {
            "workers": 2,
            "cpus": _os.cpu_count() or 1,
            "shards": stats.shards,
            "vectorized_s": round(local_s, 3),
            "remote_s": round(remote_s, 3),
            "remote_rep_s": rep_times,
            "speedup": round(local_s / remote_s, 2),
            "vectorized_scenarios_per_s": round(n / local_s, 1),
            "remote_scenarios_per_s": round(n / remote_s, 1),
            "bit_identical": bit_identical,
            "lease_expired": stats.lease_expired,
            "retried": stats.retried,
            "quarantined": stats.quarantined,
        }
    finally:
        shutil.rmtree(td, ignore_errors=True)


def measure(smoke: bool = False) -> dict:
    from repro.obs.probe import NULL_PROBE
    from repro.obs.spans import PROFILER
    from repro.sweep import SCHEMA_VERSION, SWEEPS, SweepRunner
    from repro.sweep.device import DEVICE_MODE_RTOL, records_max_rel_err

    scenarios = SWEEPS["perf"].build(smoke)

    # the timed mode runs double as the wall-clock phase breakdown
    # (span overhead is a handful of perf_counter pairs per scenario)
    PROFILER.enable(reset=True)
    try:
        t0 = time.perf_counter()
        ev_records, ev_stats = SweepRunner(cache=None,
                                           mode="event_loop").run(scenarios)
        event_loop_s = time.perf_counter() - t0

        vectorized_s, _, (ve_records, ve_stats) = _best_of(
            lambda: SweepRunner(cache=None, mode="vectorized").run(scenarios),
            reps=2)

        device_s, dev_times, (dv_records, dv_stats) = _best_of(
            lambda: SweepRunner(cache=None, mode="device").run(scenarios),
            reps=2)
    finally:
        PROFILER.disable()
    phases = {name: {"count": int(a["count"]),
                     "total_s": round(a["total_s"], 3)}
              for name, a in sorted(PROFILER.aggregate().items())}

    # probe-cost protocol: a probe's true per-scenario cost (tens of
    # microseconds) sits far below the machine noise of any whole-pass
    # timing, so each scenario executes under both trial sides back to
    # back (alternating order to cancel warm-cache bias) and the two
    # samples of a pair see near-identical machine state. One
    # persistent probe instance serves a whole trial side — matching
    # how SweepRunner attaches a single probe for an entire sweep
    # (execute_scenario marks each scenario via on_run_begin). The
    # cost set is a stratified subset of the full-size grid (see the
    # module docstring for why smoke scenarios misprice the probes).
    from repro.sweep.runner import execute_scenario

    cost_source = scenarios if not smoke else SWEEPS["perf"].build(False)
    stride = max(1, len(cost_source) // 32)
    cost_set = cost_source[::stride][:32]
    for sc in cost_set:                 # warm the jit/exec caches
        execute_scenario(sc, probe=None)

    def _paired_trial(base_probe, test_probe):
        gc.collect()
        base_ts, test_ts = [], []
        for k, sc in enumerate(cost_set):
            pair = ((base_probe, test_probe) if k % 2 == 0
                    else (test_probe, base_probe))
            for probe in pair:
                t0 = time.perf_counter()
                execute_scenario(sc, probe=probe)
                dt = time.perf_counter() - t0
                (base_ts if probe is base_probe else test_ts).append(dt)
        return base_ts, test_ts

    def _overhead_pct(trials):
        # median-of-pairs: each scenario pair contributes one delta,
        # and the median over all pairs (3 trials x grid) is immune to
        # the scheduler-noise spikes that dominate sum-of-side ratios;
        # normalizing by the median baseline scenario yields the pct
        base_all = [b for bt, _ in trials for b in bt]
        delta_all = [t - b for bt, tt in trials
                     for b, t in zip(bt, tt)]
        return (statistics.median(delta_all)
                / statistics.median(base_all) * 100.0)

    # obs-neutrality cost: NULL_PROBE (every hook dispatched, empty
    # bodies) vs probe-off
    obs_trials = [_paired_trial(None, NULL_PROBE) for _ in range(3)]
    obs_off_s = min(sum(bt) for bt, _ in obs_trials)
    obs_on_s = min(sum(tt) for _, tt in obs_trials)
    obs_overhead_pct = _overhead_pct(obs_trials)

    # audit cost: the streaming invariant checks vs the no-op probe
    # (the NULL_PROBE baseline isolates the check bodies, not the
    # hook dispatch both sides share); a fresh auditor per trial so
    # report state never accretes across trials
    from repro.obs.audit import AuditProbe

    audit_trials = [_paired_trial(NULL_PROBE, AuditProbe())
                    for _ in range(3)]
    audit_s = min(sum(tt) for _, tt in audit_trials)
    audit_overhead_pct = _overhead_pct(audit_trials)

    # distributed backend: always on the FULL grid (cost_source) — the
    # smoke grid's traces are too short for dispatch to dominate, and
    # the pin is about production sweeps
    remote = _measure_remote(cost_source)

    bit_identical = all(a["metrics"] == b["metrics"]
                        for a, b in zip(ev_records, ve_records))
    device_max_rel_err = records_max_rel_err(dv_records, ev_records)
    n = len(scenarios)
    return {
        "grid": "perf",
        "smoke": smoke,
        "schema": SCHEMA_VERSION,
        "n_scenarios": n,
        "n_trace_groups": ve_stats.trace_groups,
        "event_loop_s": round(event_loop_s, 3),
        "vectorized_s": round(vectorized_s, 3),
        "device_s": round(device_s, 3),
        "device_first_call_s": round(dev_times[0], 3),
        "device_event_loops": dv_stats.event_loops,
        "device_replayed": dv_stats.replayed,
        "event_loop_scenarios_per_s": round(n / event_loop_s, 1),
        "vectorized_scenarios_per_s": round(n / vectorized_s, 1),
        "device_scenarios_per_s": round(n / device_s, 1),
        "speedup": round(event_loop_s / vectorized_s, 2),
        "device_speedup": round(vectorized_s / device_s, 2),
        "bit_identical": bit_identical,
        "device_max_rel_err": device_max_rel_err,
        "device_rtol": DEVICE_MODE_RTOL,
        "probe_cost_scenarios": len(cost_set),
        "obs_probe_off_s": round(obs_off_s, 3),
        "obs_null_probe_s": round(obs_on_s, 3),
        "obs_probe_overhead_pct": round(obs_overhead_pct, 2),
        "audit_probe_s": round(audit_s, 3),
        "audit_probe_overhead_pct": round(audit_overhead_pct, 2),
        "remote": remote,
        "phases": phases,
    }


def run(smoke: bool = False):
    """``benchmarks.run`` entry: (rows, derived, us_per_call)."""
    t0 = time.time()
    result = measure(smoke=smoke)
    BENCH_PATHS[smoke].write_text(json.dumps(result, indent=1) + "\n")
    derived = (f"speedup={result['speedup']}x"
               f"(target>=5);bit_identical={result['bit_identical']};"
               f"device_speedup={result['device_speedup']}x(target>=2);"
               f"device_max_rel_err={result['device_max_rel_err']:.2e};"
               f"{result['n_scenarios']}scen/"
               f"{result['n_trace_groups']}traces;"
               f"vec={result['vectorized_scenarios_per_s']}scen_per_s;"
               f"obs_overhead={result['obs_probe_overhead_pct']}%"
               f"(target<=2);"
               f"audit_overhead={result['audit_probe_overhead_pct']}%"
               f"(target<=3);"
               f"remote_speedup={result['remote']['speedup']}x"
               f"(target>=1.5,2workers,"
               f"bit_identical={result['remote']['bit_identical']})")
    return [result], derived, (time.time() - t0) * 1e6


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    check = None
    if "--check" in args:
        i = args.index("--check")
        check = float(args[i + 1]) if i + 1 < len(args) else 5.0
    check_device = None
    if "--check-device" in args:
        i = args.index("--check-device")
        check_device = float(args[i + 1]) if i + 1 < len(args) else 2.0
    check_obs = None
    if "--check-obs" in args:
        i = args.index("--check-obs")
        check_obs = float(args[i + 1]) if i + 1 < len(args) else 2.0
    check_audit = None
    if "--check-audit" in args:
        i = args.index("--check-audit")
        check_audit = float(args[i + 1]) if i + 1 < len(args) else 3.0
    check_remote = None
    if "--check-remote" in args:
        i = args.index("--check-remote")
        check_remote = float(args[i + 1]) if i + 1 < len(args) else 1.5
    rows, derived, _ = run(smoke=smoke)
    result = rows[0]
    print(json.dumps(result, indent=1))
    print(f"wrote {BENCH_PATHS[smoke]}")
    if not result["bit_identical"]:
        print("FAIL: vectorized records diverge from event-loop records",
              file=sys.stderr)
        return 1
    if result["device_max_rel_err"] > result["device_rtol"]:
        print(f"FAIL: device records diverge from event-loop records by "
              f"{result['device_max_rel_err']:.3e} > rtol "
              f"{result['device_rtol']:.1e}", file=sys.stderr)
        return 1
    if check is not None and result["speedup"] < check:
        print(f"FAIL: speedup {result['speedup']}x < required {check}x",
              file=sys.stderr)
        return 1
    if check_device is not None and result["device_speedup"] < check_device:
        print(f"FAIL: device speedup {result['device_speedup']}x < "
              f"required {check_device}x", file=sys.stderr)
        return 1
    if check_obs is not None and \
            result["obs_probe_overhead_pct"] > check_obs:
        print(f"FAIL: null-probe overhead "
              f"{result['obs_probe_overhead_pct']}% > allowed "
              f"{check_obs}%", file=sys.stderr)
        return 1
    if check_audit is not None and \
            result["audit_probe_overhead_pct"] > check_audit:
        print(f"FAIL: audit-probe overhead "
              f"{result['audit_probe_overhead_pct']}% > allowed "
              f"{check_audit}%", file=sys.stderr)
        return 1
    if check_remote is not None:
        rem = result["remote"]
        if not rem["bit_identical"]:
            print("FAIL: remote records diverge from single-process "
                  "vectorized records", file=sys.stderr)
            return 1
        if rem["lease_expired"]:
            print(f"FAIL: {rem['lease_expired']} lease(s) expired on "
                  "the happy path (workers wedged or heartbeats lost)",
                  file=sys.stderr)
            return 1
        if rem["speedup"] < check_remote:
            print(f"FAIL: remote speedup {rem['speedup']}x < required "
                  f"{check_remote}x", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
