"""Sweep-engine perf trajectory: device vs vectorized vs event loop.

Times the ``perf`` smoke grid (plane A: 4 workloads x 16 PUE x 16
grid-CI; plane B: a device x TP x PP family over one isolated-arrival
stream) through all three runner modes with the cache disabled, checks
the equivalence contract — vectorized records bit-identical to the
event loop, device records within ``DEVICE_MODE_RTOL`` — and writes
the scenarios/sec baseline to ``BENCH_sweep.json`` at the repo root so
future PRs can compare against it. CI runs
``--smoke --check 5 --check-device 2`` and fails if vectorized drops
below 5x the event-loop throughput or device below 2x vectorized.

Vectorized and device are timed best-of-2 so the device number
reflects steady-state dispatch, not the one-time jit compile (the
compile cost is reported separately as ``device_first_call_s``).

The mode runs execute under the ``repro.obs`` wall-clock profiler, so
the bench JSON carries a ``phases`` breakdown (cache lookup, event
loops, stacked passes, device compile vs execute). The probe-
neutrality *cost* contract is measured too: one persistent probe per
trial side (matching how ``SweepRunner`` attaches a single probe for
a whole sweep), each scenario timed back to back under both sides
with alternating order so machine drift cancels pairwise, and the
overhead estimated as median(paired deltas) / median(baseline times)
over 3 trials — the paired-median estimator is robust to the
scheduler-noise spikes any single sample can take. The probe cost is
always measured on a stratified subset of the FULL-SIZE grid (even
under ``--smoke``): the pin is a statement about production sweeps,
and smoke scenarios are ~3-15x shorter than the grid's real
workloads, so their percentage is dominated by per-scenario fixed
costs (rollup, finalize, run reset) rather than the per-event audit
scaling the pin is meant to bound. Probe-off vs ``NULL_PROBE`` is
reported as ``obs_probe_overhead_pct`` and bounded by ``--check-obs``
(CI pins <= 2%); ``NULL_PROBE`` vs ``AuditProbe`` isolates the
streaming-invariant checks from the hook dispatch both sides share —
reported as ``audit_probe_overhead_pct`` and bounded by
``--check-audit`` (CI pins <= 3%).

Usage: python -m benchmarks.perf_sweep [--smoke] [--check MIN_SPEEDUP]
                                       [--check-device MIN_SPEEDUP]
                                       [--check-obs MAX_OVERHEAD_PCT]
                                       [--check-audit MAX_OVERHEAD_PCT]
"""
from __future__ import annotations

import gc
import json
import statistics
import sys
import time
from pathlib import Path

# the committed/CI baseline is the smoke grid (by design: ~1k scenarios
# in seconds); a full-scale run writes its own file so it never
# clobbers — nor is clobbered by — the smoke baseline
_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATHS = {True: _ROOT / "BENCH_sweep.json",
               False: _ROOT / "BENCH_sweep_full.json"}


def _best_of(fn, reps: int):
    best, out = float("inf"), None
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        times.append(dt)
        best = min(best, dt)
    return best, times, out


def measure(smoke: bool = False) -> dict:
    from repro.obs.probe import NULL_PROBE
    from repro.obs.spans import PROFILER
    from repro.sweep import SCHEMA_VERSION, SWEEPS, SweepRunner
    from repro.sweep.device import DEVICE_MODE_RTOL, records_max_rel_err

    scenarios = SWEEPS["perf"].build(smoke)

    # the timed mode runs double as the wall-clock phase breakdown
    # (span overhead is a handful of perf_counter pairs per scenario)
    PROFILER.enable(reset=True)
    try:
        t0 = time.perf_counter()
        ev_records, ev_stats = SweepRunner(cache=None,
                                           mode="event_loop").run(scenarios)
        event_loop_s = time.perf_counter() - t0

        vectorized_s, _, (ve_records, ve_stats) = _best_of(
            lambda: SweepRunner(cache=None, mode="vectorized").run(scenarios),
            reps=2)

        device_s, dev_times, (dv_records, dv_stats) = _best_of(
            lambda: SweepRunner(cache=None, mode="device").run(scenarios),
            reps=2)
    finally:
        PROFILER.disable()
    phases = {name: {"count": int(a["count"]),
                     "total_s": round(a["total_s"], 3)}
              for name, a in sorted(PROFILER.aggregate().items())}

    # probe-cost protocol: a probe's true per-scenario cost (tens of
    # microseconds) sits far below the machine noise of any whole-pass
    # timing, so each scenario executes under both trial sides back to
    # back (alternating order to cancel warm-cache bias) and the two
    # samples of a pair see near-identical machine state. One
    # persistent probe instance serves a whole trial side — matching
    # how SweepRunner attaches a single probe for an entire sweep
    # (execute_scenario marks each scenario via on_run_begin). The
    # cost set is a stratified subset of the full-size grid (see the
    # module docstring for why smoke scenarios misprice the probes).
    from repro.sweep.runner import execute_scenario

    cost_source = scenarios if not smoke else SWEEPS["perf"].build(False)
    stride = max(1, len(cost_source) // 32)
    cost_set = cost_source[::stride][:32]
    for sc in cost_set:                 # warm the jit/exec caches
        execute_scenario(sc, probe=None)

    def _paired_trial(base_probe, test_probe):
        gc.collect()
        base_ts, test_ts = [], []
        for k, sc in enumerate(cost_set):
            pair = ((base_probe, test_probe) if k % 2 == 0
                    else (test_probe, base_probe))
            for probe in pair:
                t0 = time.perf_counter()
                execute_scenario(sc, probe=probe)
                dt = time.perf_counter() - t0
                (base_ts if probe is base_probe else test_ts).append(dt)
        return base_ts, test_ts

    def _overhead_pct(trials):
        # median-of-pairs: each scenario pair contributes one delta,
        # and the median over all pairs (3 trials x grid) is immune to
        # the scheduler-noise spikes that dominate sum-of-side ratios;
        # normalizing by the median baseline scenario yields the pct
        base_all = [b for bt, _ in trials for b in bt]
        delta_all = [t - b for bt, tt in trials
                     for b, t in zip(bt, tt)]
        return (statistics.median(delta_all)
                / statistics.median(base_all) * 100.0)

    # obs-neutrality cost: NULL_PROBE (every hook dispatched, empty
    # bodies) vs probe-off
    obs_trials = [_paired_trial(None, NULL_PROBE) for _ in range(3)]
    obs_off_s = min(sum(bt) for bt, _ in obs_trials)
    obs_on_s = min(sum(tt) for _, tt in obs_trials)
    obs_overhead_pct = _overhead_pct(obs_trials)

    # audit cost: the streaming invariant checks vs the no-op probe
    # (the NULL_PROBE baseline isolates the check bodies, not the
    # hook dispatch both sides share); a fresh auditor per trial so
    # report state never accretes across trials
    from repro.obs.audit import AuditProbe

    audit_trials = [_paired_trial(NULL_PROBE, AuditProbe())
                    for _ in range(3)]
    audit_s = min(sum(tt) for _, tt in audit_trials)
    audit_overhead_pct = _overhead_pct(audit_trials)

    bit_identical = all(a["metrics"] == b["metrics"]
                        for a, b in zip(ev_records, ve_records))
    device_max_rel_err = records_max_rel_err(dv_records, ev_records)
    n = len(scenarios)
    return {
        "grid": "perf",
        "smoke": smoke,
        "schema": SCHEMA_VERSION,
        "n_scenarios": n,
        "n_trace_groups": ve_stats.trace_groups,
        "event_loop_s": round(event_loop_s, 3),
        "vectorized_s": round(vectorized_s, 3),
        "device_s": round(device_s, 3),
        "device_first_call_s": round(dev_times[0], 3),
        "device_event_loops": dv_stats.event_loops,
        "device_replayed": dv_stats.replayed,
        "event_loop_scenarios_per_s": round(n / event_loop_s, 1),
        "vectorized_scenarios_per_s": round(n / vectorized_s, 1),
        "device_scenarios_per_s": round(n / device_s, 1),
        "speedup": round(event_loop_s / vectorized_s, 2),
        "device_speedup": round(vectorized_s / device_s, 2),
        "bit_identical": bit_identical,
        "device_max_rel_err": device_max_rel_err,
        "device_rtol": DEVICE_MODE_RTOL,
        "probe_cost_scenarios": len(cost_set),
        "obs_probe_off_s": round(obs_off_s, 3),
        "obs_null_probe_s": round(obs_on_s, 3),
        "obs_probe_overhead_pct": round(obs_overhead_pct, 2),
        "audit_probe_s": round(audit_s, 3),
        "audit_probe_overhead_pct": round(audit_overhead_pct, 2),
        "phases": phases,
    }


def run(smoke: bool = False):
    """``benchmarks.run`` entry: (rows, derived, us_per_call)."""
    t0 = time.time()
    result = measure(smoke=smoke)
    BENCH_PATHS[smoke].write_text(json.dumps(result, indent=1) + "\n")
    derived = (f"speedup={result['speedup']}x"
               f"(target>=5);bit_identical={result['bit_identical']};"
               f"device_speedup={result['device_speedup']}x(target>=2);"
               f"device_max_rel_err={result['device_max_rel_err']:.2e};"
               f"{result['n_scenarios']}scen/"
               f"{result['n_trace_groups']}traces;"
               f"vec={result['vectorized_scenarios_per_s']}scen_per_s;"
               f"obs_overhead={result['obs_probe_overhead_pct']}%"
               f"(target<=2);"
               f"audit_overhead={result['audit_probe_overhead_pct']}%"
               f"(target<=3)")
    return [result], derived, (time.time() - t0) * 1e6


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    check = None
    if "--check" in args:
        i = args.index("--check")
        check = float(args[i + 1]) if i + 1 < len(args) else 5.0
    check_device = None
    if "--check-device" in args:
        i = args.index("--check-device")
        check_device = float(args[i + 1]) if i + 1 < len(args) else 2.0
    check_obs = None
    if "--check-obs" in args:
        i = args.index("--check-obs")
        check_obs = float(args[i + 1]) if i + 1 < len(args) else 2.0
    check_audit = None
    if "--check-audit" in args:
        i = args.index("--check-audit")
        check_audit = float(args[i + 1]) if i + 1 < len(args) else 3.0
    rows, derived, _ = run(smoke=smoke)
    result = rows[0]
    print(json.dumps(result, indent=1))
    print(f"wrote {BENCH_PATHS[smoke]}")
    if not result["bit_identical"]:
        print("FAIL: vectorized records diverge from event-loop records",
              file=sys.stderr)
        return 1
    if result["device_max_rel_err"] > result["device_rtol"]:
        print(f"FAIL: device records diverge from event-loop records by "
              f"{result['device_max_rel_err']:.3e} > rtol "
              f"{result['device_rtol']:.1e}", file=sys.stderr)
        return 1
    if check is not None and result["speedup"] < check:
        print(f"FAIL: speedup {result['speedup']}x < required {check}x",
              file=sys.stderr)
        return 1
    if check_device is not None and result["device_speedup"] < check_device:
        print(f"FAIL: device speedup {result['device_speedup']}x < "
              f"required {check_device}x", file=sys.stderr)
        return 1
    if check_obs is not None and \
            result["obs_probe_overhead_pct"] > check_obs:
        print(f"FAIL: null-probe overhead "
              f"{result['obs_probe_overhead_pct']}% > allowed "
              f"{check_obs}%", file=sys.stderr)
        return 1
    if check_audit is not None and \
            result["audit_probe_overhead_pct"] > check_audit:
        print(f"FAIL: audit-probe overhead "
              f"{result['audit_probe_overhead_pct']}% > allowed "
              f"{check_audit}%", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
