"""Sweep-engine perf trajectory: vectorized vs event-loop throughput.

Times the 1k-scenario ``perf`` smoke grid (4 workloads x 16 PUE x 16
grid-CI) through both runner modes with the cache disabled, checks the
records agree bit-for-bit, and writes the scenarios/sec baseline to
``BENCH_sweep.json`` at the repo root so future PRs can compare
against it. CI runs ``--smoke --check 5`` and fails if the vectorized
mode drops below 5x the event-loop throughput.

Usage: python -m benchmarks.perf_sweep [--smoke] [--check MIN_SPEEDUP]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

# the committed/CI baseline is the smoke grid (by design: 1k scenarios
# in seconds); a full-scale run writes its own file so it never
# clobbers — nor is clobbered by — the smoke baseline
_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATHS = {True: _ROOT / "BENCH_sweep.json",
               False: _ROOT / "BENCH_sweep_full.json"}


def measure(smoke: bool = False) -> dict:
    from repro.sweep import SCHEMA_VERSION, SWEEPS, SweepRunner

    scenarios = SWEEPS["perf"].build(smoke)

    t0 = time.perf_counter()
    ev_records, ev_stats = SweepRunner(cache=None,
                                       mode="event_loop").run(scenarios)
    event_loop_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ve_records, ve_stats = SweepRunner(cache=None,
                                       mode="vectorized").run(scenarios)
    vectorized_s = time.perf_counter() - t0

    bit_identical = all(a["metrics"] == b["metrics"]
                        for a, b in zip(ev_records, ve_records))
    n = len(scenarios)
    return {
        "grid": "perf",
        "smoke": smoke,
        "schema": SCHEMA_VERSION,
        "n_scenarios": n,
        "n_trace_groups": ve_stats.trace_groups,
        "event_loop_s": round(event_loop_s, 3),
        "vectorized_s": round(vectorized_s, 3),
        "event_loop_scenarios_per_s": round(n / event_loop_s, 1),
        "vectorized_scenarios_per_s": round(n / vectorized_s, 1),
        "speedup": round(event_loop_s / vectorized_s, 2),
        "bit_identical": bit_identical,
    }


def run(smoke: bool = False):
    """``benchmarks.run`` entry: (rows, derived, us_per_call)."""
    t0 = time.time()
    result = measure(smoke=smoke)
    BENCH_PATHS[smoke].write_text(json.dumps(result, indent=1) + "\n")
    derived = (f"speedup={result['speedup']}x"
               f"(target>=5);bit_identical={result['bit_identical']};"
               f"{result['n_scenarios']}scen/"
               f"{result['n_trace_groups']}traces;"
               f"vec={result['vectorized_scenarios_per_s']}scen_per_s")
    return [result], derived, (time.time() - t0) * 1e6


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    check = None
    if "--check" in args:
        i = args.index("--check")
        check = float(args[i + 1]) if i + 1 < len(args) else 5.0
    rows, derived, _ = run(smoke=smoke)
    result = rows[0]
    print(json.dumps(result, indent=1))
    print(f"wrote {BENCH_PATHS[smoke]}")
    if not result["bit_identical"]:
        print("FAIL: vectorized records diverge from event-loop records",
              file=sys.stderr)
        return 1
    if check is not None and result["speedup"] < check:
        print(f"FAIL: speedup {result['speedup']}x < required {check}x",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
