"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus writes detailed rows to
results/benchmarks/*.json). All entries execute through the scenario
sweep engine (``repro.sweep``), so completed scenarios are memoized in
the on-disk result cache and re-runs are incremental.

Usage: python -m benchmarks.run [--smoke] [names...]
"""
from __future__ import annotations

import json
import sys
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "benchmarks"


def main() -> None:
    from benchmarks import (exp5_parallelism, exp6_fleet, exp7_shifting,
                            exp8_day, fig1_qps_saturation,
                            fig2_request_count, fig3_pd_ratio,
                            fig4_batch_cap, fig5_qps, perf_sweep,
                            table2_cosim)
    benches = [
        ("fig1_qps_saturation", fig1_qps_saturation.run),
        ("fig2_request_count", fig2_request_count.run),
        ("fig3_pd_ratio", fig3_pd_ratio.run),
        ("fig4_batch_cap", fig4_batch_cap.run),
        ("fig5_qps", fig5_qps.run),
        ("exp5_parallelism", exp5_parallelism.run),
        ("table2_cosim", table2_cosim.run),
        ("exp6_fleet", exp6_fleet.run),
        ("exp7_shifting", exp7_shifting.run),
        ("perf_sweep", perf_sweep.run),
        ("exp8_day", exp8_day.run),
    ]
    args = sys.argv[1:]
    smoke = "--smoke" in args
    bad_flags = [a for a in args if a.startswith("--") and a != "--smoke"]
    if bad_flags:
        print(f"unknown flag(s): {' '.join(bad_flags)} "
              f"(only --smoke is supported)", file=sys.stderr)
        sys.exit(2)
    names = [a for a in args if not a.startswith("--")]
    if names:
        benches = [(n, fn) for n, fn in benches
                   if any(n.startswith(want) for want in names)]
        if not benches:
            print(f"no benchmark matches {names!r}; have "
                  f"fig1..fig5, exp5, exp6, exp7, exp8, table2, "
                  f"perf_sweep", file=sys.stderr)
            sys.exit(2)
    # smoke-scale rows go to their own subdir so they never shadow a
    # full reproduction's results under the same path
    outdir = RESULTS / "smoke" if smoke else RESULTS
    outdir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        try:
            rows, derived, us = fn(smoke=smoke)
            print(f"{name},{us:.0f},{derived}")
            payload = rows if isinstance(rows, (list, dict)) else str(rows)
            (outdir / f"{name}.json").write_text(
                json.dumps({"rows": payload, "derived": derived,
                            "us_per_call": us, "smoke": smoke},
                           indent=1, default=str))
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
