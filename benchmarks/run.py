"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus writes detailed rows to
results/benchmarks/*.json).
"""
from __future__ import annotations

import json
import sys
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "benchmarks"


def main() -> None:
    from benchmarks import (exp5_parallelism, fig1_qps_saturation,
                            fig2_request_count, fig3_pd_ratio,
                            fig4_batch_cap, fig5_qps, table2_cosim)
    benches = [
        ("fig1_qps_saturation", fig1_qps_saturation.run),
        ("fig2_request_count", fig2_request_count.run),
        ("fig3_pd_ratio", fig3_pd_ratio.run),
        ("fig4_batch_cap", fig4_batch_cap.run),
        ("fig5_qps", fig5_qps.run),
        ("exp5_parallelism", exp5_parallelism.run),
        ("table2_cosim", table2_cosim.run),
    ]
    RESULTS.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        try:
            rows, derived, us = fn()
            print(f"{name},{us:.0f},{derived}")
            payload = rows if isinstance(rows, (list, dict)) else str(rows)
            (RESULTS / f"{name}.json").write_text(
                json.dumps({"rows": payload, "derived": derived,
                            "us_per_call": us}, indent=1, default=str))
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
