"""Table 2: Vidur-Vessim co-simulation case study.

Paper setup (Table 1b): Llama-2-7B, 400k requests at 20 QPS (Zipf 1-4k,
P:D 20), A100, CAISO-North CI, 600 W solar, 100 Wh battery (SoC 20-80%),
1-minute resolution. Headline paper numbers: 5.90 kWh total demand,
70.3% renewable share, 2.47 kgCO2 total, 69.2% offset by solar.

We simulate a reduced request count and tile the resulting diurnal-scale
load to 48 h (the paper's trace spans >24 h of wall time), against
synthetic Solcast/WattTime stand-ins (offline container; generators
documented in repro/core/datasets.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer
from repro.core import MicrogridConfig, PowerModel, run_cosim, Signal
from repro.core.cosim import stages_to_load_signal
from repro.core.datasets import carbon_intensity_signal, solar_signal
from repro.core.microgrid import BatteryConfig
from repro.sim import INTEGRATION_DEFAULT, run_simulation
import dataclasses


def run(n_requests: int = 110_000, hours: float = 30.0, qps: float = 5.5):
    """Paper deviation (documented in EXPERIMENTS.md §Repro): the stated
    20 QPS on one A100 exceeds the device's peak FLOP/s by ~1.6x for this
    workload; Vidur's random forest extrapolated beyond its validity
    range ("accurate near 85% of max QPS"). We reproduce the co-sim at
    85% of OUR max QPS (5.5), preserving the 5.5 h saturated-burst shape
    and total energy of the paper's Table 2."""
    with Timer() as t:
        cfg = dataclasses.replace(
            INTEGRATION_DEFAULT,
            workload=dataclasses.replace(INTEGRATION_DEFAULT.workload,
                                         n_requests=n_requests, qps=qps))
        res = run_simulation(cfg)
        pm = PowerModel(cfg.device)
        load = stages_to_load_signal(res.stages.start_s, res.stages.dur_s,
                                     res.stages.mfu, pm,
                                     n_devices=cfg.n_devices, pue=1.2,
                                     resolution_s=60.0)
        # place the active trace once (starting 9 am) with the idle-power
        # floor elsewhere — the paper's 5.9 kWh spans >24 h of wall time
        # around a ~5 h active burst
        n_bins = int(hours * 60)
        idle_w = pm.dev.p_idle * cfg.n_devices * 1.2
        vals = np.full(n_bins, idle_w)
        start_bin = int(8 * 60)  # 5.5h burst across daylight
        n_active = min(len(load.values), n_bins - start_bin)
        vals[start_bin:start_bin + n_active] = load.values[:n_active]
        times = np.arange(n_bins) * 60.0
        load48 = Signal(times, vals, interp="previous")

        # CAISO June-July conditions (paper traces): low cloud cover
        solar = solar_signal(hours, capacity_w=600.0, seed=3,
                             cloudiness=0.12)
        ci = carbon_intensity_signal(hours, seed=4)
        grid_cfg = MicrogridConfig(battery=BatteryConfig(
            capacity_wh=100.0, soc_init=0.5, soc_min=0.2, soc_max=0.8))
        out = run_cosim(load48, solar, ci, grid_cfg)
    m = out.metrics
    derived = (f"renewable_share={m['renewable_share_pct']:.1f}%"
               f"(paper:70.3);offset={m['carbon_offset_pct']:.1f}%"
               f"(paper:69.2);E={m['total_energy_kwh']:.2f}kWh(paper:5.90);"
               f"net={m['net_emissions_kg']*1000:.0f}g(paper:759)")
    return m, derived, t.elapsed_us


if __name__ == "__main__":
    m, derived, _ = run()
    for k, v in m.items():
        print(f"{k:28s} {v:10.2f}")
    print(derived)
