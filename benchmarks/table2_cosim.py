"""Table 2: Vidur-Vessim co-simulation case study.

Paper setup (Table 1b): Llama-2-7B, 400k requests at 20 QPS (Zipf 1-4k,
P:D 20), A100, CAISO-North CI, 600 W solar, 100 Wh battery (SoC 20-80%),
1-minute resolution. Headline paper numbers: 5.90 kWh total demand,
70.3% renewable share, 2.47 kgCO2 total, 69.2% offset by solar.

We simulate a reduced request count and tile the resulting diurnal-scale
load to a 30 h window (the paper's trace spans >24 h of wall time),
against synthetic Solcast/WattTime stand-ins (offline container;
generators documented in repro/core/datasets.py). The paper-deviation
rationale (5.5 QPS = 85% of our max) is documented on the table2 grid
declaration in ``repro.sweep.scenarios``; the microgrid post-processing
lives in ``repro.sweep.runner`` ("microgrid_cosim").
"""
from __future__ import annotations

from benchmarks.common import bench_main, run_paper_sweep


def run(n_requests=None, smoke: bool = False):
    return run_paper_sweep("table2", smoke=smoke, n_requests=n_requests)


if __name__ == "__main__":
    bench_main("table2")
