"""Carbon-aware inference deployment study (paper Table 2 + Section 5
policy directions).

Runs the Vidur-Vessim co-simulation for a diurnal window, then compares
carbon-aware policies: threshold deferral, solar-following, and
multi-region routing. Finishes with a vmap'd battery x solar sweep
(beyond-paper: whole scenario grids in one compiled call).

    PYTHONPATH=src python examples/carbon_aware_sim.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BatteryConfig, MicrogridConfig, PowerModel,
                        Signal, run_cosim, simulate, stages_to_load_signal)
from repro.core.datasets import carbon_intensity_signal, solar_signal
from repro.core.policies import solar_following, threshold_deferral
from repro.sim import INTEGRATION_DEFAULT, run_simulation


def main():
    hours = 30.0
    print("simulating inference workload (llama2-7b, 20k requests)...")
    cfg = dataclasses.replace(
        INTEGRATION_DEFAULT,
        workload=dataclasses.replace(INTEGRATION_DEFAULT.workload,
                                     n_requests=20_000, qps=5.5))
    res = run_simulation(cfg)
    pm = PowerModel(cfg.device)
    load = stages_to_load_signal(res.stages.start_s, res.stages.dur_s,
                                 res.stages.mfu, pm, n_devices=cfg.n_devices,
                                 pue=1.2)
    n_bins = int(hours * 60)
    vals = np.full(n_bins, pm.dev.p_idle * 1.2)
    k = min(len(load.values), n_bins - 8 * 60)
    vals[8 * 60:8 * 60 + k] = load.values[:k]
    load = Signal(np.arange(n_bins) * 60.0, vals)

    solar = solar_signal(hours, capacity_w=600.0, seed=3, cloudiness=0.12)
    ci = carbon_intensity_signal(hours, seed=4)

    out = run_cosim(load, solar, ci)
    m = out.metrics
    print(f"baseline: {m['total_energy_kwh']:.2f} kWh, "
          f"renewable {m['renewable_share_pct']:.1f}%, "
          f"net {m['net_emissions_kg']*1000:.0f} gCO2")

    # --- policy: threshold deferral (SPROUT-style) ---
    ci_v = ci.at(load.times)
    deferred, stats = threshold_deferral(
        load.values, ci_v, ci_high=float(np.percentile(ci_v, 70)),
        ci_low=float(np.percentile(ci_v, 30)), deferrable_frac=0.5)
    out_d = run_cosim(Signal(load.times, deferred), solar, ci)
    print(f"deferral: net {out_d.metrics['net_emissions_kg']*1000:.0f} gCO2 "
          f"({stats['deferred_steps']} deferred steps)")

    # --- policy: solar following ---
    sol_v = solar.at(load.times)
    followed = solar_following(load.values, sol_v, min_frac=0.5)
    out_s = run_cosim(Signal(load.times, followed), solar, ci)
    print(f"solar-following: net "
          f"{out_s.metrics['net_emissions_kg']*1000:.0f} gCO2, renewable "
          f"{out_s.metrics['renewable_share_pct']:.1f}%")

    # --- beyond-paper: vmap'd scenario sweep (battery x solar scale) ---
    print("\nvmapped sweep: net gCO2 by (battery Wh x solar scale)")
    lw = jnp.asarray(load.values)
    ci_j = jnp.asarray(ci_v)
    sol_j = jnp.asarray(sol_v)

    def scenario(cap_wh, solar_scale):
        cfgm = MicrogridConfig(battery=BatteryConfig(capacity_wh=1.0))
        b = cfgm.battery
        # capacity enters through scaled signals (static pytree config)
        tr = simulate(lw / jnp.maximum(cap_wh, 1e-3), sol_j * solar_scale
                      / jnp.maximum(cap_wh, 1e-3), ci_j, cfgm)
        return jnp.sum(tr["emissions_g"]) * cap_wh

    caps = jnp.asarray([50.0, 100.0, 500.0, 2000.0])
    scales = jnp.asarray([0.5, 1.0, 2.0])
    grid = jax.vmap(lambda c: jax.vmap(lambda s: scenario(c, s))(scales))(caps)
    for i, c in enumerate(caps):
        row = " ".join(f"{float(grid[i, j]):8.0f}" for j in range(len(scales)))
        print(f"  battery {float(c):6.0f} Wh: {row}")


if __name__ == "__main__":
    main()
