"""Quickstart: estimate the energy and carbon cost of an LLM serving
workload in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.core import PowerModel, emissions
from repro.core.power import DEVICES
from repro.sim import PAPER_DEFAULT, energy_report, run_simulation

# 1. Configure: Meta-Llama-3-8B on one A100, paper Table 1a defaults
cfg = dataclasses.replace(
    PAPER_DEFAULT,
    workload=dataclasses.replace(PAPER_DEFAULT.workload, n_requests=512))

# 2. Simulate the serving cluster (continuous batching, Poisson arrivals)
result = run_simulation(cfg)
print(f"served {len(result.requests)} requests in "
      f"{result.stages.total_duration():.0f} s "
      f"({result.throughput_qps():.2f} QPS, avg MFU {result.avg_mfu():.2f})")
lat = result.latency_stats()
print(f"TTFT p50 {lat['ttft_p50_s']:.2f} s   e2e p50 {lat['e2e_p50_s']:.2f} s")

# 3. Energy (paper Eqs. 1-3): MFU -> power -> Wh, with datacenter PUE
rep = energy_report(result, pue=1.2)
print(f"avg power {rep.avg_power_w:.0f} W   energy {rep.energy_wh:.1f} Wh "
      f"({rep.gpu_hours:.2f} GPU-hours)")

# 4. Carbon (paper Eq. 4): grid intensity + embodied
carbon = emissions(rep.energy_wh, rep.gpu_hours, DEVICES["a100"], ci=400.0)
print(f"emissions: {carbon.operational_g:.1f} g operational + "
      f"{carbon.embodied_g:.1f} g embodied = {carbon.total_g:.1f} gCO2")

# 5. Same workload on a TPU v5e deployment (hardware adaptation).
#    8B bf16 weights exceed one v5e's 16 GB, so serve with TP=4.
tpu_cfg = dataclasses.replace(cfg, device="tpu-v5e", tp=4)
tpu_rep = energy_report(run_simulation(tpu_cfg), pue=1.1)
print(f"tpu-v5e x4 (TP=4): avg power {tpu_rep.avg_power_w:.0f} W/chip   "
      f"energy {tpu_rep.energy_wh:.1f} Wh")
