"""End-to-end serving driver: run the REAL JAX model behind the
continuous-batching engine, then push the measured iteration log through
the paper's energy/carbon pipeline.

    PYTHONPATH=src python examples/serve_demo.py [--arch stablelm-1.6b]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import PowerModel, emissions
from repro.core.power import DEVICES
from repro.core.signals import aggregate_power
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    # reduced config: the same family at laptop scale
    cfg = reduced_config(get_config(args.arch))
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f} M params, "
          f"family={cfg.family})")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServingEngine(model, params, max_slots=4, max_len=128)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(ServeRequest(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, rng.integers(4, 24)),
            max_new_tokens=args.new_tokens))
    done = engine.run()

    total_tokens = sum(len(r.generated) for r in done)
    print(f"completed {len(done)} requests, {total_tokens} tokens in "
          f"{engine.clock:.2f} s wall "
          f"({total_tokens / max(engine.clock, 1e-9):.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {list(r.generated)}")

    # energy accounting from the engine's measured iteration log
    starts = np.array([l.start_s for l in engine.logs])
    durs = np.array([l.dur_s for l in engine.logs])
    # MFU per iteration from achieved FLOPs (reduced model on CPU)
    flops = np.array([2.0 * cfg.param_count() * l.n_tokens
                      for l in engine.logs])
    dev = DEVICES["tpu-v5e"]
    mfu = np.clip(flops / (np.maximum(durs, 1e-9) * dev.peak_flops), 0, 1)
    pm = PowerModel(dev)
    p = np.asarray(pm.power(mfu))
    energy_wh = float(np.sum(p * durs)) / 3600.0
    carbon = emissions(energy_wh, engine.clock / 3600.0, dev, ci=400.0)
    print(f"modeled v5e energy for this trace: {energy_wh*1000:.2f} mWh, "
          f"{carbon.total_g:.4f} gCO2 (CI=400)")


if __name__ == "__main__":
    main()
