"""End-to-end training driver with fault tolerance: synthetic-data LM
training with checkpoint/restart, NaN rejection, and straggler watchdog.

Default is laptop-scale (CPU-friendly); --full trains a ~100M-param model
for a few hundred steps (slow on CPU, sized for a single accelerator).

    PYTHONPATH=src python examples/train_100m.py [--steps 60] [--full]
"""
import argparse
import tempfile

import jax

from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig
from repro.models import build_model
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import (FaultToleranceConfig,
                                         FaultTolerantRunner)
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def model_cfg(full: bool) -> ModelConfig:
    if full:  # ~100M params
        return ModelConfig(
            name="lm-100m", family="dense", n_layers=12, d_model=768,
            vocab_size=32_000,
            attention=AttentionConfig(n_heads=12, n_kv_heads=12, head_dim=64),
            mlp=MLPConfig(d_ff=2048), tie_embeddings=True, max_seq_len=1024)
    return ModelConfig(
        name="lm-micro", family="dense", n_layers=4, d_model=128,
        vocab_size=1024,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        mlp=MLPConfig(d_ff=384), tie_embeddings=True, max_seq_len=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_cfg(args.full)
    model = build_model(cfg, remat=args.full)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f} M params")
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-4 if args.full else 2e-3, warmup_steps=20,
                          total_steps=max(args.steps, 100))
    step = jax.jit(make_train_step(model, opt_cfg))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                global_batch=args.batch, seed=0))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    runner = FaultTolerantRunner(step, FaultToleranceConfig(
        ckpt_dir=ckpt_dir, ckpt_every=20))
    params, opt, start = runner.try_restore(params, adamw_init(params))
    if start:
        print(f"resumed from checkpoint at step {start}")
    out = runner.run(params, opt, ds.batch, n_steps=args.steps,
                     start_step=start)
    print(f"finished at step {out['final_step']}: loss "
          f"{out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"({out['straggler_events']} straggler events); "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
