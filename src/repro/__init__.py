"""repro: energy- and carbon-aware LLM inference/training framework (JAX).

Reproduction and extension of "Quantifying the Energy Consumption and
Carbon Emissions of LLM Inference via Simulations" (Özcan et al., 2025).
"""
__version__ = "1.0.0"
