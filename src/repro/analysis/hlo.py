"""Post-SPMD HLO analysis: collective byte accounting + roofline terms.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not
collective traffic; we parse the optimized HLO text and sum the *result*
sizes of every collective op.

Loop awareness: the layer scan compiles to a ``while`` whose body appears
once in the text but executes n_layers times. We build the computation
graph (entry -> while bodies, recursively), extract trip counts from the
loop-condition constants, and multiply each body's collective bytes by
its trip count — so a per-layer all-reduce is charged L times.

Byte convention: for each collective we record result bytes, and the
roofline converts to link traffic with the standard per-algorithm factors
(ring all-reduce 2x, all-gather/reduce-scatter 1x, etc.).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%?([\w.\-]+)")
_CALLS_ATTR_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(
    r"\bconditional\(.*?(?:branch_computations=\{([^}]*)\}|"
    r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+))")


def _control_edges(line):
    """Returns list of ("while", cond, body) / ("call", comp) /
    ("cond", [branches]) edges found on an HLO line."""
    out = []
    wm = _WHILE_RE.search(line)
    if wm:
        out.append(("while", wm.group(1), wm.group(2)))
    cm = _CALL_RE.search(line)
    if cm:
        out.append(("call", cm.group(1)))
    dm = _COND_RE.search(line)
    if dm:
        if dm.group(1):
            branches = [b.strip().lstrip("%") for b in dm.group(1).split(",")]
        else:
            branches = [dm.group(2), dm.group(3)]
        out.append(("cond", branches))
    return out


def _shape_bytes_in(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if (line and not line.startswith(" ")
                and not line.startswith("HloModule")
                and line.rstrip().endswith("{") and "->" in line):
            header = line.strip()
            if header.startswith("ENTRY "):
                header = header[len("ENTRY "):]
            cur = header.split("(")[0].strip().lstrip("%")
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _collective_result_bytes(line: str) -> Tuple[str, int]:
    """Returns (kind, result_bytes) or ("", 0)."""
    if "=" not in line:
        return "", 0
    lhs, rhs = line.split("=", 1)
    rhs_stripped = rhs.lstrip()
    for kind in COLLECTIVES:
        # result shapes precede the op name on the RHS
        idx = rhs_stripped.find(f" {kind}(")
        start_idx = rhs_stripped.find(f" {kind}-start(")
        if idx < 0 and start_idx < 0:
            continue
        if "-done(" in rhs_stripped:
            return "", 0  # async done op: shapes already counted at -start
        pos = idx if idx >= 0 else start_idx
        result_part = rhs_stripped[:pos]
        return kind, _shape_bytes_in(result_part)
    return "", 0


def collective_bytes(hlo_text: str, default_trip: int = 1) -> Dict[str, float]:
    """Loop-aware collective byte totals per kind."""
    comps = _split_computations(hlo_text)

    # per-computation raw tallies + while edges
    raw: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, List[Tuple[str, str]]] = {}  # comp -> [(cond, body)]
    for name, lines in comps.items():
        tally = {k: 0.0 for k in COLLECTIVES}
        tally["count"] = 0
        e = []
        for line in lines:
            kind, nbytes = _collective_result_bytes(line)
            if kind:
                tally[kind] += nbytes
                tally["count"] += 1
            e.extend(_control_edges(line))
        raw[name] = tally
        edges[name] = e

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = [int(c) for l in lines for c in _CONST_RE.findall(l)]
        big = [c for c in consts if 1 < c < 1_000_000]
        return max(big) if big else default_trip

    # entry computation: the one not referenced as any cond/body and with
    # the most lines (XLA names it main.* / ENTRY)
    referenced = set()
    for es in edges.values():
        for ed in es:
            if ed[0] == "while":
                referenced.update((ed[1], ed[2]))
            elif ed[0] == "call":
                referenced.add(ed[1])
            else:
                referenced.update(ed[1])
    entry_candidates = [n for n in comps if n not in referenced
                        and ("main" in n or "ENTRY" in n)]
    entry = entry_candidates[0] if entry_candidates else max(
        comps, key=lambda n: len(comps[n]))

    total = {k: 0.0 for k in COLLECTIVES}
    total["count"] = 0

    def accumulate(comp: str, mult: float):
        if comp not in raw:
            return
        for k in COLLECTIVES:
            total[k] += raw[comp][k] * mult
        total["count"] += raw[comp]["count"] * mult
        for ed in edges.get(comp, []):
            if ed[0] == "while":
                accumulate(ed[2], mult * trip_count(ed[1]))
            elif ed[0] == "call":
                accumulate(ed[1], mult)
            else:  # conditional: charge the average branch (approximation)
                for b in ed[1]:
                    accumulate(b, mult / max(len(ed[1]), 1))

    accumulate(entry, 1.0)
    total["total"] = sum(total[k] for k in COLLECTIVES)
    # link-traffic estimate with per-algorithm factors (ring collectives)
    total["link_bytes"] = (2.0 * total["all-reduce"] + total["all-gather"]
                          + total["reduce-scatter"] + total["all-to-all"]
                          + total["collective-permute"])
    return total


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Loop-aware FLOPs and HBM-traffic accounting
#
# cost_analysis() counts while-loop bodies ONCE; with scan-over-layers and
# gradient accumulation that understates FLOPs by ~L x ga. We re-derive
# dot FLOPs and a HBM-traffic proxy per computation and scale by loop trip
# counts (same machinery as collective_bytes).
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_DOT_RE = re.compile(
    r"dot\(\s*%?([\w.\-]+),\s*%?([\w.\-]+)\s*\)(.*)$")
_DIMS_ATTR_RE = re.compile(r"(\w+)=\{([0-9,]*)\}")
_RESULT_SHAPE_RE = re.compile(
    r"^(?:\()?(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_SKIP_OPS = ("parameter(", "constant(", "bitcast(", "tuple(",
             "get-tuple-element(", "while(", "conditional(", "call(",
             "after-all(", "partition-id(", "replica-id(")

# excluded from the HBM-traffic proxy: converts/copies are predominantly
# XLA-CPU float-normalization artifacts (bf16 upcasts) that do not exist
# in a native-bf16 TPU executable
_SKIP_BYTES_OPS = _SKIP_OPS + ("convert(", "copy(", "copy-start(",
                               "copy-done(", "wrapped_convert")


def _operand_names(rhs: str):
    """Names inside the op's first (...) argument list."""
    try:
        start = rhs.index("(")
    except ValueError:
        return []
    depth = 0
    end = start
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rhs[start:end])


def _parse_shape_dims(dims: str):
    return [int(d) for d in dims.split(",") if d] if dims else []


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, None
    return m.group(1), _parse_shape_dims(m.group(2))


def _dus_fusion_update_bytes(comps) -> Dict[str, float]:
    """Fused computations whose ROOT is dynamic-update-slice: in-place on
    TPU, so traffic is only the update slice. Returns comp -> update bytes."""
    out = {}
    for name, lines in comps.items():
        shapes = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            var, rhs = dm.group(1), dm.group(2)
            dt, dims = _first_shape(rhs)
            if dt is not None:
                shapes[var] = (dt, dims)
            if "dynamic-update-slice(" in rhs and " fusion(" not in rhs:
                # a DUS anywhere in a fused computation makes the fusion
                # in-place on TPU (the surrounding converts are CPU-only
                # bf16-normalization artifacts)
                ops = _operand_names(rhs)
                upd = shapes.get(ops[1]) if len(ops) > 1 else None
                if upd is not None:
                    n = 1
                    for d in upd[1]:
                        n *= d
                    out[name] = max(out.get(name, 0.0),
                                    n * _DTYPE_BYTES[upd[0]])
                else:
                    out.setdefault(name, 0.0)
    return out


def program_stats(hlo_text: str, default_trip: int = 1) -> Dict[str, float]:
    """Loop-aware {dot_flops, hbm_bytes, dot_count} for the whole program."""
    comps = _split_computations(hlo_text)
    dus_fusions = _dus_fusion_update_bytes(comps)

    # symbol tables + per-comp raw stats + while edges
    comp_stats: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, List[Tuple[str, str]]] = {}
    for name, lines in comps.items():
        shapes: Dict[str, Tuple[str, List[int]]] = {}
        pending = []  # (lhs_name, rhs_name, attrs, result_numel)
        flops = 0.0
        bytes_rw = 0.0
        ndots = 0
        e = []
        op_lines = []
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            var, rhs = dm.group(1), dm.group(2)
            dt, dims = _first_shape(rhs)
            if dt is not None:
                shapes[var] = (dt, dims)
            ces = _control_edges(line)
            if ces:
                e.extend(ces)
                continue
            if "parameter(" not in rhs and any(op in rhs for op in _SKIP_OPS):
                continue
            op_lines.append((rhs, dt, dims))
            dmt = _DOT_RE.search(rhs)
            if dmt:
                pending.append((dmt.group(1), dmt.group(3), dt, dims))
                ndots += 1

        def nbytes(dt, dims):
            if dt is None:
                return 0
            n = 1
            for d in dims:
                n *= d
            return n * _DTYPE_BYTES[dt]

        # HBM-traffic proxy: every unique materialized value is written
        # once and read ~once (2x result bytes); computation parameters are
        # read once; dynamic-update-slice moves only its update slice
        # (in-place on TPU). Convert/copy results are excluded as XLA-CPU
        # bf16-upcast artifacts.
        param_bytes = 0.0
        for rhs, dt, dims in op_lines:
            if "parameter(" in rhs:
                param_bytes += nbytes(dt, dims)
                continue
            if any(op in rhs for op in _SKIP_BYTES_OPS):
                continue
            if "dynamic-update-slice(" in rhs:
                ops = _operand_names(rhs)
                upd = shapes.get(ops[1]) if len(ops) > 1 else None
                bytes_rw += 2 * (nbytes(*upd) if upd else 0)
                continue
            if " fusion(" in rhs:
                cm = _CALLS_ATTR_RE.search(rhs)
                if cm and cm.group(1) in dus_fusions:
                    bytes_rw += 2 * dus_fusions[cm.group(1)]
                    continue
            bytes_rw += 2 * nbytes(dt, dims)
        for lhs_name, attrs, rdt, rdims in pending:
            lhs = shapes.get(lhs_name)
            if lhs is None or rdt is None:
                continue
            contract = []
            for key, val in _DIMS_ATTR_RE.findall(attrs):
                if key == "lhs_contracting_dims":
                    contract = _parse_shape_dims(val)
            csize = 1
            for ci in contract:
                if ci < len(lhs[1]):
                    csize *= lhs[1][ci]
            rn = 1
            for d in rdims:
                rn *= d
            flops += 2.0 * rn * csize
        comp_stats[name] = {"dot_flops": flops, "hbm_bytes": bytes_rw,
                            "param_bytes": param_bytes,
                            "dot_count": float(ndots)}
        edges[name] = e

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = [int(c) for l in lines for c in _CONST_RE.findall(l)]
        big = [c for c in consts if 1 < c < 1_000_000]
        return max(big) if big else default_trip

    referenced = set()
    for es in edges.values():
        for ed in es:
            if ed[0] == "while":
                referenced.update((ed[1], ed[2]))
            elif ed[0] == "call":
                referenced.add(ed[1])
            else:
                referenced.update(ed[1])
    entry_candidates = [n for n in comps if n not in referenced
                        and ("main" in n or "ENTRY" in n)]
    entry = entry_candidates[0] if entry_candidates else max(
        comps, key=lambda n: len(comps[n]))

    total = {"dot_flops": 0.0, "hbm_bytes": 0.0, "dot_count": 0.0}

    # while bodies/conds receive loop-carried state as parameters — not
    # fresh HBM reads (in-body dynamic-slices count the real traffic)
    loop_comps = set()
    for es in edges.values():
        for ed in es:
            if ed[0] == "while":
                loop_comps.update((ed[1], ed[2]))

    def accumulate(comp: str, mult: float):
        if comp not in comp_stats:
            return
        for k in total:
            total[k] += comp_stats[comp][k] * mult
        if comp not in loop_comps:
            total["hbm_bytes"] += comp_stats[comp]["param_bytes"] * mult
        for ed in edges.get(comp, []):
            if ed[0] == "while":
                accumulate(ed[2], mult * trip_count(ed[1]))
            elif ed[0] == "call":
                accumulate(ed[1], mult)
            else:
                for b in ed[1]:
                    accumulate(b, mult / max(len(ed[1]), 1))

    accumulate(entry, 1.0)
    return total
