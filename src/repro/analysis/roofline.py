"""Roofline analysis over dry-run records (deliverable g).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = link_bytes_per_device / link_bw

(The post-SPMD HLO is a per-device program, so per-device quantities
divided by per-chip rates equal the global-quantity/(chips x rate) form.)

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference) with N_active for MoE
plus context-dependent attention-score FLOPs; the MODEL/HLO ratio flags
remat and dispatch overheads.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.core.power import TPU_V5E

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

PEAK = TPU_V5E.peak_flops          # 197e12
HBM_BW = TPU_V5E.hbm_bw            # 819e9
LINK_BW = TPU_V5E.link_bw          # 50e9
HBM_CAP = TPU_V5E.hbm_bytes        # 16e9


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_tok = 3.0 * cfg.flops_per_token_total(shape.seq_len // 2)
        _ = 6.0 * n_act * tokens  # classic 6ND (proj-only) for reference
        return per_tok * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return cfg.flops_per_token_total(shape.seq_len // 2) * tokens / n_devices
    # decode: one token per sequence against a seq_len cache
    tokens = shape.global_batch
    return cfg.flops_per_token_total(shape.seq_len) * tokens / n_devices


def ideal_bytes_per_device(arch: str, shape_name: str, chips: int) -> float:
    """Algorithmic HBM-traffic floor per device: weight shard read once
    per pass, KV cache read/written once, one residual-stream activation
    round-trip per layer."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p_bytes = cfg.param_count() * 2            # bf16 weights
    n_act = cfg.active_param_count() * 2
    if shape.kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / max(chips / 16, 1)
        # fwd + bwd weight reads (fp32 master + moments) + grad write
        w = (cfg.param_count() * (4 * 3 + 8 * 2)) / chips
        acts = tokens_dev * cfg.d_model * 2 * cfg.n_layers * 2
        return w + acts
    if shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / max(chips / 16, 1)
        w = n_act / 16                          # TP shard read once
        kv = tokens_dev * cfg.kv_bytes_per_token()
        acts = tokens_dev * cfg.d_model * 2 * cfg.n_layers * 2
        return w + kv + acts
    # decode
    w = n_act / 16
    a = cfg.attention
    ctx = shape.seq_len
    if a is not None and a.sliding_window:
        ctx = min(ctx, a.sliding_window)
    kv_dev = (shape.global_batch * ctx * cfg.kv_bytes_per_token()
              / max(chips / 16, 1))
    return w + kv_dev


def cpu_fp32_artifact_bytes(hlo_path: Path) -> float:
    """Estimate CPU float-normalization doubling: f32 buffers that have an
    identically-shaped bf16 twin (XLA CPU upcasts bf16 compute)."""
    if not hlo_path.exists():
        return 0.0
    text = hlo_path.read_text()
    f32 = set(re.findall(r"f32\[([0-9,]+)\]", text))
    bf16 = set(re.findall(r"bf16\[([0-9,]+)\]", text))
    dup = 0
    for dims in f32 & bf16:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 > 50e6:  # only large buffers matter
            dup += n * 4
    return float(dup)


def analyze_cell(rec: Dict, hlo_path: Optional[Path] = None) -> Dict:
    la = rec["loop_aware"]
    coll = rec["collectives"]
    mem = rec["memory"]
    n_dev = rec["n_devices"]
    # the mesh uses 256 (single pod) or 512 (multi pod) of the forced 512
    chips = 512 if rec["mesh"] == "2x16x16" else 256

    t_comp = la["dot_flops"] / PEAK
    t_mem = la["hbm_bytes"] / HBM_BW
    t_coll = coll["link_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    ib = ideal_bytes_per_device(rec["arch"], rec["shape"], chips)
    # the achievable floor is itself a roofline: max(compute, memory) ideal
    t_ideal = max(mf / PEAK, ib / HBM_BW, 1e-12)
    t_bound = max(t_comp, t_mem, t_coll)
    artifact = cpu_fp32_artifact_bytes(hlo_path) if hlo_path else 0.0
    temp = mem.get("temp_bytes") or 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": la["dot_flops"],
        "ideal_bytes_per_dev": ib,
        "hlo_bytes_per_dev": la["hbm_bytes"],
        "useful_ratio": mf / max(la["dot_flops"], 1e-9),
        "t_ideal_s": t_ideal,
        "roofline_fraction": t_ideal / max(t_bound, 1e-12),
        "temp_bytes": temp,
        "temp_bytes_tpu_est": max(temp - artifact, 0),
        "argument_bytes": mem.get("argument_bytes") or 0,
        "fits_hbm": (max(temp - artifact, 0)
                     + (mem.get("argument_bytes") or 0)) < HBM_CAP * 1.05,
    }


def load_all(mesh: str = "16x16", reparse: bool = True) -> List[Dict]:
    out = []
    for p in sorted((RESULTS / mesh).glob("*.json")):
        rec = json.loads(p.read_text())
        if not rec.get("runnable", False) or "loop_aware" not in rec:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh", mesh), "skipped": True,
                        "reason": rec.get("reason", rec.get("error", ""))[:90]})
            continue
        hlo_path = p.with_suffix(".hlo.txt")
        if reparse and hlo_path.exists():
            # recompute with the current parser (JSONs may be stale)
            from repro.analysis.hlo import collective_bytes, program_stats
            text = hlo_path.read_text()
            trip = get_config(rec["arch"]).n_layers
            rec["loop_aware"] = program_stats(text, default_trip=trip)
            rec["collectives"] = collective_bytes(text, default_trip=trip)
        out.append(analyze_cell(rec, hlo_path))
    return out


def markdown_table(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "dominant | MODEL/HLO | roofline frac | fits 16G |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for c in cells:
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped: {c['reason'][:40]} | — | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']*1e3:.2f} | "
            f"{c['t_memory_s']*1e3:.2f} | {c['t_collective_s']*1e3:.2f} | "
            f"{c['dominant']} | {c['useful_ratio']:.2f} | "
            f"{c['roofline_fraction']:.3f} | "
            f"{'yes' if c['fits_hbm'] else 'NO'} |")
    return "\n".join(rows)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    cells = load_all(args.mesh)
    if args.json:
        print(json.dumps(cells, indent=1))
    else:
        print(markdown_table(cells))


if __name__ == "__main__":
    main()
