"""Config registry: ``get_config(arch_id)`` and the assigned-arch list."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    AttentionConfig,
    MLPConfig,
    MoEConfig,
    ModelConfig,
    RWKVConfig,
    SHAPES,
    SSMConfig,
    ShapeConfig,
    ZambaConfig,
    cell_is_runnable,
)

from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.paper_models import PAPER_MODELS

# The 10 assigned architectures (``--arch <id>``).
ASSIGNED: Dict[str, ModelConfig] = {
    "smollm-360m": _smollm,
    "stablelm-1.6b": _stablelm,
    "h2o-danube-1.8b": _danube,
    "mistral-nemo-12b": _nemo,
    "mixtral-8x22b": _mixtral,
    "qwen3-moe-30b-a3b": _qwen3moe,
    "qwen2-vl-2b": _qwen2vl,
    "rwkv6-1.6b": _rwkv6,
    "zamba2-1.2b": _zamba2,
    "hubert-xlarge": _hubert,
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> List[tuple]:
    """All 40 (arch, shape) cells with runnability verdicts."""
    cells = []
    for arch, cfg in ASSIGNED.items():
        for sname, shape in SHAPES.items():
            ok, reason = cell_is_runnable(cfg, shape)
            cells.append((arch, sname, ok, reason))
    return cells


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    import dataclasses
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        max_seq_len=128,
    )
    if cfg.attention is not None:
        a = cfg.attention
        n_heads = 4 if cfg.name != "smollm-360m" else 3  # keep the odd-head family trait
        n_kv = max(1, n_heads * a.n_kv_heads // a.n_heads)
        kw["attention"] = dataclasses.replace(
            a, n_heads=n_heads, n_kv_heads=n_kv, head_dim=16,
            sliding_window=32 if a.sliding_window else None,
        )
    if cfg.mlp is not None:
        kw["mlp"] = dataclasses.replace(cfg.mlp, d_ff=128)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k), d_expert=64
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=16, decay_lora=8, mix_lora=8, gate_lora=8
        )
    if cfg.zamba is not None:
        kw["zamba"] = dataclasses.replace(cfg.zamba, shared_attn_every=1)
    return cfg.replace(**kw)


__all__ = [
    "ASSIGNED", "REGISTRY", "SHAPES", "PAPER_MODELS",
    "get_config", "get_shape", "all_cells", "reduced_config",
    "ModelConfig", "ShapeConfig", "AttentionConfig", "MLPConfig",
    "MoEConfig", "SSMConfig", "RWKVConfig", "ZambaConfig", "cell_is_runnable",
]
