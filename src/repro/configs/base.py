"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the model
zoo (``repro.models``) builds parameter trees and step functions from it.
Configs are plain frozen dataclasses so they can be hashed into jit static
arguments and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    # Sliding-window attention: None => full attention.
    sliding_window: Optional[int] = None
    # Rotary embedding config. "mrope" = multimodal rope (Qwen2-VL).
    rope: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 10_000.0
    # Fraction of head_dim that is rotated (stablelm uses partial rotary).
    rope_pct: float = 1.0
    causal: bool = True
    qkv_bias: bool = False
    # KV-head replication factor for TP (MaxText-style): set by the
    # launcher when n_kv_heads < TP degree. Caches store replicated heads.
    kv_repeat: int = 1

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def n_kv_eff(self) -> int:
        """KV heads after TP replication (what caches actually store)."""
        return self.n_kv_heads * self.kv_repeat


@dataclass(frozen=True)
class MLPConfig:
    d_ff: int
    activation: str = "silu"  # "silu" (gated) | "gelu" (plain, hubert)
    gated: bool = True


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                   # per-expert hidden dim
    router_jitter: float = 0.0
    # load-balancing aux loss coefficient (train only)
    aux_loss_coef: float = 0.01
    n_shared_experts: int = 0       # qwen-style shared expert (unused here)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block (zamba2)."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" time-mix config."""
    head_dim: int = 64
    decay_lora: int = 64      # low-rank dim for data-dependent decay w_t
    mix_lora: int = 32        # low-rank dim for token-shift mixers
    gate_lora: int = 64


@dataclass(frozen=True)
class ZambaConfig:
    """Zamba2 hybrid layout: mamba2 backbone + shared attention block."""
    shared_attn_every: int = 6     # apply shared block every N backbone layers
    shared_attn_copies: int = 2    # zamba2 alternates between 2 shared blocks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    mlp: Optional[MLPConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    zamba: Optional[ZambaConfig] = None
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    # encoder-only models (hubert) have no causal decode path
    is_encoder_only: bool = False
    # modality frontend stub: inputs arrive as precomputed embeddings
    embed_stub: bool = False       # True for [audio]/[vlm] frontends
    dtype: str = "bfloat16"

    # ---------------- parameter counting ----------------
    def attn_params(self) -> int:
        a = self.attention
        if a is None:
            return 0
        return self.d_model * (a.q_dim + 2 * a.kv_dim) + a.q_dim * self.d_model

    def mlp_params(self) -> int:
        if self.mlp is None:
            return 0
        m = 3 if self.mlp.gated else 2
        return m * self.d_model * self.mlp.d_ff

    def moe_params(self) -> int:
        if self.moe is None:
            return 0
        per_expert = 3 * self.d_model * self.moe.d_expert
        return self.moe.n_experts * per_expert + self.d_model * self.moe.n_experts

    def moe_active_params(self) -> int:
        if self.moe is None:
            return 0
        per_expert = 3 * self.d_model * self.moe.d_expert
        return self.moe.top_k * per_expert + self.d_model * self.moe.n_experts

    def rwkv_params(self) -> int:
        if self.rwkv is None:
            return 0
        d, r = self.d_model, self.rwkv
        # time-mix: receptance, key, value, gate, output = 5 full matrices
        time_mix = 5 * d * d
        # token-shift mixers (5x) + data-dependent decay, all low-rank
        lora = 5 * (d * r.mix_lora + r.mix_lora * d) + (d * r.decay_lora + r.decay_lora * d)
        # channel-mix: key (d->ff), value (ff->d), receptance (d->d)
        channel_mix = 2 * d * (self.mlp.d_ff if self.mlp else 4 * d) + d * d
        return time_mix + lora + channel_mix

    def ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        d_in = self.ssm.d_inner(self.d_model)
        n_h = self.ssm.n_heads(self.d_model)
        in_proj = self.d_model * (2 * d_in + 2 * self.ssm.n_groups * self.ssm.d_state + n_h)
        conv = self.ssm.d_conv * (d_in + 2 * self.ssm.n_groups * self.ssm.d_state)
        out_proj = d_in * self.d_model
        return in_proj + conv + out_proj + 2 * n_h

    def param_count(self) -> int:
        """Approximate total parameter count N (embeddings included)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            per_layer = self.rwkv_params()
        elif self.family == "hybrid":  # zamba2: mamba backbone, shared attn+MLP
            n_shared = self.zamba.shared_attn_copies if self.zamba else 1
            backbone = self.ssm_params()
            shared = n_shared * (self.attn_params() + self.mlp_params())
            return emb + self.n_layers * backbone + shared + d
        elif self.family == "moe":
            per_layer = self.attn_params() + self.moe_params()
        else:
            per_layer = self.attn_params() + self.mlp_params()
        return emb + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Active params per token (= N for dense, N_active for MoE)."""
        if self.family == "moe":
            d = self.d_model
            emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
            per_layer = self.attn_params() + self.moe_active_params()
            return emb + self.n_layers * per_layer + d
        if self.family == "hybrid":
            return self.param_count()
        return self.param_count()

    # ---------------- FLOPs accounting (paper Eq. 2 terms) -------------
    # All totals are forward FLOPs per token across ALL layers (2 * MACs).
    def n_attn_applications(self) -> int:
        """How many attention blocks a token passes through."""
        if self.attention is None:
            return 0
        if self.family == "hybrid" and self.zamba is not None:
            return self.n_layers // self.zamba.shared_attn_every
        return self.n_layers

    def flops_per_token_mlp_total(self) -> float:
        """Total MLP/MoE/channel-mix + LM-head FLOPs per token (Eq. 2 FLOPs_MLP)."""
        d = self.d_model
        head = 2.0 * d * self.vocab_size
        if self.family == "moe":
            return self.n_layers * 2.0 * self.moe_active_params() + head
        if self.family == "ssm":
            ff = self.mlp.d_ff if self.mlp else 4 * d
            return self.n_layers * 2.0 * (2 * d * ff + d * d) + head
        if self.family == "hybrid":
            return self.n_attn_applications() * 2.0 * self.mlp_params() + head
        return self.n_layers * 2.0 * self.mlp_params() + head

    def flops_per_token_attn_proj_total(self) -> float:
        """Total attention/SSM projection FLOPs per token (context-free part)."""
        if self.family == "ssm":
            ff = self.mlp.d_ff if self.mlp else 4 * self.d_model
            chan = 2 * self.d_model * ff + self.d_model * self.d_model
            return self.n_layers * 2.0 * (self.rwkv_params() - chan)
        if self.family == "hybrid":
            return (self.n_layers * 2.0 * self.ssm_params()
                    + self.n_attn_applications() * 2.0 * self.attn_params())
        return self.n_layers * 2.0 * self.attn_params()

    def flops_attn_score_per_token(self, context_len: int) -> float:
        """Total score+value attention FLOPs per token given context length
        (Eq. 2 FLOPs_Attention context-dependent part)."""
        score = 0.0
        a = self.attention
        if a is not None:
            ctx = context_len
            if a.sliding_window is not None:
                ctx = min(ctx, a.sliding_window)
            score += self.n_attn_applications() * 4.0 * a.n_heads * a.head_dim * ctx
        if self.family == "ssm" and self.rwkv is not None:
            n_h = self.d_model // self.rwkv.head_dim
            score += self.n_layers * 4.0 * n_h * self.rwkv.head_dim * self.rwkv.head_dim
        if self.ssm is not None:
            n_h = self.ssm.n_heads(self.d_model)
            score += self.n_layers * 4.0 * n_h * self.ssm.head_dim * self.ssm.d_state
        return score

    def flops_per_token_total(self, context_len: int) -> float:
        return (self.flops_per_token_mlp_total()
                + self.flops_per_token_attn_proj_total()
                + self.flops_attn_score_per_token(context_len))

    # ---------------- derived helpers ----------------
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        a = self.attention
        if a is None:
            return 0
        n_layers_attn = self.n_layers
        if self.family == "hybrid" and self.zamba is not None:
            n_layers_attn = max(1, self.n_layers // self.zamba.shared_attn_every)
        return 2 * a.n_kv_heads * a.head_dim * n_layers_attn * dtype_bytes

    def supports_decode(self) -> bool:
        return not self.is_encoder_only

    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM/hybrid/linear/SWA)"""
        if self.family in ("ssm", "hybrid"):
            return True
        a = self.attention
        return a is not None and a.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len x global_batch).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The (arch x shape) applicability matrix. Returns (runnable, reason)."""
    if shape.kind == "decode" and not cfg.supports_decode():
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic():
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
