"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000
[arXiv:2401.16818; hf]

SWA (window 4096) makes this arch sub-quadratic: long_500k decode runs
with a window-bounded KV cache.
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2_560,
    vocab_size=32_000,
    attention=AttentionConfig(
        n_heads=32, n_kv_heads=8, head_dim=80, sliding_window=4_096
    ),
    mlp=MLPConfig(d_ff=6_912, activation="silu", gated=True),
    norm="rmsnorm",
    max_seq_len=16_384,
)
