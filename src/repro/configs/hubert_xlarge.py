"""hubert-xlarge [audio] — encoder-only, wav2vec2-style transformer.

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

Encoder-only: bidirectional attention, no KV-cache decode -> decode_32k
and long_500k shapes are skipped. The CNN waveform frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings
(batch, frames, d_model); vocab_size=504 is the masked-unit prediction
codebook.
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1_280,
    vocab_size=504,
    attention=AttentionConfig(
        n_heads=16, n_kv_heads=16, head_dim=80, causal=False, rope="none",
        qkv_bias=True,
    ),
    mlp=MLPConfig(d_ff=5_120, activation="gelu", gated=False),
    norm="layernorm",
    is_encoder_only=True,
    embed_stub=True,
    max_seq_len=65_536,
)
