"""mistral-nemo-12b [dense] — 128k context.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407; hf]

Full attention (no SWA) -> long_500k is skipped per the shape rules.
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5_120,
    vocab_size=131_072,
    attention=AttentionConfig(
        n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=1_000_000.0
    ),
    mlp=MLPConfig(d_ff=14_336, activation="silu", gated=True),
    norm="rmsnorm",
    max_seq_len=131_072,
)
