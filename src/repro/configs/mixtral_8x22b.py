"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2
[arXiv:2401.04088; hf]

SWA => sub-quadratic => long_500k runs. 8 experts do not divide the
16-way model axis -> experts are TP-sharded along d_expert instead of
expert-parallel on the production mesh.
"""
from repro.configs.base import AttentionConfig, MLPConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6_144,
    vocab_size=32_768,
    attention=AttentionConfig(
        n_heads=48, n_kv_heads=8, head_dim=128, sliding_window=4_096,
        rope_theta=1_000_000.0,
    ),
    mlp=MLPConfig(d_ff=16_384, activation="silu", gated=True),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16_384),
    norm="rmsnorm",
    max_seq_len=65_536,
)
