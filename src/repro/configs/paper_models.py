"""Model configs used in the paper's own experiments (Section 4).

These are used by the benchmark harness to reproduce the paper's
figures: Meta-Llama-3-8B (Table 1a default), Llama-2-7B-hf (Table 1b
co-simulation), plus the Exp. 1/5 sweep models (phi-2 2.7B,
CodeLlama-34B, Llama-3-70B, Qwen-72B).
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

LLAMA3_8B = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4_096,
    vocab_size=128_256,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    mlp=MLPConfig(d_ff=14_336),
    max_seq_len=8_192,
)

LLAMA2_7B = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4_096,
    vocab_size=32_000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=128),
    mlp=MLPConfig(d_ff=11_008),
    max_seq_len=4_096,
)

PHI2_2_7B = ModelConfig(
    name="phi2-2.7b",
    family="dense",
    n_layers=32,
    d_model=2_560,
    vocab_size=51_200,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                              rope_pct=0.4, qkv_bias=True),
    mlp=MLPConfig(d_ff=10_240, activation="gelu", gated=False),
    norm="layernorm",
    max_seq_len=2_048,
)

CODELLAMA_34B = ModelConfig(
    name="codellama-34b",
    family="dense",
    n_layers=48,
    d_model=8_192,
    vocab_size=32_000,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
    mlp=MLPConfig(d_ff=22_016),
    max_seq_len=16_384,
)

LLAMA3_70B = ModelConfig(
    name="llama3-70b",
    family="dense",
    n_layers=80,
    d_model=8_192,
    vocab_size=128_256,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    mlp=MLPConfig(d_ff=28_672),
    max_seq_len=8_192,
)

QWEN_72B = ModelConfig(
    name="qwen-72b",
    family="dense",
    n_layers=80,
    d_model=8_192,
    vocab_size=152_064,
    attention=AttentionConfig(n_heads=64, n_kv_heads=64, head_dim=128,
                              qkv_bias=True),
    mlp=MLPConfig(d_ff=24_576),
    max_seq_len=32_768,
)

PAPER_MODELS = {
    m.name: m
    for m in [LLAMA3_8B, LLAMA2_7B, PHI2_2_7B, CODELLAMA_34B, LLAMA3_70B, QWEN_72B]
}
