"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings; this config describes the
transformer backbone with multimodal rotary position embeddings.
heads=12 ∤ 16 -> head_dim-sharded attention fallback.
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1_536,
    vocab_size=151_936,
    attention=AttentionConfig(
        n_heads=12, n_kv_heads=2, head_dim=128, rope="mrope", qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    mlp=MLPConfig(d_ff=8_960, activation="silu", gated=True),
    norm="rmsnorm",
    embed_stub=True,
    tie_embeddings=True,
    max_seq_len=32_768,
)
