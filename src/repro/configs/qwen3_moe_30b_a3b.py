"""qwen3-moe-30b-a3b [moe] — 128 experts top-8.

48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B; hf]

d_ff=768 is the per-expert hidden dim (moe_intermediate_size). 128
experts divide every mesh axis -> full expert parallelism available.
Full attention -> long_500k skipped.
"""
from repro.configs.base import AttentionConfig, MLPConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2_048,
    vocab_size=151_936,
    attention=AttentionConfig(
        n_heads=32, n_kv_heads=4, head_dim=128, rope_theta=1_000_000.0
    ),
    mlp=MLPConfig(d_ff=768, activation="silu", gated=True),
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
    norm="rmsnorm",
    max_seq_len=32_768,
)
