"""rwkv6-1.6b [ssm] — "Finch", attention-free with data-dependent decay.

24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
[arXiv:2404.05892; unverified]

Linear recurrence (O(1) state per channel) -> long_500k runs. The
recurrence is computed with the ``gla_scan`` chunked Pallas kernel (TPU)
or its jnp reference (CPU/dry-run).
"""
from repro.configs.base import MLPConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2_048,
    vocab_size=65_536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
    mlp=MLPConfig(d_ff=7_168, activation="relu_sq", gated=False),
    norm="layernorm",
    max_seq_len=1_048_576,
)
