"""smollm-360m [dense] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-360M; hf]

Note: 15 heads / 5 kv heads are not divisible by TP=16 -> the sharding
layer falls back to head_dim-sharded attention for this arch.
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    vocab_size=49_152,
    attention=AttentionConfig(n_heads=15, n_kv_heads=5, head_dim=64),
    mlp=MLPConfig(d_ff=2_560, activation="silu", gated=True),
    norm="rmsnorm",
    tie_embeddings=True,
    max_seq_len=8_192,
)
