"""stablelm-1.6b [dense].

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b; unverified]

StableLM-2 uses LayerNorm and partial rotary embeddings (25%).
"""
from repro.configs.base import AttentionConfig, MLPConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2_048,
    vocab_size=100_352,
    attention=AttentionConfig(
        n_heads=32, n_kv_heads=32, head_dim=64, rope_pct=0.25, qkv_bias=True
    ),
    mlp=MLPConfig(d_ff=5_632, activation="silu", gated=True),
    norm="layernorm",
    max_seq_len=4_096,
)
