"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

Backbone layers are Mamba2 blocks (O(1) state); a shared
attention+MLP block (2 alternating copies) is applied every 6 backbone
layers. SSM => long_500k runs (shared-attn KV is the long-context cost).
"""
from repro.configs.base import (
    AttentionConfig, MLPConfig, ModelConfig, SSMConfig, ZambaConfig,
)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2_048,
    vocab_size=32_000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64),
    mlp=MLPConfig(d_ff=8_192, activation="gelu", gated=False),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    zamba=ZambaConfig(shared_attn_every=6, shared_attn_copies=2),
    norm="rmsnorm",
    max_seq_len=1_048_576,
)
