from repro.core.power import DEVICES, DeviceProfile, PowerModel, power
from repro.core.energy import (EnergyReport, operational_energy,
                               operational_energy_trace, stacked_energy_reports,
                               stage_mfu)
from repro.core.carbon import (CarbonReport, emissions, emissions_batch,
                               stage_attributed_carbon)
from repro.core.signals import Signal, aggregate_power
from repro.core.microgrid import BatteryConfig, MicrogridConfig, simulate, summarize
from repro.core.cosim import (CosimResult, run_cosim, stages_to_load_signal,
                              trace_to_load_signal)

__all__ = [
    "DEVICES", "DeviceProfile", "PowerModel", "power",
    "EnergyReport", "operational_energy", "operational_energy_trace",
    "stacked_energy_reports", "stage_mfu",
    "CarbonReport", "emissions", "emissions_batch", "stage_attributed_carbon",
    "Signal", "aggregate_power",
    "BatteryConfig", "MicrogridConfig", "simulate", "summarize",
    "CosimResult", "run_cosim", "stages_to_load_signal",
    "trace_to_load_signal",
]
