from repro.core.power import DEVICES, DeviceProfile, PowerModel, power
from repro.core.energy import EnergyReport, operational_energy, stage_mfu
from repro.core.carbon import CarbonReport, emissions
from repro.core.signals import Signal, aggregate_power
from repro.core.microgrid import BatteryConfig, MicrogridConfig, simulate, summarize
from repro.core.cosim import CosimResult, run_cosim, stages_to_load_signal

__all__ = [
    "DEVICES", "DeviceProfile", "PowerModel", "power",
    "EnergyReport", "operational_energy", "stage_mfu",
    "CarbonReport", "emissions",
    "Signal", "aggregate_power",
    "BatteryConfig", "MicrogridConfig", "simulate", "summarize",
    "CosimResult", "run_cosim", "stages_to_load_signal",
]
