"""Carbon accounting (paper Eq. 4).

    C = E_op * CI + H * phi_manuf

with static or time-varying grid carbon intensity CI (gCO2/kWh) and
per-GPU-hour embodied carbon phi_manuf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.power import DeviceProfile
from repro.core.signals import Signal


@dataclasses.dataclass
class CarbonReport:
    operational_g: float
    embodied_g: float
    total_g: float
    avg_ci: float


def emissions(energy_wh: float, gpu_hours: float, device: DeviceProfile,
              ci: Union[float, Signal],
              power_signal: Optional[Signal] = None) -> CarbonReport:
    """Eq. 4. With a time-varying CI signal, operational emissions are
    integrated against the power signal:  sum_t P(t) * CI(t) * dt."""
    if isinstance(ci, Signal):
        assert power_signal is not None, "time-varying CI needs a power signal"
        t = power_signal.times
        if len(t) >= 2:
            dt_h = float(np.median(np.diff(t))) / 3600.0
        else:
            dt_h = 1.0 / 60.0
        ci_t = ci.at(t)
        op_g = float(np.sum(power_signal.values * ci_t) * dt_h / 1000.0)
        avg_ci = float(np.mean(ci_t))
    else:
        op_g = energy_wh / 1000.0 * float(ci)
        avg_ci = float(ci)
    emb_g = gpu_hours * device.embodied_kg_per_hour * 1000.0
    return CarbonReport(operational_g=op_g, embodied_g=emb_g,
                        total_g=op_g + emb_g, avg_ci=avg_ci)
