"""Carbon accounting (paper Eq. 4).

    C = E_op * CI + H * phi_manuf

with static or time-varying grid carbon intensity CI (gCO2/kWh) and
per-GPU-hour embodied carbon phi_manuf.

``emissions_batch`` stacks Eq. 4 over aligned (energy, CI) axes in one
pass — the sweep engine's vectorized mode evaluates a whole grid-CI
axis against a shared trace through it. ``stage_attributed_carbon``
consumes a ``StageTrace`` directly: per-stage Eq. 2-3 energy weighted
by the live CI each stage ran under (no idle fill), the request-
attributable quantity temporal/spatial scheduling moves.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.power import DeviceProfile, PowerModel
from repro.core.signals import Signal


@dataclasses.dataclass
class CarbonReport:
    operational_g: float
    embodied_g: float
    total_g: float
    avg_ci: float


def emissions(energy_wh: float, gpu_hours: float, device: DeviceProfile,
              ci: Union[float, Signal],
              power_signal: Optional[Signal] = None) -> CarbonReport:
    """Eq. 4. With a time-varying CI signal, operational emissions are
    integrated against the power signal:  sum_t P(t) * CI(t) * dt."""
    if isinstance(ci, Signal):
        assert power_signal is not None, "time-varying CI needs a power signal"
        t = power_signal.times
        if len(t) >= 2:
            dt_h = float(np.median(np.diff(t))) / 3600.0
        else:
            dt_h = 1.0 / 60.0
        ci_t = ci.at(t)
        op_g = float(np.sum(power_signal.values * ci_t) * dt_h / 1000.0)
        avg_ci = float(np.mean(ci_t))
    else:
        op_g = energy_wh / 1000.0 * float(ci)
        avg_ci = float(ci)
    emb_g = gpu_hours * device.embodied_kg_per_hour * 1000.0
    return CarbonReport(operational_g=op_g, embodied_g=emb_g,
                        total_g=op_g + emb_g, avg_ci=avg_ci)


def emissions_batch(energy_wh: Sequence[float], gpu_hours: Sequence[float],
                    device: DeviceProfile, ci: Sequence[float]
                    ) -> List[CarbonReport]:
    """Eq. 4 stacked over aligned scenario axes (static CI only): one
    array pass over the (energy, gpu_hours, ci) triples. Elementwise
    float64 ops round exactly like the scalar arithmetic in
    ``emissions``, so the reports are bit-identical to per-scenario
    calls (pinned by the runner-mode equality tests)."""
    e = np.asarray(energy_wh, np.float64)
    h = np.asarray(gpu_hours, np.float64)
    c = np.asarray(ci, np.float64)
    op_g = e / 1000.0 * c
    emb_g = h * device.embodied_kg_per_hour * 1000.0
    return reports_from_arrays(op_g, emb_g, op_g + emb_g, c)


def reports_from_arrays(op_g: Sequence[float], emb_g: Sequence[float],
                        total_g: Sequence[float], ci: Sequence[float]
                        ) -> List[CarbonReport]:
    """Assemble ``CarbonReport`` rows from already-evaluated aligned
    Eq. 4 terms — shared by ``emissions_batch`` (numpy pass) and the
    sweep's device mode (the same elementwise ops inside one jax
    program, which round identically; only reductions upstream of the
    energy inputs can differ)."""
    return [CarbonReport(operational_g=float(o), embodied_g=float(m),
                         total_g=float(t), avg_ci=float(a))
            for o, m, t, a in zip(op_g, emb_g, total_g, ci)]


def stage_attributed_carbon(trace, power_model: PowerModel,
                            n_devices: int, pue: float,
                            ci: Signal) -> float:
    """Per-stage Eq. 2-3 energy x the live grid CI at each stage's
    start (gCO2), in one array pass over the ``StageTrace``. No idle
    fill — this is active (stage-time) carbon, immune to the Eq. 5
    bin quantization of co-sim totals."""
    if len(trace.start_s) == 0:
        return 0.0
    stage_wh = (np.asarray(power_model.power(trace.mfu)) * trace.dur_s
                / 3600.0 * n_devices * pue)
    return float(np.sum(stage_wh * ci.at(trace.start_s)) / 1000.0)
