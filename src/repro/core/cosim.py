"""Vidur->Vessim bridge: turn simulator batch-stage logs into a power
signal, run the microgrid co-simulation, and report paper-Table-2
metrics.

Pipeline (paper Section 3.2):
  1. timestamp batch stages (simulator clock)
  2. Eq. 1 power per stage from MFU
  3. Eq. 5 duration-weighted aggregation into fixed bins
  4. microgrid scan against solar + CI signals
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.microgrid import MicrogridConfig, simulate, summarize
from repro.core.power import PowerModel
from repro.core.signals import Signal, aggregate_power


@dataclasses.dataclass
class CosimResult:
    load: Signal
    solar: Signal
    ci: Signal
    traces: Dict[str, np.ndarray]
    metrics: Dict[str, float]


def stages_to_load_signal(stage_start_s, stage_dur_s, stage_mfu,
                          power_model: PowerModel, n_devices: int = 1,
                          pue: float = 1.0, resolution_s: float = 60.0,
                          include_idle: bool = True) -> Signal:
    """Stages -> per-bin average power (W, whole deployment)."""
    p = np.asarray(power_model.power(np.asarray(stage_mfu)))
    sig = aggregate_power(stage_start_s, stage_dur_s, p, resolution_s)
    vals = sig.values.copy()
    if include_idle:
        # bins with no recorded stage still draw idle power
        vals = np.where(vals > 0, vals, power_model.dev.p_idle)
    return Signal(sig.times, vals * n_devices * pue, interp="previous")


def trace_to_load_signal(trace, power_model: PowerModel,
                         n_devices: int = 1, pue: float = 1.0,
                         resolution_s: float = 60.0,
                         include_idle: bool = True) -> Signal:
    """``stages_to_load_signal`` directly over a ``StageTrace``."""
    return stages_to_load_signal(trace.start_s, trace.dur_s, trace.mfu,
                                 power_model, n_devices=n_devices, pue=pue,
                                 resolution_s=resolution_s,
                                 include_idle=include_idle)


def run_cosim(load: Signal, solar: Signal, ci: Signal,
              cfg: Optional[MicrogridConfig] = None) -> CosimResult:
    cfg = cfg or MicrogridConfig()
    # align all signals on the load grid
    t = load.times
    lw = jnp.asarray(load.values)
    sw = jnp.asarray(solar.at(t))
    cw = jnp.asarray(ci.at(t))
    tr = simulate(lw, sw, cw, cfg)
    tr_np = {k: np.asarray(v) for k, v in tr.items()}
    metrics = summarize(np.asarray(lw), np.asarray(sw), np.asarray(cw),
                        tr_np, cfg)
    return CosimResult(load=load, solar=Signal(t, np.asarray(sw)),
                       ci=Signal(t, np.asarray(cw)), traces=tr_np,
                       metrics=metrics)
