"""Environmental datasets: synthetic generators (offline stand-ins for
Solcast irradiance and WattTime CAISO-North carbon intensity) plus a
loader for real ElectricityMaps/WattTime-style CSV carbon-intensity
exports.

Synthetic traces are generated with documented diurnal structure +
seeded noise so benchmark results are reproducible. Interfaces mirror
the real data: 1-minute resolution W/m^2-scaled solar output and
gCO2/kWh marginal intensity. File-backed traces register alongside the
synthetic ones in ``ci_trace_signal`` and tile periodically to any
requested horizon (prefix-stable, like the generators).
"""
from __future__ import annotations

import csv
from datetime import datetime, timezone
from pathlib import Path
from typing import Dict

import numpy as np

from repro.core.signals import Signal

#: bundled sample traces (``src/repro/core/data``)
DATA_DIR = Path(__file__).resolve().parent / "data"


def solar_signal(hours: float, capacity_w: float = 600.0, seed: int = 0,
                 step_s: float = 60.0, day_offset_h: float = 0.0,
                 cloudiness: float = 0.25) -> Signal:
    """Diurnal solar generation: clear-sky half-sine (6am-6pm) with
    cloud-driven multiplicative noise (Ornstein-Uhlenbeck-ish)."""
    rng = np.random.default_rng(seed)
    t = np.arange(0.0, hours * 3600.0, step_s)
    hod = ((t / 3600.0 + day_offset_h) % 24.0)
    x = (hod - 6.0) / 12.0
    clear = np.where((x >= 0) & (x <= 1), np.sin(np.pi * np.clip(x, 0, 1)),
                     0.0)
    # correlated cloud factor
    n = len(t)
    cloud = np.empty(n)
    c = 0.0
    alpha = step_s / 1800.0     # ~30 min correlation
    for i in range(n):
        c = (1 - alpha) * c + alpha * rng.normal()
        cloud[i] = c
    cloud_factor = np.clip(1.0 - cloudiness * (1 + np.tanh(cloud)), 0.05, 1.0)
    return Signal(t, capacity_w * clear * cloud_factor, interp="linear")


# Named grid regions for fleet/sweep axes: parameterizations of the
# synthetic duck-curve generator below (gCO2/kWh; seeds fixed so every
# sweep samples identical traces). "caiso-east" is the same grid shape
# three timezones ahead, so its evening ramp lands 3 h earlier in
# absolute sim time — a cheap timezone-diversity stand-in. "-evening"
# variants start the trace at 17:00 local, so sim t=0 sits on the
# evening ramp and the overnight decline is within a few hours — the
# window where temporal deferral (repro.schedule) has something to
# shift into.
CI_TRACES = {
    "caiso": dict(base=380.0, swing=120.0, seed=4),
    "caiso-east": dict(base=380.0, swing=120.0, seed=4, day_offset_h=3.0),
    "caiso-evening": dict(base=380.0, swing=120.0, seed=4,
                          day_offset_h=17.0),
    "coal": dict(base=720.0, swing=60.0, seed=11),
    "coal-evening": dict(base=720.0, swing=60.0, seed=11,
                         day_offset_h=17.0),
    "hydro": dict(base=70.0, swing=20.0, seed=12),
    "hydro-evening": dict(base=70.0, swing=20.0, seed=12,
                          day_offset_h=17.0),
    "wind": dict(base=180.0, swing=90.0, seed=13),
    # "-night" variants start just past the 19.5 h duck-curve peak, so
    # CI declines from sim t=0 — short-horizon deferral windows (the
    # day-scale smoke grids) see an immediate carbon gradient to shift
    # into without needing hours of lead-up
    "caiso-night": dict(base=380.0, swing=120.0, seed=4,
                        day_offset_h=20.0),
    "coal-night": dict(base=720.0, swing=60.0, seed=11,
                       day_offset_h=20.0),
}

# File-backed traces (real-world CI exports), registered next to the
# synthetic ones. The bundled sample is a 48 h hourly ElectricityMaps-
# style CAISO export; drop additional CSVs in and register them here or
# via register_ci_trace_file().
CI_TRACE_FILES: Dict[str, Path] = {
    "caiso-em": DATA_DIR / "electricitymaps_caiso_48h.csv",
}


def register_ci_trace_file(name: str, path) -> None:
    """Register an ElectricityMaps/WattTime-style CSV as a named trace.

    Names are cache-relevant (sweep scenarios digest the trace *name*,
    not the file contents), so silently repointing an existing name
    would make cached and fresh results disagree — rebinding requires
    an explicit ``del CI_TRACE_FILES[name]`` first.
    """
    if name in CI_TRACES:
        raise ValueError(f"{name!r} already names a synthetic trace")
    if name in CI_TRACE_FILES:
        raise ValueError(f"{name!r} already names a registered file trace")
    CI_TRACE_FILES[name] = Path(path)


# Recognized CI value columns, in priority order (ElectricityMaps
# exports, WattTime MOER exports, and our own to_csv round-trip).
_CI_VALUE_COLUMNS = ("carbon_intensity_gco2eq_per_kwh", "carbon_intensity",
                     "moer", "value", "ci")
_CI_TIME_COLUMNS = ("datetime", "point_time", "timestamp", "time_s", "time")


def _parse_time_s(raw: str) -> float:
    """ISO-8601 timestamp -> epoch seconds, or plain numeric seconds.
    Timezone-naive timestamps are taken as UTC — localtime would make
    the same file parse differently per host and inject a phantom hour
    at DST transitions."""
    try:
        return float(raw)
    except ValueError:
        dt = datetime.fromisoformat(raw.replace("Z", "+00:00"))
        if dt.tzinfo is None:
            dt = dt.replace(tzinfo=timezone.utc)
        return dt.timestamp()


def load_ci_csv(path) -> Signal:
    """Parse an ElectricityMaps/WattTime-style CSV into a ``Signal``.

    Column detection is by name (case-insensitive): time from
    ``datetime``/``point_time``/``time_s``/..., value from
    ``carbon_intensity*``/``moer``/``value``/... Timestamps may be
    ISO-8601 or numeric seconds; the signal's time axis is rebased so
    the first sample sits at t=0 (sim time).
    """
    path = Path(path)
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        cols = {c.lower().strip(): c for c in reader.fieldnames or []}
        tcol = next((cols[c] for c in _CI_TIME_COLUMNS if c in cols), None)
        vcol = next((cols[c] for c in _CI_VALUE_COLUMNS if c in cols), None)
        if tcol is None or vcol is None:
            raise ValueError(
                f"{path}: need a time column ({'/'.join(_CI_TIME_COLUMNS)}) "
                f"and a CI column ({'/'.join(_CI_VALUE_COLUMNS)}); "
                f"have {reader.fieldnames}")
        times, values = [], []
        for row in reader:
            if not row.get(tcol) or not row.get(vcol):
                continue        # skip blank/malformed rows
            try:
                v = float(row[vcol])
            except ValueError:
                continue        # "null"/placeholder cells
            if not np.isfinite(v):
                continue        # "NaN" missing-reading markers
            times.append(_parse_time_s(row[tcol]))
            values.append(v)
    if len(times) < 2:
        raise ValueError(f"{path}: fewer than 2 usable rows")
    t = np.asarray(times, np.float64)
    order = np.argsort(t, kind="stable")
    t = t[order] - t[order[0]]
    return Signal(t, np.asarray(values, np.float64)[order], interp="linear")


def _tile_signal(sig: Signal, hours: float) -> Signal:
    """Extend a finite trace to ``hours`` by periodic tiling (prefix-
    stable: a longer horizon never changes the values of a shorter
    one, matching the synthetic generators' contract).

    The period must preserve time-of-day phase, and exports come in
    two shapes: *endpoint-inclusive* (last sample sits at a whole-day
    offset from the first, i.e. it already starts the next period —
    period = span, drop the duplicate) and *endpoint-exclusive*
    (period = span + one sample step; tiling by the raw span would
    drift the diurnal phase one step per repeat)."""
    span = float(sig.times[-1])
    need_s = hours * 3600.0
    if span <= 0 or span >= need_s:
        return sig
    day_phase = span % 86400.0
    if min(day_phase, 86400.0 - day_phase) < 1e-6:
        period, skip = span, 1      # t=span of copy k == t=0 of k+1
    else:
        step = float(np.median(np.diff(sig.times)))
        period, skip = span + step, 0
    reps = int(np.ceil(need_s / period))
    times = [sig.times]
    values = [sig.values]
    for k in range(1, reps + 1):
        times.append(sig.times[skip:] + k * period)
        values.append(sig.values[skip:])
    return Signal(np.concatenate(times), np.concatenate(values),
                  interp=sig.interp, fill=sig.fill)


def ci_trace_signal(name: str, hours: float, step_s: float = 60.0) -> Signal:
    """Carbon-intensity trace for a named region: synthetic
    (``CI_TRACES``) or file-backed (``CI_TRACE_FILES``, tiled
    periodically to cover the horizon)."""
    if name in CI_TRACES:
        return carbon_intensity_signal(hours, step_s=step_s,
                                       **CI_TRACES[name])
    if name in CI_TRACE_FILES:
        return _tile_signal(load_ci_csv(CI_TRACE_FILES[name]), hours)
    raise KeyError(f"unknown CI trace {name!r}; have "
                   f"{sorted(CI_TRACES) + sorted(CI_TRACE_FILES)}")


def carbon_intensity_signal(hours: float, seed: int = 1,
                            step_s: float = 60.0,
                            base: float = 380.0, swing: float = 120.0,
                            day_offset_h: float = 0.0) -> Signal:
    """CAISO-North-like marginal CI (gCO2/kWh): low mid-day (solar on the
    grid), high evening ramp (duck curve), noisy around the trend."""
    rng = np.random.default_rng(seed)
    t = np.arange(0.0, hours * 3600.0, step_s)
    hod = ((t / 3600.0 + day_offset_h) % 24.0)
    # duck curve: dip at 12h, peak at 19-21h
    dip = -np.exp(-0.5 * ((hod - 13.0) / 2.5) ** 2)
    peak = 0.9 * np.exp(-0.5 * ((hod - 19.5) / 1.8) ** 2)
    trend = base + swing * (dip + peak)
    noise = np.empty(len(t))
    c = 0.0
    alpha = step_s / 3600.0
    for i in range(len(t)):
        c = (1 - alpha) * c + alpha * rng.normal() * 30.0
        noise[i] = c
    return Signal(t, np.clip(trend + noise, 50.0, 900.0), interp="linear")
