"""Synthetic environmental datasets (offline stand-ins for Solcast
irradiance and WattTime CAISO-North carbon intensity).

Generated with documented diurnal structure + seeded noise so benchmark
results are reproducible. Interfaces mirror the real data: 1-minute
resolution W/m^2-scaled solar output and gCO2/kWh marginal intensity.
"""
from __future__ import annotations

import numpy as np

from repro.core.signals import Signal


def solar_signal(hours: float, capacity_w: float = 600.0, seed: int = 0,
                 step_s: float = 60.0, day_offset_h: float = 0.0,
                 cloudiness: float = 0.25) -> Signal:
    """Diurnal solar generation: clear-sky half-sine (6am-6pm) with
    cloud-driven multiplicative noise (Ornstein-Uhlenbeck-ish)."""
    rng = np.random.default_rng(seed)
    t = np.arange(0.0, hours * 3600.0, step_s)
    hod = ((t / 3600.0 + day_offset_h) % 24.0)
    x = (hod - 6.0) / 12.0
    clear = np.where((x >= 0) & (x <= 1), np.sin(np.pi * np.clip(x, 0, 1)),
                     0.0)
    # correlated cloud factor
    n = len(t)
    cloud = np.empty(n)
    c = 0.0
    alpha = step_s / 1800.0     # ~30 min correlation
    for i in range(n):
        c = (1 - alpha) * c + alpha * rng.normal()
        cloud[i] = c
    cloud_factor = np.clip(1.0 - cloudiness * (1 + np.tanh(cloud)), 0.05, 1.0)
    return Signal(t, capacity_w * clear * cloud_factor, interp="linear")


# Named grid regions for fleet/sweep axes: parameterizations of the
# synthetic duck-curve generator below (gCO2/kWh; seeds fixed so every
# sweep samples identical traces). "caiso-east" is the same grid shape
# three timezones ahead, so its evening ramp lands 3 h earlier in
# absolute sim time — a cheap timezone-diversity stand-in.
CI_TRACES = {
    "caiso": dict(base=380.0, swing=120.0, seed=4),
    "caiso-east": dict(base=380.0, swing=120.0, seed=4, day_offset_h=3.0),
    "coal": dict(base=720.0, swing=60.0, seed=11),
    "hydro": dict(base=70.0, swing=20.0, seed=12),
    "wind": dict(base=180.0, swing=90.0, seed=13),
}


def ci_trace_signal(name: str, hours: float, step_s: float = 60.0) -> Signal:
    """Carbon-intensity trace for a named region (see ``CI_TRACES``)."""
    if name not in CI_TRACES:
        raise KeyError(f"unknown CI trace {name!r}; have {sorted(CI_TRACES)}")
    return carbon_intensity_signal(hours, step_s=step_s, **CI_TRACES[name])


def carbon_intensity_signal(hours: float, seed: int = 1,
                            step_s: float = 60.0,
                            base: float = 380.0, swing: float = 120.0,
                            day_offset_h: float = 0.0) -> Signal:
    """CAISO-North-like marginal CI (gCO2/kWh): low mid-day (solar on the
    grid), high evening ramp (duck curve), noisy around the trend."""
    rng = np.random.default_rng(seed)
    t = np.arange(0.0, hours * 3600.0, step_s)
    hod = ((t / 3600.0 + day_offset_h) % 24.0)
    # duck curve: dip at 12h, peak at 19-21h
    dip = -np.exp(-0.5 * ((hod - 13.0) / 2.5) ** 2)
    peak = 0.9 * np.exp(-0.5 * ((hod - 19.5) / 1.8) ** 2)
    trend = base + swing * (dip + peak)
    noise = np.empty(len(t))
    c = 0.0
    alpha = step_s / 3600.0
    for i in range(len(t)):
        c = (1 - alpha) * c + alpha * rng.normal() * 30.0
        noise[i] = c
    return Signal(t, np.clip(trend + noise, 50.0, 900.0), interp="linear")
