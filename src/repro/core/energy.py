"""Operational energy accounting (paper Eqs. 2-3).

    MFU_i = (FLOPs_MLP(i) + FLOPs_Attn(i)) / (DeviceFLOPs * t_i)
    G     = R * TP * PP                      (GPUs per deployment)
    H_i   = dt_i / 3600 * G                  (GPU-hours of stage i)
    E_op  = sum_i P(MFU_i) * H_i * PUE       (Wh)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.power import DeviceProfile, PowerModel


@dataclasses.dataclass
class EnergyReport:
    energy_wh: float
    gpu_hours: float
    avg_power_w: float          # duration-weighted mean per-GPU power
    peak_power_w: float
    avg_mfu: float
    duration_s: float
    n_devices: int
    pue: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def stage_mfu(flops_mlp: np.ndarray, flops_attn: np.ndarray,
              stage_dur_s: np.ndarray, device: DeviceProfile,
              n_devices: int = 1) -> np.ndarray:
    """Eq. 2 (as a fraction, not percent)."""
    total = np.asarray(flops_mlp, np.float64) + np.asarray(flops_attn, np.float64)
    dt = np.maximum(np.asarray(stage_dur_s, np.float64), 1e-12)
    return total / (device.peak_flops * dt * n_devices)


def operational_energy(mfu: np.ndarray, stage_dur_s: np.ndarray,
                       power_model: PowerModel, n_devices: int = 1,
                       pue: float = 1.0) -> EnergyReport:
    """Eq. 3. mfu per stage (fraction), durations in seconds."""
    mfu = np.asarray(mfu, np.float64)
    dt = np.asarray(stage_dur_s, np.float64)
    p = np.asarray(power_model.power(mfu))                   # W per device
    wh = float(np.sum(p * dt) / 3600.0 * n_devices * pue)
    dur = float(dt.sum())
    gpu_h = dur / 3600.0 * n_devices
    return EnergyReport(
        energy_wh=wh,
        gpu_hours=gpu_h,
        avg_power_w=float(np.sum(p * dt) / max(dur, 1e-12)),
        peak_power_w=float(p.max()) if len(p) else 0.0,
        avg_mfu=float(np.sum(mfu * dt) / max(dur, 1e-12)),
        duration_s=dur,
        n_devices=n_devices,
        pue=pue,
    )
