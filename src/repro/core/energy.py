"""Operational energy accounting (paper Eqs. 2-3).

    MFU_i = (FLOPs_MLP(i) + FLOPs_Attn(i)) / (DeviceFLOPs * t_i)
    G     = R * TP * PP                      (GPUs per deployment)
    H_i   = dt_i / 3600 * G                  (GPU-hours of stage i)
    E_op  = sum_i P(MFU_i) * H_i * PUE       (Wh)

All entry points are single array passes over a stage trace; the
``stacked_energy_reports`` variant evaluates a whole axis of PUE
values against one shared trace (per-stage power computed once) and is
bit-identical to calling ``operational_energy`` per value — the sweep
engine's vectorized mode relies on that equality.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.power import DeviceProfile, PowerModel


@dataclasses.dataclass
class EnergyReport:
    energy_wh: float
    gpu_hours: float
    avg_power_w: float          # duration-weighted mean per-GPU power
    peak_power_w: float
    avg_mfu: float
    duration_s: float
    n_devices: int
    pue: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def stage_mfu(flops_mlp: np.ndarray, flops_attn: np.ndarray,
              stage_dur_s: np.ndarray, device: DeviceProfile,
              n_devices: int = 1) -> np.ndarray:
    """Eq. 2 (as a fraction, not percent)."""
    total = np.asarray(flops_mlp, np.float64) + np.asarray(flops_attn, np.float64)
    dt = np.maximum(np.asarray(stage_dur_s, np.float64), 1e-12)
    return total / (device.peak_flops * dt * n_devices)


def operational_energy(mfu: np.ndarray, stage_dur_s: np.ndarray,
                       power_model: PowerModel, n_devices: int = 1,
                       pue: float = 1.0) -> EnergyReport:
    """Eq. 3. mfu per stage (fraction), durations in seconds."""
    return stacked_energy_reports(mfu, stage_dur_s, power_model,
                                  n_devices=n_devices, pues=(pue,))[0]


def reports_from_sums(e_sum: float, m_sum: float, dur: float, peak: float,
                      n_devices: int = 1, pues: Sequence[float] = (1.0,)
                      ) -> List[EnergyReport]:
    """Eq. 3 report assembly from the trace-level reductions alone:
    ``e_sum`` = sum(P_i * dt_i) in W*s, ``m_sum`` = sum(MFU_i * dt_i),
    ``dur`` = sum(dt_i), ``peak`` = max(P_i). One report per PUE value.

    This is the single source of the report-assembly float sequence —
    ``stacked_energy_reports`` feeds it numpy reductions; the sweep's
    device mode feeds it the same reductions computed on-device (which
    reassociate, hence that mode's ulp-level tolerance contract)."""
    dur = float(dur)
    gpu_h = dur / 3600.0 * n_devices
    avg_power = float(e_sum / max(dur, 1e-12))
    avg_mfu = float(m_sum / max(dur, 1e-12))
    return [EnergyReport(
        energy_wh=float(e_sum / 3600.0 * n_devices * pue),
        gpu_hours=gpu_h,
        avg_power_w=avg_power,
        peak_power_w=float(peak),
        avg_mfu=avg_mfu,
        duration_s=dur,
        n_devices=n_devices,
        pue=pue,
    ) for pue in pues]


def stacked_energy_reports(mfu: np.ndarray, stage_dur_s: np.ndarray,
                           power_model: PowerModel, n_devices: int = 1,
                           pues: Sequence[float] = (1.0,)
                           ) -> List[EnergyReport]:
    """Eq. 3 stacked over a PUE axis: one array pass over the shared
    stage trace (per-stage power evaluated once), then one report per
    PUE value. Energy is linear in PUE, so the stacked reports are
    bit-identical to per-value ``operational_energy`` calls."""
    mfu = np.asarray(mfu, np.float64)
    dt = np.asarray(stage_dur_s, np.float64)
    p = np.asarray(power_model.power(mfu))                   # W per device
    e_sum = np.sum(p * dt)                                   # W*s
    m_sum = np.sum(mfu * dt)
    dur = float(dt.sum())
    peak = float(p.max()) if len(p) else 0.0
    return reports_from_sums(e_sum, m_sum, dur, peak,
                             n_devices=n_devices, pues=pues)


def operational_energy_trace(trace, power_model: PowerModel,
                             n_devices: int = 1,
                             pue: float = 1.0) -> EnergyReport:
    """Eq. 2-3 directly over a ``StageTrace``."""
    return operational_energy(trace.mfu, trace.dur_s, power_model,
                              n_devices=n_devices, pue=pue)
