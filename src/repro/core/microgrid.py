"""Vessim-analogue microgrid co-simulation as a JAX ``lax.scan``.

Actors (load, solar), a battery with SoC constraints (the ``ClcBattery``
analogue), and a grid connection are stepped at fixed resolution
(default 1 minute). Because the step loop is a scan over jnp arrays, a
whole scenario grid (battery sizes x solar scales x policies) can be
``vmap``-ed and evaluated in one compiled call — a beyond-paper
capability the benchmarks use for sweeps.

Power-flow convention per step (all W, averaged over the step):
  load >= 0 (consumption), solar >= 0 (generation)
  surplus = solar - load
  surplus > 0: charge battery (up to c-rate/SoC-max), export remainder
  surplus < 0: discharge battery (down to SoC-min), import remainder
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BatteryConfig:
    capacity_wh: float = 100.0
    soc_init: float = 0.5
    soc_min: float = 0.2
    soc_max: float = 0.8
    max_charge_w: float = 1000.0
    max_discharge_w: float = 1000.0
    efficiency: float = 0.95        # round-trip split evenly


@dataclasses.dataclass(frozen=True)
class MicrogridConfig:
    battery: BatteryConfig = BatteryConfig()
    step_s: float = 60.0
    ci_threshold_low: float = 100.0    # gCO2/kWh (paper Table 1b)
    ci_threshold_high: float = 200.0


def simulate(load_w: jnp.ndarray, solar_w: jnp.ndarray, ci: jnp.ndarray,
             cfg: MicrogridConfig) -> Dict[str, jnp.ndarray]:
    """Run the co-simulation. load/solar/ci: (T,) aligned at cfg.step_s.

    Returns per-step traces + aggregate metrics (all jnp; differentiable
    and vmap-able over scenario parameters)."""
    b = cfg.battery
    dt_h = cfg.step_s / 3600.0
    eff = jnp.sqrt(b.efficiency)

    def step(soc_wh, inp):
        load, solar, ci_t = inp
        surplus = solar - load
        # charge path
        room = jnp.maximum(b.soc_max * b.capacity_wh - soc_wh, 0.0)
        max_charge = jnp.minimum(b.max_charge_w, room / dt_h / eff)
        charge = jnp.clip(surplus, 0.0, max_charge)
        # discharge path
        avail = jnp.maximum(soc_wh - b.soc_min * b.capacity_wh, 0.0)
        max_dis = jnp.minimum(b.max_discharge_w, avail * eff / dt_h)
        discharge = jnp.clip(-surplus, 0.0, max_dis)
        new_soc = soc_wh + charge * eff * dt_h - discharge / eff * dt_h
        grid = surplus - charge + discharge   # >0 export, <0 import
        grid_import = jnp.maximum(-grid, 0.0)
        grid_export = jnp.maximum(grid, 0.0)
        emis_g = grid_import * dt_h / 1000.0 * ci_t
        solar_used = jnp.minimum(solar, load + charge)
        out = {
            "soc": new_soc / b.capacity_wh,
            "grid_import_w": grid_import,
            "grid_export_w": grid_export,
            "charge_w": charge,
            "discharge_w": discharge,
            "emissions_g": emis_g,
            "solar_used_w": solar_used,
        }
        return new_soc, out

    soc0 = jnp.asarray(b.soc_init * b.capacity_wh)
    _, tr = jax.lax.scan(step, soc0, (load_w, solar_w, ci))
    return tr


def summarize(load_w, solar_w, ci, tr, cfg: MicrogridConfig) -> Dict[str, float]:
    """Aggregate metrics matching the paper's Table 2."""
    dt_h = cfg.step_s / 3600.0
    load = np.asarray(load_w)
    solar = np.asarray(solar_w)
    ci = np.asarray(ci)
    soc = np.asarray(tr["soc"])
    imp = np.asarray(tr["grid_import_w"])
    chg = np.asarray(tr["charge_w"])
    dis = np.asarray(tr["discharge_w"])
    emis = np.asarray(tr["emissions_g"])
    solar_used = np.asarray(tr["solar_used_w"])

    e_total = load.sum() * dt_h                     # Wh
    e_solar_gen = solar.sum() * dt_h
    e_solar_used = solar_used.sum() * dt_h
    e_grid = imp.sum() * dt_h
    total_emis = emis.sum()
    # counterfactual: all load from grid at prevailing CI
    emis_nosolar = float(np.sum(load * ci) * dt_h / 1000.0)
    offset = emis_nosolar - total_emis
    b = cfg.battery
    full_cycles = float(chg.sum() * dt_h / max(b.capacity_wh, 1e-9))
    return {
        "total_energy_kwh": e_total / 1000.0,
        "solar_generation_kwh": e_solar_gen / 1000.0,
        "grid_consumption_kwh": e_grid / 1000.0,
        "renewable_share_pct": 100.0 * e_solar_used / max(e_total, 1e-9),
        "grid_dependency_pct": 100.0 * e_grid / max(e_total, 1e-9),
        "total_emissions_nosolar_kg": emis_nosolar / 1000.0,
        "net_emissions_kg": total_emis / 1000.0,
        "offset_kg": offset / 1000.0,
        "carbon_offset_pct": 100.0 * offset / max(emis_nosolar, 1e-9),
        "avg_soc_pct": 100.0 * float(soc.mean()) if len(soc) else 0.0,
        "hours_below_50_soc": float(np.sum(soc < 0.5) * dt_h),
        "hours_above_80_soc": float(np.sum(soc >= 0.795) * dt_h),
        "charging_pct": 100.0 * float(np.mean(chg > 1e-6)),
        "discharging_pct": 100.0 * float(np.mean(dis > 1e-6)),
        "idle_pct": 100.0 * float(np.mean((chg <= 1e-6) & (dis <= 1e-6))),
        "battery_full_cycles": full_cycles,
        "avg_ci": float(ci.mean()),
        "hours_high_ci": float(np.sum(ci > cfg.ci_threshold_high) * dt_h),
        "duration_h": len(load) * dt_h,
    }
