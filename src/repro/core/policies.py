"""Carbon-aware scheduling policies (paper Section 5 directions).

Policies transform a load profile given grid signals:
  - ``threshold_deferral``: pause deferrable load when CI > high threshold,
    catch up when CI < low threshold (SPROUT/carbon-aware-batch style)
  - ``solar_following``: scale service capacity with solar availability
  - ``multi_region``: route load to the lower-CI region each step,
    subject to a migration cost

All operate on fixed-resolution numpy/jnp arrays so they can prepend the
microgrid scan.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def threshold_deferral(load_w: np.ndarray, ci: np.ndarray,
                       ci_high: float = 200.0, ci_low: float = 100.0,
                       deferrable_frac: float = 0.5,
                       max_backlog_wh: float = 1e9,
                       step_s: float = 60.0) -> Tuple[np.ndarray, Dict]:
    """Defer `deferrable_frac` of load during high-CI steps into a backlog
    served during low-CI steps. Returns (new_load, stats)."""
    dt_h = step_s / 3600.0
    out = np.array(load_w, np.float64)
    backlog = 0.0
    deferred_steps = 0
    catchup_steps = 0
    peak_backlog = 0.0
    for i in range(len(out)):
        if ci[i] > ci_high and backlog < max_backlog_wh:
            d = out[i] * deferrable_frac
            out[i] -= d
            backlog += d * dt_h
            deferred_steps += 1
        elif ci[i] < ci_low and backlog > 0:
            boost = min(backlog / dt_h, out[i] * deferrable_frac + 1e-9)
            out[i] += boost
            backlog -= boost * dt_h
            catchup_steps += 1
        peak_backlog = max(peak_backlog, backlog)
    return out, {"deferred_steps": deferred_steps,
                 "catchup_steps": catchup_steps,
                 "unserved_backlog_wh": backlog,
                 "peak_backlog_wh": peak_backlog}


def solar_following(load_w: np.ndarray, solar_w: np.ndarray,
                    min_frac: float = 0.4) -> np.ndarray:
    """Scale load toward solar availability, never below min_frac (QoS
    floor). Conserves total energy by renormalizing."""
    solar = np.asarray(solar_w, np.float64)
    load = np.asarray(load_w, np.float64)
    cap = np.clip(solar / max(solar.max(), 1e-9), min_frac, 1.0)
    scaled = load * cap
    total_in = load.sum()
    total_out = scaled.sum()
    if total_out > 0:
        scaled = scaled * (total_in / total_out)
    return scaled


def multi_region(load_w: np.ndarray, ci_regions: np.ndarray,
                 migration_penalty_g: float = 5.0,
                 expected_dwell_steps: int = 60,
                 step_s: float = 60.0) -> Tuple[np.ndarray, Dict]:
    """Greedy lowest-CI routing across regions with a per-switch carbon
    penalty amortized over the expected dwell time at the new region.
    ci_regions: (R, T). Returns (assignment (T,), stats)."""
    R, T = ci_regions.shape
    assign = np.zeros(T, np.int32)
    cur = int(np.argmin(ci_regions[:, 0]))
    switches = 0
    dwell_h = expected_dwell_steps * step_s / 3600.0
    for t in range(T):
        best = int(np.argmin(ci_regions[:, t]))
        if best != cur:
            # switch if the CI gap over the expected dwell amortizes the
            # migration penalty
            gap = ci_regions[cur, t] - ci_regions[best, t]
            if gap * load_w[t] / 1000.0 * dwell_h > migration_penalty_g:
                cur = best
                switches += 1
        assign[t] = cur
    ci_eff = ci_regions[assign, np.arange(T)]
    return assign, {"switches": switches,
                    "avg_ci_routed": float(ci_eff.mean()),
                    "avg_ci_region0": float(ci_regions[0].mean())}
