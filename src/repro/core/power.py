"""GPU/TPU power model (paper Eq. 1).

    P(mfu) = P_idle + (P_max_inst - P_idle) * (min(mfu, mfu_sat)/mfu_sat)^gamma

Sublinear power-law in MFU with saturation — captures early power
saturation of memory-bound inference (gamma < 1) and clamps at the
empirical saturation threshold. Calibrations follow the paper:
A100 100/400 W, H100 60/700 W, A40 30/300 W, mfu_sat = 0.45, gamma = 0.7.

TPU profiles are our hardware adaptation (documented estimates from
public TDP / efficiency figures; same functional form).

All functions are vectorized jnp so whole MFU traces (and vmapped
scenario sweeps) evaluate in one call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    p_idle: float               # W
    p_max_inst: float           # W, observed maximum under saturation
    mfu_sat: float              # empirical MFU saturation threshold
    gamma: float                # sublinear exponent (< 1)
    peak_flops: float           # FLOP/s (dense, fp16/bf16)
    hbm_bw: float               # bytes/s
    hbm_bytes: float            # capacity
    link_bw: float              # bytes/s per interconnect link
    embodied_kg_per_hour: float  # phi_manuf: embodied carbon rate kgCO2/h


# --- paper-faithful GPU calibrations (Section 3.1 / 4.1) ---
A100_SXM = DeviceProfile(
    name="a100-sxm4-80gb", p_idle=100.0, p_max_inst=400.0, mfu_sat=0.45,
    gamma=0.7, peak_flops=312e12, hbm_bw=2.039e12, hbm_bytes=80e9,
    link_bw=300e9,
    # LLMCarbon-style amortization: ~150 kgCO2 embodied over 5y of use
    embodied_kg_per_hour=150.0 / (5 * 365 * 24))
H100_SXM = DeviceProfile(
    name="h100-sxm5", p_idle=60.0, p_max_inst=700.0, mfu_sat=0.45,
    gamma=0.7, peak_flops=989e12, hbm_bw=3.35e12, hbm_bytes=80e9,
    link_bw=450e9, embodied_kg_per_hour=180.0 / (5 * 365 * 24))
A40_PCIE = DeviceProfile(
    name="a40-pcie", p_idle=30.0, p_max_inst=300.0, mfu_sat=0.45,
    gamma=0.7, peak_flops=149.7e12, hbm_bw=696e9, hbm_bytes=48e9,
    link_bw=32e9, embodied_kg_per_hour=120.0 / (5 * 365 * 24))

# --- TPU adaptation (estimates; same Eq. 1 form) ---
TPU_V5E = DeviceProfile(
    name="tpu-v5e", p_idle=60.0, p_max_inst=200.0, mfu_sat=0.45,
    gamma=0.7, peak_flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
    link_bw=50e9, embodied_kg_per_hour=80.0 / (5 * 365 * 24))
TPU_V5P = DeviceProfile(
    name="tpu-v5p", p_idle=90.0, p_max_inst=350.0, mfu_sat=0.45,
    gamma=0.7, peak_flops=459e12, hbm_bw=2.765e12, hbm_bytes=95e9,
    link_bw=100e9, embodied_kg_per_hour=120.0 / (5 * 365 * 24))

DEVICES: Dict[str, DeviceProfile] = {
    d.name: d for d in (A100_SXM, H100_SXM, A40_PCIE, TPU_V5E, TPU_V5P)
}
DEVICES["a100"] = A100_SXM
DEVICES["h100"] = H100_SXM
DEVICES["a40"] = A40_PCIE
DEVICES["v5e"] = TPU_V5E
DEVICES["v5p"] = TPU_V5P


def power(mfu, dev: DeviceProfile):
    """Eq. 1, vectorized. mfu in [0, 1] (fraction, not percent)."""
    mfu = jnp.clip(jnp.asarray(mfu, jnp.float32), 0.0, None)
    x = jnp.minimum(mfu, dev.mfu_sat) / dev.mfu_sat
    return dev.p_idle + (dev.p_max_inst - dev.p_idle) * jnp.power(x, dev.gamma)


class PowerModel:
    """Object facade used by the simulator and co-simulation bridge."""

    def __init__(self, device: str | DeviceProfile = "a100"):
        self.dev = DEVICES[device] if isinstance(device, str) else device

    def power(self, mfu):
        return power(mfu, self.dev)

    def energy_wh(self, mfu, duration_s, n_devices: int = 1, pue: float = 1.0):
        """Energy in Wh for stages with given MFU and duration (Eq. 3)."""
        p = self.power(mfu)
        return jnp.sum(p * jnp.asarray(duration_s) / 3600.0) * n_devices * pue
