"""Time-series signals: the Vessim ``HistoricalSignal`` analogue + the
Eq. 5 variable-duration -> fixed-resolution aggregation pipeline.

A ``Signal`` is (times_s, values) with interpolation ("previous",
"linear", "cubic"). ``aggregate_power`` converts the simulator's
variable-duration batch-stage power sequence into fixed bins with the
paper's duration-weighted average:

    P_bar = sum_i P_i * dt_i / sum_i dt_i                      (Eq. 5)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Signal:
    """Time-indexed signal. times in seconds (monotonic), values float."""
    times: np.ndarray
    values: np.ndarray
    interp: str = "previous"          # previous | linear | cubic
    fill: float = 0.0

    def __post_init__(self):
        self.times = np.asarray(self.times, np.float64)
        self.values = np.asarray(self.values, np.float64)
        assert self.times.ndim == 1 and self.times.shape == self.values.shape
        if len(self.times) > 1:
            assert np.all(np.diff(self.times) >= 0), "times must be sorted"

    def at(self, t) -> np.ndarray:
        """Sample the signal at time(s) t."""
        t = np.asarray(t, np.float64)
        if len(self.times) == 0:
            return np.full_like(t, self.fill, dtype=np.float64)
        if self.interp == "previous":
            idx = np.searchsorted(self.times, t, side="right") - 1
            out = np.where(idx >= 0, self.values[np.clip(idx, 0, None)],
                           self.fill)
            return out
        if self.interp == "linear":
            return np.interp(t, self.times, self.values,
                             left=self.fill, right=self.values[-1])
        if self.interp == "cubic":
            from scipy.interpolate import CubicSpline
            if len(self.times) < 4:
                return np.interp(t, self.times, self.values,
                                 left=self.fill, right=self.values[-1])
            cs = CubicSpline(self.times, self.values)
            out = cs(np.clip(t, self.times[0], self.times[-1]))
            return np.asarray(out, np.float64)
        raise ValueError(self.interp)

    def resample(self, resolution_s: float, t0: Optional[float] = None,
                 t1: Optional[float] = None) -> "Signal":
        t0 = self.times[0] if t0 is None else t0
        t1 = self.times[-1] if t1 is None else t1
        grid = np.arange(t0, t1 + resolution_s * 0.5, resolution_s)
        return Signal(grid, self.at(grid), interp=self.interp, fill=self.fill)


def aggregate_power(stage_start_s: np.ndarray, stage_dur_s: np.ndarray,
                    stage_power_w: np.ndarray, resolution_s: float = 60.0
                    ) -> Signal:
    """Eq. 5: duration-weighted binning of per-batch-stage power into a
    fixed-resolution load profile.

    Stages may straddle bin edges; each stage's power contributes to a bin
    weighted by its overlap with the bin."""
    start = np.asarray(stage_start_s, np.float64)
    dur = np.asarray(stage_dur_s, np.float64)
    power = np.asarray(stage_power_w, np.float64)
    if len(start) == 0:
        return Signal(np.zeros(0), np.zeros(0))
    end = start + dur
    t0 = np.floor(start.min() / resolution_s) * resolution_s
    t1 = np.ceil(end.max() / resolution_s) * resolution_s
    n_bins = max(1, int(round((t1 - t0) / resolution_s)))
    acc = np.zeros(n_bins)
    wsum = np.zeros(n_bins)
    first_bin = np.floor((start - t0) / resolution_s).astype(int)
    last_bin = np.ceil((end - t0) / resolution_s).astype(int) - 1
    max_span = int(np.max(last_bin - first_bin)) + 1 if len(start) else 1
    for k in range(max_span):
        b = first_bin + k
        in_range = b <= last_bin
        bs = t0 + b * resolution_s
        be = bs + resolution_s
        overlap = np.clip(np.minimum(end, be) - np.maximum(start, bs),
                          0.0, None) * in_range
        np.add.at(acc, np.clip(b, 0, n_bins - 1), power * overlap)
        np.add.at(wsum, np.clip(b, 0, n_bins - 1), overlap)
    vals = np.where(wsum > 0, acc / np.maximum(wsum, 1e-12), 0.0)
    # idle bins draw zero *dynamic* load; callers add idle power explicitly
    times = t0 + np.arange(n_bins) * resolution_s
    return Signal(times, vals, interp="previous")


def to_csv(signal: Signal, path: str, name: str = "value"):
    """Vessim-style load-profile CSV export."""
    with open(path, "w") as f:
        f.write(f"time_s,{name}\n")
        for t, v in zip(signal.times, signal.values):
            f.write(f"{t:.3f},{v:.6f}\n")
