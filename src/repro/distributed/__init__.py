from repro.distributed.axes import axis_env, constrain, default_mapping, logical_to_spec

__all__ = ["axis_env", "constrain", "default_mapping", "logical_to_spec"]
