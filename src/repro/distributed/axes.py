"""Logical-axis sharding environment.

Model code is mesh-agnostic: it annotates intermediates with *logical*
axis names via ``constrain(x, ("batch", "seq", "embed"))``. The launcher
activates an environment mapping logical names to physical mesh axes
(e.g. batch -> ("pod", "data"), heads/mlp/expert -> "model"). Outside an
active environment ``constrain`` is a no-op, so the same model code runs
single-device on CPU and multi-pod under pjit.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisName = Union[str, Tuple[str, ...], None]


def _current() -> Optional[dict]:
    return getattr(_state, "env", None)


@contextlib.contextmanager
def axis_env(mesh: Mesh, mapping: Dict[str, AxisName]):
    """Activate a logical->physical axis mapping for the enclosed trace."""
    prev = _current()
    _state.env = {"mesh": mesh, "map": dict(mapping)}
    try:
        yield
    finally:
        _state.env = prev


def logical_to_spec(axes: Tuple[Optional[str], ...],
                    mapping: Dict[str, AxisName]) -> P:
    phys = []
    used = set()
    for a in axes:
        m = mapping.get(a) if a is not None else None
        # a physical axis may appear at most once in a PartitionSpec
        if m is not None:
            flat = (m,) if isinstance(m, str) else tuple(m)
            flat = tuple(f for f in flat if f not in used)
            used.update(flat)
            m = flat if len(flat) > 1 else (flat[0] if flat else None)
        phys.append(m)
    return P(*phys)


def constrain(x, axes: Tuple[Optional[str], ...]):
    """Apply a logical sharding constraint if an axis env is active."""
    env = _current()
    if env is None:
        return x
    spec = logical_to_spec(axes, env["map"])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env["mesh"], spec))


# Default logical-axis mapping for the production meshes.
def default_mapping(multi_pod: bool = False) -> Dict[str, AxisName]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,           # sequence usually unsharded (SP for long_500k)
        "embed": None,
        "heads": "model",
        "head_dim": None,
        "kv_heads": None,      # replicated when they don't divide TP
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "capacity": batch,
        "layers": None,
    }
