"""Gradient compression for cross-pod reduction: int8 quantization with
error feedback (1-bit-Adam-style residual carrying).

At 1000+ node scale the data-parallel gradient reduce-scatter crosses the
slow inter-pod links; 8-bit block-quantized gradients cut that traffic 4x
(fp32) / 2x (bf16) with the residual error fed back into the next step so
the compression bias vanishes in expectation.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype
                    ) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads, residuals=None):
    """Error-feedback compression of a gradient pytree.

    Returns (quantized tree of (q, scale), new residuals)."""
    if residuals is None:
        residuals = jax.tree.map(jnp.zeros_like, grads)

    def one(g, r):
        g_corr = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, s = quantize_int8(g_corr)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        new_r = g_corr - deq
        return (q, s), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(residuals)[0]
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    rtree = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return qtree, rtree


def decompress_tree(qtree, like):
    flat_q, treedef = jax.tree_util.tree_flatten(
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))
    flat_l = jax.tree_util.tree_flatten(like)[0]
    out = [dequantize_int8(q, s, l.shape, l.dtype)
           for (q, s), l in zip(flat_q, flat_l)]
    return jax.tree_util.tree_unflatten(treedef, out)
