"""Elastic scaling: re-mesh and re-shard live state when the device pool
changes (node failure or capacity growth).

The checkpoint layout is device-count-independent (host numpy leaves), so
elasticity reduces to: gather -> rebuild mesh/plan for the new topology ->
re-place. ``reshard_tree`` performs the live device-to-device path when
both meshes coexist; ``ElasticContext.on_change`` falls back to the
checkpoint path when they don't.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import make_plan, param_pspecs


def reshard_tree(tree, new_spec_tree, new_mesh: Mesh):
    """Re-place a pytree onto a new mesh (gathers to host if needed)."""
    def one(x, spec):
        sh = NamedSharding(new_mesh, spec)
        try:
            return jax.device_put(x, sh)
        except Exception:
            return jax.device_put(np.asarray(x), sh)
    return jax.tree.map(one, tree, new_spec_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


@dataclasses.dataclass
class ElasticContext:
    """Tracks the active mesh; rebuilds plans when the pool changes."""
    cfg: "ModelConfig"
    kind: str
    mesh: Mesh
    plan: object = None

    def __post_init__(self):
        self.plan = make_plan(self.cfg, self.mesh, self.kind)

    def on_change(self, new_mesh: Mesh, params, opt_state=None):
        """Re-shard live training state onto ``new_mesh``."""
        new_plan = make_plan(self.cfg, new_mesh, self.kind)
        p_abs = jax.eval_shape(lambda t: t, params)
        specs = param_pspecs(p_abs, new_plan.mapping)
        params = reshard_tree(params, specs, new_mesh)
        if opt_state is not None:
            o_specs = {"mu": specs, "nu": specs,
                       "step": jax.sharding.PartitionSpec()}
            opt_state = reshard_tree(opt_state, o_specs, new_mesh)
        self.mesh = new_mesh
        self.plan = new_plan
        return params, opt_state
