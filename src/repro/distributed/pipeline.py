"""Pipeline parallelism: GPipe-style microbatch schedule via shard_map +
collective_permute over a ``stage`` mesh axis.

The production meshes are 2D/3D without a dedicated stage axis; PP is an
*optional* layout for deployments that want it (the launcher builds a
(stage, data) mesh). The schedule below is the standard loop formulation:
at step t, stage s processes microbatch (t - s); activations hop one
stage per step via ppermute; the bubble is (S-1) steps of (M+S-1).

Gradient flow works through the same schedule because the whole thing is
differentiable jnp code (ppermute has a transpose rule).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, n_stages: int, n_micro: int,
                     mesh: Mesh, stage_axis: str = "stage"):
    """Build fn(stage_params, x_micro) -> y_micro running under shard_map.

    stage_fn(params_for_stage, x) -> y is the per-stage computation.
    stage_params leaves have leading dim = n_stages (sharded over the
    stage axis); x_micro is (n_micro, mb, ...) replicated.
    """

    def per_stage(params, x_micro):
        # params: this stage's slice (leading dim 1); x_micro replicated
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(stage_axis)
        S, M = n_stages, n_micro
        T = M + S - 1
        mb_shape = x_micro.shape[1:]

        def step(carry, t):
            buf, outputs = carry
            # stage s works on microbatch (t - s) if 0 <= t - s < M
            mb_idx = t - sid
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 reads fresh input; others use the handed-off buffer
            x_in = jnp.where(
                sid == 0,
                x_micro[jnp.clip(mb_idx, 0, M - 1)],
                buf)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage records output
            outputs = jax.lax.cond(
                active & (sid == S - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                lambda o: o,
                outputs)
            # hand activations to the next stage
            buf_next = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf_next, outputs), None

        buf0 = jnp.zeros(mb_shape, x_micro.dtype)
        out0 = jnp.zeros((M,) + mb_shape, x_micro.dtype)
        (_, outputs), _ = jax.lax.scan(step, (buf0, out0), jnp.arange(T))
        # only the last stage holds nonzero outputs; psum broadcasts them
        return jax.lax.psum(outputs, stage_axis)

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)


def make_pp_mesh(n_stages: int, n_data: int = 1):
    return jax.make_mesh((n_stages, n_data), ("stage", "data"))
