"""Sharding plans: param/activation/cache PartitionSpecs per (arch, mesh,
run-kind).

Logical parameter axes are assigned from tree paths (weight layouts are
head-major, so specs align with head boundaries); physical mappings
implement:

  - TP "head" mode  : q heads sharded over ``model``; KV heads replicated
                      ``kv_repeat``x when KV < TP (MaxText-style)
  - TP "head_dim"   : fallback when head counts don't divide TP
                      (smollm 15H, qwen2-vl 12H): shard head_dim instead
  - FSDP            : parameter d_model/embed dims additionally sharded
                      over ``data`` (+ ``pod``) for training and for
                      models whose bf16 weights exceed per-chip HBM
  - EP               : MoE expert dim sharded over ``model`` when the
                      expert count divides it (qwen3: 128e), else experts
                      are TP-sharded internally (mixtral: 8e)
  - SP (long_500k)  : KV-cache sequence dim sharded over ``data``/``pod``
                      for batch=1 long-context decode
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.axes import logical_to_spec

# ---------------------------------------------------------------------------
# TP mode selection
# ---------------------------------------------------------------------------


def tp_degree(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def attention_tp_mode(cfg: ModelConfig, tp: int) -> str:
    a = cfg.attention
    if a is None:
        return "head"
    if a.n_heads % tp == 0 and (a.n_kv_heads % tp == 0 or tp % a.n_kv_heads == 0):
        return "head"
    if a.head_dim % tp == 0:
        return "head_dim"
    return "replicated"


def kv_repeat_for(cfg: ModelConfig, tp: int) -> int:
    a = cfg.attention
    if a is None or attention_tp_mode(cfg, tp) != "head":
        return 1
    if a.n_kv_heads % tp == 0:
        return 1
    return tp // a.n_kv_heads


def needs_fsdp(cfg: ModelConfig, tp: int, kind: str,
               hbm_per_chip: float = 16e9) -> bool:
    if kind == "train":
        return True  # fp32 master + Adam moments always 2D-sharded
    bytes_per_chip = cfg.param_count() * 2 / tp
    return bytes_per_chip > 0.45 * hbm_per_chip


def moe_ep(cfg: ModelConfig, tp: int) -> bool:
    return cfg.moe is not None and cfg.moe.n_experts % tp == 0


# ---------------------------------------------------------------------------
# Logical mappings
# ---------------------------------------------------------------------------

def make_mapping(cfg: ModelConfig, mesh: Mesh, kind: str,
                 shape: Optional[ShapeConfig] = None,
                 variant: str = "baseline") -> Dict[str, Any]:
    """Logical axis -> physical mesh axis mapping for params + activations.

    Variants (§Perf hillclimb):
      baseline : TP over `model`, FSDP over `data` where needed
      dp       : no tensor parallelism — batch sharded over BOTH axes,
                 weights FSDP-sharded 2D for storage, gathered per layer
      hd       : force head_dim-sharded attention (kv_repeat = 1)
      sp       : baseline + Megatron-style sequence parallelism — the
                 residual stream is seq-sharded over `model`, converting
                 per-layer all-reduces into all-gather/reduce-scatter
                 pairs (half the ring traffic) and shrinking saved
                 activations TP-fold
    """
    tp = tp_degree(mesh)
    multi_pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if variant == "dp":
        batch_axes = batch_axes + ("model",)
        return {
            "batch": batch_axes, "seq": None, "seq_inner": None,
            "embed": None,
            "heads": None, "kv_heads": None, "head_dim": None,
            "vocab": None, "expert": None, "capacity": None,
            "mlp_act": None, "cache_seq": None,
            # 2D storage sharding; XLA gathers per layer for compute
            "p_vocab": "model",
            "p_embed": ("data",),
            "p_heads": ("model" if (cfg.attention is not None and
                                    cfg.attention.n_heads % tp == 0)
                        else None),
            "p_kv": ("model" if (cfg.attention is not None and
                                 cfg.attention.n_kv_heads % tp == 0)
                     else None),
            "p_head_dim": None,
            "p_mlp": "model",
            "p_expert": ("model" if (cfg.moe is not None
                                     and cfg.moe.n_experts % tp == 0)
                         else None),
            "p_mlp_expert": (None if (cfg.moe is not None
                                      and cfg.moe.n_experts % tp == 0)
                             else "model"),
        }
    mode = attention_tp_mode(cfg, tp)
    if variant == "hd":
        mode = "head_dim" if (cfg.attention is not None
                              and cfg.attention.head_dim % tp == 0) else mode
    fsdp = needs_fsdp(cfg, tp, kind)
    ep = moe_ep(cfg, tp)
    a = cfg.attention
    vocab_ok = cfg.vocab_size % tp == 0

    mapping: Dict[str, Any] = {
        # --- activations ---
        "batch": batch_axes,
        "seq": "model" if variant == "sp" else None,
        "seq_inner": None,
        "embed": None,
        "heads": "model" if mode == "head" else None,
        "kv_heads": "model" if (mode == "head" and a is not None
                                and a.n_kv_eff % tp == 0) else None,
        "head_dim": "model" if mode == "head_dim" else None,
        "vocab": "model" if vocab_ok else None,
        "expert": "model" if ep else None,
        "capacity": batch_axes,
        "mlp_act": "model",
        # --- parameters ---
        "p_vocab": "model" if vocab_ok else None,
        "p_embed": batch_axes if fsdp else None,
        "p_heads": "model" if mode == "head" else None,
        "p_kv": "model" if (mode == "head" and a is not None
                            and a.n_kv_heads % tp == 0) else None,
        "p_head_dim": "model" if mode == "head_dim" else None,
        "p_mlp": "model",
        "p_expert": "model" if ep else None,
    }
    if ep:
        mapping["p_mlp_expert"] = None   # expert dim takes the model axis
    else:
        mapping["p_mlp_expert"] = "model"
    # long-context decode: shard cache sequence over the batch axes
    if shape is not None and shape.kind == "decode" and shape.global_batch == 1:
        mapping["cache_seq"] = batch_axes
        mapping["batch"] = None
        mapping["capacity"] = None
    else:
        mapping["cache_seq"] = None
    return mapping


# ---------------------------------------------------------------------------
# Parameter specs from tree paths
# ---------------------------------------------------------------------------

_RULES_3D = {
    "wq": ("p_embed", "p_heads", "p_head_dim"),
    "wk": ("p_embed", "p_kv", "p_head_dim"),
    "wv": ("p_embed", "p_kv", "p_head_dim"),
    "wo": ("p_heads", "p_head_dim", "p_embed"),
    "wr": ("p_embed", "p_heads", "p_head_dim"),
    "wg": ("p_embed", "p_heads", "p_head_dim"),
    "in_z": ("p_embed", "p_heads", "p_head_dim"),
    "in_x": ("p_embed", "p_heads", "p_head_dim"),
    "out_proj": ("p_heads", "p_head_dim", "p_embed"),
    "conv_x_w": (None, "p_heads", "p_head_dim"),
    "decay_lora_b": (None, "p_heads", "p_head_dim"),
    "up": ("p_expert", "p_embed", "p_mlp_expert"),     # MoE (E, d, f)
    "gate": ("p_expert", "p_embed", "p_mlp_expert"),
    "down": ("p_expert", "p_mlp_expert", "p_embed"),
    "mix_lora_a": (None, "p_embed", None),
    "mix_lora_b": (None, None, "p_embed"),
}

_RULES_2D = {
    "embed": ("p_vocab", "p_embed"),
    "lm_head": ("p_vocab", "p_embed"),
    "up": ("p_embed", "p_mlp"),
    "gate": ("p_embed", "p_mlp"),
    "down": ("p_mlp", "p_embed"),
    "cm_key": ("p_embed", "p_mlp"),
    "cm_value": ("p_mlp", "p_embed"),
    "cm_recept": ("p_embed", None),
    "router": ("p_embed", None),
    "bq": ("p_heads", "p_head_dim"),
    "bk": ("p_kv", "p_head_dim"),
    "bv": ("p_kv", "p_head_dim"),
    "u": ("p_heads", "p_head_dim"),
    "w0": ("p_heads", "p_head_dim"),
    "ln_x_scale": ("p_heads", "p_head_dim"),
    "ln_x_bias": ("p_heads", "p_head_dim"),
    "norm_scale": ("p_heads", "p_head_dim"),
    "conv_x_b": ("p_heads", "p_head_dim"),
    "in_B": ("p_embed", None),
    "in_C": ("p_embed", None),
    "in_dt": ("p_embed", "p_heads"),
    "decay_lora_a": ("p_embed", None),
    "conv_bc_w": (None, None),
    "maa": (None, None),
}

_RULES_1D = {
    "A_log": ("p_heads",),
    "dt_bias": ("p_heads",),
    "D_skip": ("p_heads",),
}


def _leaf_logical(path, leaf) -> Tuple[Optional[str], ...]:
    keys = [k.key for k in path if hasattr(k, "key")]
    name = keys[-1] if keys else ""
    stacked = 0
    if "layers" in keys:
        stacked = 1
    if "shared" in keys:
        stacked = 1
    ndim = leaf.ndim - stacked
    rule = None
    if ndim == 3:
        rule = _RULES_3D.get(name)
        # MoE expert tensors are 3D even unstacked; rwkv mix loras too.
        if rule is None and name in _RULES_2D:
            rule = _RULES_2D[name]
    elif ndim == 2:
        rule = _RULES_2D.get(name)
    elif ndim == 1:
        rule = _RULES_1D.get(name)
    if rule is None:
        rule = (None,) * ndim
    rule = tuple(rule[:ndim]) + (None,) * max(0, ndim - len(rule))
    return (None,) * stacked + rule


def param_logical_tree(params_shape) -> Any:
    """Map a params shape-tree to a tree of logical-axis tuples."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = [_leaf_logical(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_pspecs(params_shape, mapping: Dict[str, Any]):
    logical = param_logical_tree(params_shape)
    flat_l, treedef = jax.tree_util.tree_flatten(
        logical, is_leaf=lambda x: isinstance(x, tuple))
    specs = [logical_to_spec(ax, mapping) for ax in flat_l]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, mapping: Dict[str, Any],
                 batch_tree: Dict[str, Any]):
    def spec_for(name, leaf):
        nd = len(leaf.shape)
        if name in ("tokens", "labels", "valid"):
            return logical_to_spec(("batch", None)[:nd] + (None,) * (nd - 2),
                                   mapping)
        if name == "embeds":
            return logical_to_spec(("batch", None, None), mapping)
        if name == "positions3":
            return logical_to_spec(("batch", None, None), mapping)
        if name == "lengths":
            return logical_to_spec((None,), mapping)
        return P()
    return {k: spec_for(k, v) for k, v in batch_tree.items()}


def cache_pspecs(cfg: ModelConfig, mapping: Dict[str, Any], cache_tree):
    """Specs for the decode cache pytree (shape-dependent rules)."""
    def spec_for(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        if name in ("k", "v"):
            # (L|n_app, B, W, KV_eff, Dh)
            return logical_to_spec(
                (None, "batch", "cache_seq", "kv_heads", "head_dim"), mapping)
        if name == "lengths":
            return logical_to_spec((None,), mapping)
        if name == "wkv":       # (L, B, H, K, K)
            return logical_to_spec((None, "batch", "heads", None, None), mapping)
        if name in ("tm_shift", "cm_shift"):   # (L, B, D)
            return logical_to_spec((None, "batch", None), mapping)
        if name == "ssm":       # (L, B, H, N, P)
            return logical_to_spec((None, "batch", "heads", None, None), mapping)
        if name == "conv_x":    # (L, B, K-1, H, P)
            return logical_to_spec((None, "batch", None, "heads", "head_dim"),
                                   mapping)
        if name == "conv_bc":   # (L, B, K-1, 2GN)
            return logical_to_spec((None, "batch", None, None), mapping)
        return P()
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Plan facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardingPlan:
    cfg: ModelConfig            # with kv_repeat applied
    mesh: Mesh
    mapping: Dict[str, Any]
    kind: str                   # train | prefill | decode

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def tree_shardings(self, spec_tree):
        return jax.tree.map(self.named, spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def make_plan(cfg: ModelConfig, mesh: Mesh, kind: str,
              shape: Optional[ShapeConfig] = None,
              variant: str = "baseline") -> ShardingPlan:
    tp = tp_degree(mesh)
    rep = 1 if variant in ("dp", "hd") else kv_repeat_for(cfg, tp)
    if cfg.attention is not None and rep != cfg.attention.kv_repeat:
        cfg = cfg.replace(attention=dataclasses.replace(
            cfg.attention, kv_repeat=rep))
    mapping = make_mapping(cfg, mesh, kind, shape, variant)
    return ShardingPlan(cfg=cfg, mesh=mesh, mapping=mapping, kind=kind)
