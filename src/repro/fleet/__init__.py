"""Multi-site heterogeneous fleet simulation with carbon-aware
geo-routing: site/fleet configuration, pluggable routers, and the
``run_fleet_simulation`` driver that rolls per-site continuous-batching
simulations into a fleet-level energy/carbon/latency report.
"""
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.routing import (ROUTERS, CarbonGreedyFleetRouter,
                                 CarbonSloFleetRouter, FleetRouter,
                                 LeastLoadedFleetRouter,
                                 RoundRobinFleetRouter, RoundRobinRouter,
                                 make_router)
from repro.fleet.simulation import (FleetResult, LoopSite, SiteResult,
                                    drive, run_fleet_simulation)

__all__ = [
    "FleetConfig", "SiteConfig",
    "ROUTERS", "CarbonGreedyFleetRouter", "CarbonSloFleetRouter",
    "FleetRouter", "LeastLoadedFleetRouter", "RoundRobinFleetRouter",
    "RoundRobinRouter", "make_router",
    "FleetResult", "LoopSite", "SiteResult", "drive",
    "run_fleet_simulation",
]
