"""Replica autoscaling: an in-loop controller plus an epoch planner.

Two operating points share one ``AutoscalerConfig``:

* **In-drive controller** (``ReplicaController``) — attached to a
  fleet site, polled by the event loop (``LoopSite.maybe_control``)
  every ``control_interval_s`` of sim time. It estimates queue delay
  from the site's O(1) outstanding-token counter and scales the
  *active set* of replicas up/down between ``min_replicas`` and
  ``max_replicas``. Replicas are never removed from the site's lists
  (index stability for the loop's stuck-set and trace replica ids);
  deactivated replicas drain their queue, then either stay **warm**
  (idle power, instant reactivation) up to ``warm_spares`` or go cold
  (no power, reactivation pays ``scale_up_latency_s``). Scale-down is
  carbon-aware: shedding a warm spare is only worth its restart risk
  when grid CI is at/above ``ci_scale_down_g`` — at clean-grid hours
  idle power is cheap carbon, so spares stay warm.

* **Epoch planner** (``plan_replicas``) — the day-scale hybrid
  simulation decides replica counts per epoch *from predicted demand*
  (arrival-rate x mean tokens vs per-replica capacity), determinis-
  tically and before any simulation runs, so the hybrid and exact day
  modes see the identical plan and autoscale epochs stay bit-for-bit
  comparable.

Warm-spare idle power and scale-up latency are charged through the
established Eq. 2-5 accounting: spares contribute device-seconds at
``p_idle`` to the load profile, and cold replicas' clocks start
``scale_up_latency_s`` after the decision.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.fleet.routing import RoundRobinRouter


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    enabled: bool = False
    min_replicas: int = 1
    max_replicas: int = 8
    target_util: float = 0.6          # epoch planner's sizing target
    control_interval_s: float = 300.0
    scale_up_latency_s: float = 60.0  # cold-start delay
    delay_hi_s: float = 10.0          # est. queue delay to scale up
    delay_lo_s: float = 1.0           # est. queue delay to scale down
    tokens_per_s: float = 4000.0      # per-replica service estimate
    warm_spares: int = 1              # replicas kept warm when shed
    ci_scale_down_g: float = 0.0      # shed spares only at CI >= this


class ActiveSetRouter(RoundRobinRouter):
    """Round-robin over the first ``n_active`` of a fixed replica
    list — the controller moves the boundary, the loop keeps stable
    replica indices."""

    def __init__(self, n_replicas: int, cfg, n_active: int = None):
        super().__init__(n_replicas, cfg)
        self.n_active = len(self.replicas) if n_active is None \
            else n_active

    def route(self, req) -> int:
        target = self._next % max(self.n_active, 1)
        self.replicas[target].add(req)
        self._next = (target + 1) % max(self.n_active, 1)
        return target


@dataclasses.dataclass
class ScaleEvent:
    t_s: float
    n_active: int
    n_warm: int
    kind: str                         # up_warm | up_cold | down


class ReplicaController:
    """Delay-threshold autoscaler over a site's active replica set."""

    def __init__(self, cfg: AutoscalerConfig, n_initial: int):
        self.cfg = cfg
        self.n_active = max(cfg.min_replicas,
                            min(n_initial, cfg.max_replicas))
        self.n_warm = 0
        self._next_control = 0.0
        self.events: List[ScaleEvent] = [
            ScaleEvent(0.0, self.n_active, 0, "init")]

    def maybe_control(self, site, t_s: float) -> bool:
        """One control step if the interval elapsed; returns whether
        the active set changed (the loop then refreshes its replica
        pairing)."""
        if t_s < self._next_control:
            return False
        self._next_control = t_s + self.cfg.control_interval_s
        cfg = self.cfg
        delay = (site.outstanding_tokens()
                 / (cfg.tokens_per_s * max(self.n_active, 1)))
        if delay > cfg.delay_hi_s and self.n_active < cfg.max_replicas:
            warm = self.n_warm > 0
            if warm:
                self.n_warm -= 1
            else:
                # cold start: the new replica is usable only after the
                # scale-up latency — preset its clock
                site.clocks[self.n_active] = max(
                    site.clocks[self.n_active],
                    t_s + cfg.scale_up_latency_s)
            self.n_active += 1
            site.replicas.n_active = self.n_active
            self.events.append(ScaleEvent(
                t_s, self.n_active, self.n_warm,
                "up_warm" if warm else "up_cold"))
            if site.probe is not None:
                site.probe.on_scale(t_s, site.site_index, self.n_active,
                                    self.n_warm,
                                    "up_warm" if warm else "up_cold")
            return True
        if delay < cfg.delay_lo_s and self.n_active > cfg.min_replicas \
                and site.ci_at(t_s) >= cfg.ci_scale_down_g:
            self.n_active -= 1
            self.n_warm = min(self.n_warm + 1, cfg.warm_spares)
            site.replicas.n_active = self.n_active
            self.events.append(ScaleEvent(
                t_s, self.n_active, self.n_warm, "down"))
            if site.probe is not None:
                site.probe.on_scale(t_s, site.site_index, self.n_active,
                                    self.n_warm, "down")
            return True
        return False

    def stats(self) -> dict:
        ups = sum(1 for e in self.events if e.kind.startswith("up"))
        downs = sum(1 for e in self.events if e.kind == "down")
        return {"scale_ups": float(ups), "scale_downs": float(downs)}

    def device_signal(self, t_end: float, devices_per_replica: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(times, powered device count) step signal — active + warm
        replicas draw power; cold ones don't."""
        ts = np.asarray([e.t_s for e in self.events] + [t_end])
        vals = np.asarray([(e.n_active + e.n_warm) * devices_per_replica
                           for e in self.events] + [0])
        return ts, vals


def plan_replicas(cfg: AutoscalerConfig, util1: np.ndarray,
                  ci_mean: np.ndarray, n_initial: int
                  ) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Per-epoch (active, warm) replica plan from predicted demand.

    ``util1[e]`` is epoch e's utilization if served by ONE replica
    (rate x mean tokens / capacity); the plan sizes the active set to
    hold utilization near ``target_util``, scaling up eagerly and
    down one replica per epoch — and only when the epoch's mean grid
    CI is at/above ``ci_scale_down_g`` (carbon-aware scale-down:
    at clean hours a spare's idle energy is cheap carbon, so it stays
    warm instead).
    """
    n_ep = len(util1)
    active = np.empty(n_ep, int)
    warm = np.zeros(n_ep, int)
    cur = max(cfg.min_replicas, min(n_initial, cfg.max_replicas))
    cur_warm, ups, downs = 0, 0, 0
    for e in range(n_ep):
        need = int(np.ceil(util1[e] / max(cfg.target_util, 1e-9)))
        need = max(cfg.min_replicas, min(need, cfg.max_replicas))
        if need > cur:
            take_warm = min(cur_warm, need - cur)
            cur_warm -= take_warm
            ups += need - cur
            cur = need
        elif need < cur and ci_mean[e] >= cfg.ci_scale_down_g:
            cur -= 1                  # hysteresis: one step per epoch
            cur_warm = min(cur_warm + 1, cfg.warm_spares)
            downs += 1
        active[e] = cur
        warm[e] = cur_warm
    return active, warm, {"scale_ups": float(ups),
                          "scale_downs": float(downs)}
