"""Multi-site fleet deployment description.

A fleet serves one workload from several *sites*: each site is a
continuous-batching deployment (device type, replica count, TP/PP) in
its own grid region, with a named carbon-intensity trace
(``repro.core.datasets.CI_TRACES``) and an optional microgrid (solar
capacity + battery sizing, the paper's Table 1b actors). Requests are
assigned to sites by a pluggable router (``repro.fleet.routing``)
inside the simulation loop, so carbon-aware placement decisions see
each site's live CI signal — not a post-hoc load transform.

Everything here is plain dataclasses over primitives, so a
``FleetConfig`` content-hashes into the sweep cache exactly like a
``SimConfig`` (``repro.sweep.grid.config_digest``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.fleet.autoscale import AutoscalerConfig
from repro.schedule.config import ScheduleConfig
from repro.sim.execmodel import ExecModelConfig
from repro.sim.hybrid import DayConfig
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig


@dataclasses.dataclass(frozen=True)
class SiteConfig:
    """One datacenter site of the fleet."""
    name: str
    device: str = "a100"              # repro.core.power.DEVICES key
    n_replicas: int = 1
    tp: int = 1
    pp: int = 1
    ci_trace: str = "caiso"           # repro.core.datasets.CI_TRACES key
    # microgrid actors (paper Table 1b); zero capacity disables each
    solar_capacity_w: float = 0.0
    cloudiness: float = 0.12
    solar_seed: int = 3
    battery_capacity_wh: float = 0.0
    soc_init: float = 0.5
    soc_min: float = 0.2
    soc_max: float = 0.8
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)
    # replica autoscaling (repro.fleet.autoscale); default disabled —
    # the active set is then fixed at n_replicas
    autoscaler: AutoscalerConfig = dataclasses.field(
        default_factory=AutoscalerConfig)

    @property
    def n_devices(self) -> int:
        return self.n_replicas * self.tp * self.pp    # Eq. 2, per site

    @property
    def max_replicas(self) -> int:
        """Replica-list size the runtimes allocate: the autoscaler's
        ceiling when enabled, else the fixed replica count."""
        return (max(self.autoscaler.max_replicas, self.n_replicas)
                if self.autoscaler.enabled else self.n_replicas)


@dataclasses.dataclass
class FleetConfig:
    """The whole deployment: sites + shared workload + router policy."""
    model: ModelConfig
    sites: Tuple[SiteConfig, ...]
    workload: WorkloadConfig = dataclasses.field(
        default_factory=WorkloadConfig)
    router: str = "round_robin"       # repro.fleet.routing.ROUTERS key
    router_params: Dict[str, float] = dataclasses.field(default_factory=dict)
    # temporal admission gate ahead of the router (repro.schedule);
    # default immediate == the gate is a no-op
    schedule: ScheduleConfig = dataclasses.field(
        default_factory=ScheduleConfig)
    execmodel: ExecModelConfig = dataclasses.field(
        default_factory=ExecModelConfig)
    auto_kv_budget: bool = True
    pue: float = 1.2
    resolution_s: float = 60.0        # Eq. 5 bin width for site profiles
    # fixed co-sim horizon (s): pins the idle-energy accounting window
    # so scenarios differing only in admission policy charge identical
    # idle carbon and stay comparable; None = size from the stage logs
    horizon_s: Optional[float] = None
    # day-scale epoch segmentation + fluid/request hybrid evaluation
    # (repro.fleet.day); None = the request-level simulation path
    day: Optional[DayConfig] = None

    def __post_init__(self):
        self.sites = tuple(self.sites)
        if not self.sites:
            raise ValueError("a fleet needs at least one site")
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            raise ValueError(f"site names must be unique, got {names}")

    @property
    def n_devices(self) -> int:
        return sum(s.n_devices for s in self.sites)

    @property
    def device(self) -> str:
        """Joined device mix, for report metadata."""
        return "+".join(dict.fromkeys(s.device for s in self.sites))
