"""Day-scale fleet simulation: epoch-segmented fluid/request hybrid.

``run_fleet_day`` evaluates a whole day (millions of requests) by
partitioning it into fixed epochs (``repro.sim.hybrid``) and driving
each epoch either through the exact continuous-batching event loop or
through the fluid pilot-and-tile approximation — per site, with an
epoch-granular replica-autoscaling plan (``repro.fleet.autoscale``)
and epoch-granular carbon-aware deferral (``repro.schedule.epochs``).

Determinism contract: workload generation, deferral, site assignment,
the replica plan and the epoch classification are all array passes
over the ``ArrivalStream`` that never look at simulation output, so
the ``hybrid`` and ``event_loop`` day modes plan identical epochs —
an epoch the planner marks exact is then evaluated by the identical
code path on identical inputs in both modes and agrees bit-for-bit.

Energy convention (day accounting): stage rows are (replica,
pipeline-stage) grains, so active energy charges each row for its
``tp`` devices; idle energy is the powered-device integral (active +
warm replicas from the autoscale plan) minus busy device-seconds, at
``p_idle`` — warm spares and scale-up latency thus surface directly
in Eq. 2-5 terms. The co-sim load profile bins active stage energy
plus that idle fill at the fleet resolution and runs the usual
solar/battery microgrid scan per site.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.cosim import run_cosim
from repro.core.datasets import ci_trace_signal, solar_signal
from repro.core.microgrid import BatteryConfig, MicrogridConfig
from repro.core.power import DEVICES, PowerModel
from repro.core.signals import Signal
from repro.fleet.autoscale import plan_replicas
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.routing import RoundRobinRouter
from repro.fleet.simulation import LoopSite, drive
from repro.obs.spans import PROFILER
from repro.schedule import fleet_ci_forecast, make_forecaster
from repro.schedule.epochs import epoch_deferral
from repro.sim.hybrid import (EXACT, DayConfig, Epoch, EpochEval,
                              concat_traces, epoch_bounds, evaluate_epoch,
                              plan_epochs, weighted_percentile)
from repro.sim.simulator import kv_budget_tokens
from repro.sim.trace import StageTrace
from repro.workloads.stream import ArrivalStream, generate_stream


@dataclasses.dataclass
class DaySiteResult:
    site: SiteConfig
    stream: ArrivalStream              # this site's slice, ready-sorted
    epochs: List[Epoch]
    evals: List[EpochEval]
    trace: StageTrace                  # concatenated (synthetic + exact)
    energy: Dict[str, float]           # active/idle split, device-hours
    cosim: Dict[str, float]
    avg_ci: float
    carbon_active_g: float
    carbon_idle_g: float
    autoscale: Dict[str, float]

    @property
    def carbon_operational_g(self) -> float:
        return self.cosim["net_emissions_kg"] * 1000.0


@dataclasses.dataclass
class DayResult:
    cfg: FleetConfig
    bounds: np.ndarray
    sites: List[DaySiteResult]
    admission_stats: Dict[str, float]
    duration_s: float

    def summary(self) -> Dict[str, float]:
        day = self.cfg.day
        n_req = sum(len(s.stream) for s in self.sites)
        n_sim = sum(ev.n_simulated for s in self.sites for ev in s.evals)
        evals = [ev for s in self.sites for ev in s.evals]
        n_exact = sum(1 for ev in evals if ev.epoch.planned == EXACT)
        reasons: Dict[str, int] = {}
        for ev in evals:
            if ev.epoch.planned == EXACT:
                r = ev.epoch.reason
                reasons[r] = reasons.get(r, 0) + 1
        act_wh = sum(s.energy["active_wh"] for s in self.sites)
        idle_wh = sum(s.energy["idle_wh"] for s in self.sites)
        op_g = sum(s.carbon_operational_g for s in self.sites)
        nosolar_g = sum(s.cosim["total_emissions_nosolar_kg"] * 1000.0
                        for s in self.sites)
        gpu_h = sum(s.energy["powered_dev_s"] for s in self.sites) / 3600.0
        emb_g = sum(s.energy["powered_dev_s"] / 3600.0
                    * DEVICES[s.site.device].embodied_kg_per_hour * 1000.0
                    for s in self.sites)
        ttft = np.concatenate([ev.ttft_s for ev in evals]) \
            if evals else np.empty(0)
        e2e = np.concatenate([ev.e2e_s for ev in evals]) \
            if evals else np.empty(0)
        w_t = np.concatenate([np.full(len(ev.ttft_s), ev.weight)
                              for ev in evals]) if evals else np.empty(0)
        w_e = np.concatenate([np.full(len(ev.e2e_s), ev.weight)
                              for ev in evals]) if evals else np.empty(0)
        deferrable = sum(int(s.stream.deferrable.sum())
                         for s in self.sites)
        out: Dict[str, float] = {
            "n_requests": float(n_req),
            "n_simulated": float(n_sim),
            "sim_fraction": n_sim / max(n_req, 1),
            "n_epochs": float(len(self.bounds) - 1),
            "n_exact_epochs": float(n_exact),
            "n_fluid_epochs": float(len(evals) - n_exact),
            "duration_s": self.duration_s,
            "throughput_qps": n_req / max(self.duration_s, 1e-9),
            "energy_wh": act_wh + idle_wh,
            "energy_active_wh": act_wh,
            "energy_idle_wh": idle_wh,
            "gpu_hours": gpu_h,
            "carbon_active_g": sum(s.carbon_active_g for s in self.sites),
            "carbon_idle_g": sum(s.carbon_idle_g for s in self.sites),
            "carbon_operational_g": op_g,
            "carbon_nosolar_g": nosolar_g,
            "carbon_offset_pct": 100.0 * (nosolar_g - op_g)
            / max(nosolar_g, 1e-9),
            "carbon_embodied_g": emb_g,
            "carbon_total_g": op_g + emb_g,
            "ttft_p50_s": weighted_percentile(ttft, w_t, 50),
            "ttft_p99_s": weighted_percentile(ttft, w_t, 99),
            "e2e_p50_s": weighted_percentile(e2e, w_e, 50),
            "e2e_p99_s": weighted_percentile(e2e, w_e, 99),
            "deferrable_frac_actual": deferrable / max(n_req, 1),
            "scale_ups": sum(s.autoscale.get("scale_ups", 0.0)
                             for s in self.sites),
            "scale_downs": sum(s.autoscale.get("scale_downs", 0.0)
                               for s in self.sites),
            "replica_peak": float(max(
                (ep.n_replicas for s in self.sites for ep in s.epochs),
                default=0)),
            "epoch_s": day.epoch_s,
            **{f"n_exact_{k}": float(v) for k, v in sorted(reasons.items())},
            **self.admission_stats,
        }
        # per-epoch fleet columns: what the day-smoke CI job compares
        # between the hybrid and event_loop modes (planned-exact epochs
        # bit-for-bit, planned-fluid epochs within tolerance)
        n_ep = len(self.bounds) - 1
        for e in range(n_ep):
            evs = [s.evals[e] for s in self.sites if e < len(s.evals)]
            tag = f"e{e:03d}"
            # fraction of sites that planned this epoch exact: 1.0 =>
            # the whole fleet epoch is bit-for-bit comparable across
            # day modes, anything else compares at fluid tolerance
            out[f"{tag}_exact"] = (sum(
                1.0 for ev in evs if ev.epoch.planned == EXACT)
                / max(len(evs), 1))
            out[f"{tag}_n"] = float(sum(ev.n_requests for ev in evs))
            out[f"{tag}_energy_wh"] = sum(
                s.energy["epoch_active_wh"][e]
                + s.energy["epoch_idle_wh"][e] for s in self.sites)
            out[f"{tag}_carbon_g"] = sum(
                s.energy["epoch_carbon_g"][e] for s in self.sites)
            tt = np.concatenate([ev.ttft_s for ev in evs]) \
                if evs else np.empty(0)
            ww = np.concatenate([np.full(len(ev.ttft_s), ev.weight)
                                 for ev in evs]) if evs else np.empty(0)
            out[f"{tag}_ttft_p99_s"] = weighted_percentile(tt, ww, 99)
        for s in self.sites:
            p = s.site.name
            out[f"{p}_n_requests"] = float(len(s.stream))
            out[f"{p}_energy_wh"] = (s.energy["active_wh"]
                                     + s.energy["idle_wh"])
            out[f"{p}_carbon_g"] = s.carbon_operational_g
            out[f"{p}_carbon_active_g"] = s.carbon_active_g
            out[f"{p}_avg_ci"] = s.avg_ci
            out[f"{p}_renewable_share_pct"] = \
                s.cosim["renewable_share_pct"]
        return {k: float(v) for k, v in out.items()}


def _assign_sites(cfg: FleetConfig, stream: ArrivalStream,
                  bounds: np.ndarray, cis: List[Signal],
                  caps_tok_per_s: List[float]) -> np.ndarray:
    """Array-pass site assignment (the day analogue of FleetRouter).

    ``round_robin``/``least_loaded`` interleave rows across sites;
    ``carbon_greedy``/``carbon_slo`` assign per epoch: each epoch's
    rows fill the lowest-CI site up to its capacity share, spilling to
    the next-cheapest (the SLO/capacity bound is the per-epoch token
    budget), so load follows clean grids without saturating them.
    """
    n = len(stream)
    n_sites = len(cfg.sites)
    if cfg.router in ("round_robin", "least_loaded") or n_sites == 1:
        return np.arange(n, dtype=np.int64) % n_sites
    assign = np.empty(n, np.int64)
    order = np.argsort(stream.ready_s, kind="stable")
    ready = stream.ready_s[order]
    tokens = stream.tokens[order].astype(np.float64)
    edges = np.searchsorted(ready, bounds, side="left")
    centers = 0.5 * (bounds[:-1] + bounds[1:])
    for e in range(len(bounds) - 1):
        lo, hi = int(edges[e]), int(edges[e + 1])
        if hi <= lo:
            continue
        dt = bounds[e + 1] - bounds[e]
        rank = sorted(range(n_sites),
                      key=lambda i: (float(cis[i].at(centers[e])), i))
        cum = np.cumsum(tokens[lo:hi])
        sl = np.empty(hi - lo, np.int64)
        sl[:] = rank[-1]               # overflow lands on the last site
        used = 0.0
        start = 0
        for i in rank[:-1]:
            budget = caps_tok_per_s[i] * dt
            cut = int(np.searchsorted(cum, used + budget, side="right"))
            sl[start:cut] = i
            if cut >= hi - lo:
                start = cut
                break
            used = float(cum[cut - 1]) if cut > 0 else used
            start = cut
        if start < hi - lo:
            sl[start:] = rank[-1]
        assign[order[lo:hi]] = sl
    return assign


def _run_site_day(cfg: FleetConfig, site: SiteConfig,
                  sub: ArrivalStream, bounds: np.ndarray,
                  drain_counts: np.ndarray, ci: Signal,
                  probe=None) -> DaySiteResult:
    """``probe`` is already site-tagged (``SiteIndexProbe``) — every
    hook here reports local site 0 and the wrapper re-tags."""
    from repro.sim.execmodel import cached_execution_model

    day = cfg.day
    device = DEVICES[site.device]
    sched = site.scheduler
    if cfg.auto_kv_budget:
        budget = kv_budget_tokens(cfg.model, device, site.tp, site.pp)
        if budget <= 0:
            raise ValueError(
                f"{cfg.model.name} does not fit {site.device} at "
                f"TP={site.tp} PP={site.pp} (site {site.name})")
        sched = dataclasses.replace(sched, kv_budget_tokens=budget)
    em = cached_execution_model(cfg.model, site.device, site.tp,
                                site.pp, cfg.execmodel)
    asc = site.autoscaler
    cap = asc.tokens_per_s

    # predicted per-epoch demand -> replica plan (deterministic, no
    # simulation output involved: both day modes plan identically)
    n_ep = len(bounds) - 1
    counts = sub.counts(bounds).astype(np.float64)
    tok_sums = np.zeros(n_ep)
    if len(sub):
        np.add.at(tok_sums, np.clip(
            np.searchsorted(bounds, sub.ready_s, side="right") - 1,
            0, n_ep - 1), sub.tokens.astype(np.float64))
    util1 = tok_sums / np.maximum(np.diff(bounds), 1e-9) / max(cap, 1e-9)
    ci_mean = np.asarray(ci.at(0.5 * (bounds[:-1] + bounds[1:])),
                         np.float64)
    with PROFILER.span("day.plan"):
        if asc.enabled:
            replica_plan, warm_plan, asc_stats = plan_replicas(
                asc, util1, ci_mean, site.n_replicas)
        else:
            replica_plan = np.full(n_ep, site.n_replicas, int)
            warm_plan = np.zeros(n_ep, int)
            asc_stats = {}

    # The saturation check gets a model-derived capacity floor: the
    # autoscaler's tokens_per_s is a configured estimate, and when it
    # overstates what the roofline can actually serve, a queue-
    # saturated epoch would be misplanned as fluid (the pilot tiles a
    # growing queue and loses the latency tail). The autoscaler's own
    # replica planning above stays on the configured estimate.
    if len(sub):
        cap_model = em.replica_tokens_per_s(
            sched.batch_cap, sched.kv_budget_tokens,
            float(np.mean(sub.prefill_tokens)),
            float(np.mean(sub.decode_tokens)))
    else:
        cap_model = cap
    with PROFILER.span("day.plan"):
        epochs = plan_epochs(sub, bounds, day, cap, replica_plan,
                             warm_plan=warm_plan,
                             scale_latency_s=asc.scale_up_latency_s,
                             drain_counts=drain_counts,
                             sat_tokens_per_s=min(cap, cap_model))

    def run_window(epoch: Epoch, lo: int, hi: int):
        reqs = sub.to_requests(lo, hi)
        router = RoundRobinRouter(epoch.n_replicas, sched)
        ls = LoopSite(router, em, site.pp)
        for k in range(epoch.n_replicas):
            ls.clocks[k] = epoch.t0
        if epoch.cold_from is not None:
            for k in range(epoch.cold_from, epoch.n_replicas):
                ls.clocks[k] = epoch.t0 + epoch.scale_latency_s
        drive([ls], ls.add, reqs, probe=probe)
        return ls.stage_log(), reqs

    force_exact = day.mode == "event_loop"
    with PROFILER.span("day.epoch_eval"):
        evals = [evaluate_epoch(ep, sub, day, run_window,
                                force_exact=force_exact, probe=probe)
                 for ep in epochs]
    trace = concat_traces([ev.trace for ev in evals])

    # ---- per-replica energy accounting (see module docstring) ----
    pm = PowerModel(site.device)
    pue = cfg.pue
    tp = site.tp
    dpr = site.tp * site.pp            # devices per replica
    row_p = np.asarray(pm.power(trace.mfu), np.float64)
    row_wh = row_p * trace.dur_s * tp * pue / 3600.0
    t_end = max(float(bounds[-1]), trace.total_duration())
    dts = np.diff(bounds).copy()
    if n_ep:
        dts[-1] += t_end - float(bounds[-1])
    powered = (replica_plan + warm_plan) * dpr
    # charge each row to the epoch that *produced* it, not its start
    # bin: an exact epoch's service can spill past the boundary, and
    # attributing the spill to the next epoch would break the
    # bit-for-bit hybrid==event_loop agreement on planned-exact epochs
    # (fluid tiling clips at the boundary, exact runs don't)
    ep_idx = np.concatenate(
        [np.full(len(ev.trace), ev.epoch.index, np.int64)
         for ev in evals]) if evals else np.empty(0, np.int64)
    ep_active_wh = np.zeros(n_ep)
    np.add.at(ep_active_wh, ep_idx, row_wh)
    ep_busy_dev_s = np.zeros(n_ep)
    np.add.at(ep_busy_dev_s, ep_idx, trace.dur_s * tp)
    ep_idle_dev_s = np.maximum(powered * dts - ep_busy_dev_s, 0.0)
    ep_idle_wh = pm.dev.p_idle * ep_idle_dev_s * pue / 3600.0
    # per-stage Eq. 4 attribution + CI-integrated idle carbon
    ci_rows = np.asarray(ci.at(trace.start_s), np.float64)
    ep_carbon_act = np.zeros(n_ep)
    np.add.at(ep_carbon_act, ep_idx, row_wh * ci_rows / 1000.0)
    ep_carbon_idle = ep_idle_wh * ci_mean / 1000.0
    energy = {
        "active_wh": float(ep_active_wh.sum()),
        "idle_wh": float(ep_idle_wh.sum()),
        "busy_dev_s": float(ep_busy_dev_s.sum()),
        "powered_dev_s": float((powered * dts).sum()),
        "epoch_active_wh": ep_active_wh,
        "epoch_idle_wh": ep_idle_wh,
        "epoch_carbon_g": ep_carbon_act + ep_carbon_idle,
    }

    # ---- Eq. 5 load profile + microgrid co-sim ----
    res_s = cfg.resolution_s
    n_bins = max(1, int(np.ceil(t_end / res_s)))
    times = np.arange(n_bins) * res_s
    bin_idx = np.clip((trace.start_s / res_s).astype(int), 0, n_bins - 1)
    act_ws = np.zeros(n_bins)
    np.add.at(act_ws, bin_idx, row_p * trace.dur_s * tp)
    busy_dev = np.zeros(n_bins)
    np.add.at(busy_dev, bin_idx, trace.dur_s * tp)
    dev_bins = powered[np.clip(np.searchsorted(bounds, times,
                                               side="right") - 1,
                               0, n_ep - 1)].astype(np.float64)
    idle_dev = np.maximum(dev_bins * res_s - busy_dev, 0.0)
    load = Signal(times, (act_ws + pm.dev.p_idle * idle_dev)
                  / res_s * pue, interp="previous")
    solar = solar_signal(max(t_end / 3600.0, 0.02),
                         capacity_w=site.solar_capacity_w,
                         seed=site.solar_seed,
                         cloudiness=site.cloudiness, step_s=res_s)
    grid_cfg = MicrogridConfig(
        battery=BatteryConfig(capacity_wh=site.battery_capacity_wh,
                              soc_init=site.soc_init,
                              soc_min=site.soc_min,
                              soc_max=site.soc_max),
        step_s=res_s)
    with PROFILER.span("day.cosim"):
        cos = run_cosim(load, solar, ci, grid_cfg)

    if probe is not None:
        # powered devices step at epoch starts (the autoscale plan),
        # not at in-drive scale events — day replica counts are planned
        probe.on_requests(sub.arrival_s, sub.ready_s)
        probe.on_site_rollup(
            site=0, name=site.name, trace=trace, device=site.device,
            row_devices=tp, pue=pue, ci=ci,
            device_signal=(bounds[:-1], powered.astype(np.float64)),
            t_end_s=t_end,
            energy_wh=float(ep_active_wh.sum()),
            idle_energy_wh=float(ep_idle_wh.sum()),
            carbon_active_g=float(ep_carbon_act.sum()),
            carbon_idle_g=float(ep_carbon_idle.sum()),
            cosim=dict(cos.metrics), load=load)

    return DaySiteResult(
        site=site, stream=sub, epochs=epochs, evals=evals, trace=trace,
        energy=energy, cosim=dict(cos.metrics),
        avg_ci=float(np.mean(ci.at(times))),
        carbon_active_g=float(ep_carbon_act.sum()),
        carbon_idle_g=float(ep_carbon_idle.sum()),
        autoscale=asc_stats)


def run_fleet_day(cfg: FleetConfig, probe=None) -> DayResult:
    """Simulate a whole day of the fleet under ``cfg.day``.

    ``probe`` (``repro.obs.Probe``) observes each site's epoch
    evaluations, event-stepped stages and the per-site Eq. 1-5 rollup;
    probe-off runs are bitwise identical."""
    day: Optional[DayConfig] = cfg.day
    if day is None:
        raise ValueError("run_fleet_day needs cfg.day (a DayConfig)")
    with PROFILER.span("day.workload"):
        stream = generate_stream(cfg.workload)
    wl = cfg.workload
    defer_slack = (wl.deferrable_deadline_s
                   if wl.deferrable_frac > 0.0 else 0.0)
    t_last = float(stream.arrival_s[-1]) if len(stream) else day.epoch_s
    bounds = epoch_bounds(t_last + defer_slack, day.epoch_s)
    horizon_h = float(bounds[-1]) / 3600.0 * 1.1 + 0.5
    cis = [ci_trace_signal(s.ci_trace, horizon_h) for s in cfg.sites]

    # ---- epoch-granular carbon-aware deferral (repro.schedule) ----
    sched = cfg.schedule
    adm_stats = {"n_deferred": 0.0, "deferral_mean_s": 0.0,
                 "deferral_max_s": 0.0}
    drain = np.zeros(len(bounds) - 1)
    if sched.policy != "immediate" and wl.deferrable_frac > 0.0:
        with PROFILER.span("day.admission"):
            forecaster = make_forecaster(sched.forecaster,
                                         **sched.forecaster_params)
            forecast = fleet_ci_forecast(forecaster, cis,
                                         stat=sched.ci_stat)
            drain, adm_stats = epoch_deferral(
                stream, bounds, forecast,
                margin=float(sched.policy_params.get("margin", 0.02)),
                service_margin_s=float(
                    sched.policy_params.get("service_margin_s", 120.0)))

    # trim trailing all-empty epochs (deferral slack the gate never
    # used) so idle accounting doesn't charge hours of dead air
    sorted_all = stream.sorted_by_ready()
    counts = sorted_all.counts(bounds)
    last_busy = int(np.max(np.nonzero(counts)[0])) if counts.any() else 0
    bounds = bounds[:last_busy + 2]
    drain = drain[:last_busy + 1]

    caps = [s.autoscaler.tokens_per_s
            * (s.autoscaler.max_replicas if s.autoscaler.enabled
               else s.n_replicas) * s.autoscaler.target_util
            for s in cfg.sites]
    assign = _assign_sites(cfg, stream, bounds, cis, caps)

    sites_out = []
    for i, site in enumerate(cfg.sites):
        sub = stream.take(np.nonzero(assign == i)[0]).sorted_by_ready()
        released = sub.ready_s > sub.arrival_s
        site_drain = np.zeros(len(bounds) - 1)
        if released.any():
            np.add.at(site_drain, np.clip(
                np.searchsorted(bounds, sub.ready_s[released],
                                side="right") - 1,
                0, len(bounds) - 2), 1.0)
        site_probe = None
        if probe is not None:
            from repro.obs.probe import SiteIndexProbe
            site_probe = SiteIndexProbe(probe, i)
        sites_out.append(_run_site_day(cfg, site, sub, bounds,
                                       site_drain, cis[i],
                                       probe=site_probe))

    duration = max([s.trace.total_duration() for s in sites_out]
                   + [float(bounds[-1])])
    return DayResult(cfg=cfg, bounds=bounds, sites=sites_out,
                     admission_stats=adm_stats, duration_s=duration)
