"""Pluggable request routing, at two levels.

**Replica level** — ``RoundRobinRouter`` spreads requests over the
replica schedulers inside one site (extracted from
``repro.sim.scheduler``; the single-site simulator is the trivial
fleet and keeps using it unchanged).

**Site level** — ``FleetRouter`` policies choose which site serves
each arriving request, inside the fleet simulation loop:

  - ``round_robin``: cycle through sites.
  - ``least_loaded``: join-shortest-queue on outstanding tokens.
  - ``carbon_greedy``: geo-route to the lowest-CI site with the
    migration-penalty semantics of ``repro.core.policies.multi_region``
    applied at per-request granularity — the fleet "current" site only
    switches when the CI gap, over the expected dwell at an estimated
    per-request energy, amortizes the migration penalty.
  - ``carbon_slo``: latency-constrained geo-routing — the min-CI site
    whose predicted queue delay (outstanding tokens over an estimated
    service rate) stays under the request's SLO; least-loaded fallback
    when no site qualifies.

Site routers see live site state through a small protocol implemented
by the fleet simulation's site runtimes:

  site.outstanding_tokens() -> int   queued + in-flight token work
  site.outstanding_requests() -> int queued + running request count
  site.ci_at(t_s) -> float           grid CI (gCO2/kWh) at sim time t
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

if TYPE_CHECKING:   # avoid import cycle with repro.sim at module load
    from repro.sim.requests import Request
    from repro.sim.scheduler import SchedulerConfig


# --------------------------------------------------------------------------
# replica-level (within one site)
# --------------------------------------------------------------------------

class RoundRobinRouter:
    """Round-robin over a site's replica schedulers."""

    def __init__(self, n_replicas: int, cfg: "SchedulerConfig"):
        from repro.sim.scheduler import ReplicaScheduler
        self.replicas = [ReplicaScheduler(cfg) for _ in range(n_replicas)]
        self._next = 0

    def route(self, req: "Request") -> int:
        """Returns the chosen replica index (the event loop uses it to
        fast-forward idle replicas to the request's arrival)."""
        target = self._next
        self.replicas[target].add(req)
        self._next = (target + 1) % len(self.replicas)
        return target


# --------------------------------------------------------------------------
# site-level (across the fleet)
# --------------------------------------------------------------------------

class FleetRouter:
    """Chooses the site index serving each arriving request."""

    name = "base"

    def choose(self, req: "Request", t_s: float, sites: Sequence) -> int:
        raise NotImplementedError

    def stats(self) -> Dict[str, float]:
        return {}


class RoundRobinFleetRouter(FleetRouter):
    name = "round_robin"

    def __init__(self, n_sites: int):
        self._n = n_sites
        self._next = 0

    def choose(self, req, t_s, sites) -> int:
        i = self._next
        self._next = (self._next + 1) % self._n
        return i


class LeastLoadedFleetRouter(FleetRouter):
    """Join-shortest-queue on outstanding token work (ties: lower index)."""
    name = "least_loaded"

    def __init__(self, n_sites: int):
        self._n = n_sites

    def choose(self, req, t_s, sites) -> int:
        return min(range(self._n),
                   key=lambda i: (sites[i].outstanding_tokens(), i))


class CarbonGreedyFleetRouter(FleetRouter):
    """Greedy lowest-CI geo-routing with sticky migration.

    Per-request analogue of ``policies.multi_region``: the fleet keeps
    a current site and re-routes to the momentary lowest-CI site only
    when the CI gap amortizes ``migration_penalty_g`` over the expected
    dwell —

        (CI_cur - CI_best) * request_kwh_est * dwell_requests
            > migration_penalty_g                          [gCO2]

    ``load_cap_tokens`` (optional) bounds outstanding work per site:
    when the preferred site is saturated, the request overflows to the
    lowest-CI site with room (without committing the sticky choice).
    """
    name = "carbon_greedy"

    def __init__(self, n_sites: int, migration_penalty_g: float = 5.0,
                 request_kwh_est: float = 2e-4,
                 expected_dwell_requests: float = 256.0,
                 load_cap_tokens: Optional[float] = None):
        self._n = n_sites
        self.migration_penalty_g = migration_penalty_g
        self.request_kwh_est = request_kwh_est
        self.expected_dwell_requests = expected_dwell_requests
        self.load_cap_tokens = load_cap_tokens
        self._cur: Optional[int] = None
        self._switches = 0
        self._overflows = 0

    def _has_room(self, site) -> bool:
        return (self.load_cap_tokens is None
                or site.outstanding_tokens() < self.load_cap_tokens)

    def choose(self, req, t_s, sites) -> int:
        ci = [sites[i].ci_at(t_s) for i in range(self._n)]
        best = min(range(self._n), key=lambda i: (ci[i], i))
        if self._cur is None:
            self._cur = best
        elif best != self._cur:
            gap = ci[self._cur] - ci[best]
            amortized = (gap * self.request_kwh_est
                         * self.expected_dwell_requests)
            if amortized > self.migration_penalty_g:
                self._cur = best
                self._switches += 1
        if not self._has_room(sites[self._cur]):
            with_room = [i for i in sorted(range(self._n),
                                           key=lambda i: (ci[i], i))
                         if self._has_room(sites[i])]
            if with_room:
                self._overflows += 1
                return with_room[0]
        return self._cur

    def stats(self) -> Dict[str, float]:
        return {"switches": float(self._switches),
                "overflows": float(self._overflows)}


class CarbonSloFleetRouter(FleetRouter):
    """SLO-bounded carbon routing (the ROADMAP's latency-constrained
    carbon_greedy variant).

    Each site's queue delay is predicted from the O(1) queue-pressure
    counter: ``outstanding_tokens / tokens_per_s`` (a deliberately
    coarse M/D/1-style estimate — the counter is exact, the service
    rate is the knob). Candidates are the sites whose predicted delay
    stays under the request's SLO (``Request.slo_s``, falling back to
    ``default_slo_s`` for untagged/deferrable work); among them the
    lowest-CI site wins. When no site qualifies the router degrades to
    least-loaded — latency first, carbon second.
    """
    name = "carbon_slo"

    def __init__(self, n_sites: int, default_slo_s: float = 30.0,
                 tokens_per_s: float = 4000.0):
        self._n = n_sites
        self.default_slo_s = default_slo_s
        self.tokens_per_s = max(tokens_per_s, 1e-9)
        self._fallbacks = 0

    def _slo(self, req) -> float:
        slo = getattr(req, "slo_s", math.inf) if req is not None \
            else math.inf
        return slo if math.isfinite(slo) else self.default_slo_s

    def choose(self, req, t_s, sites) -> int:
        slo = self._slo(req)
        delays = [sites[i].outstanding_tokens() / self.tokens_per_s
                  for i in range(self._n)]
        ok = [i for i in range(self._n) if delays[i] <= slo]
        if not ok:
            self._fallbacks += 1
            return min(range(self._n),
                       key=lambda i: (sites[i].outstanding_tokens(), i))
        return min(ok, key=lambda i: (sites[i].ci_at(t_s), i))

    def stats(self) -> Dict[str, float]:
        return {"slo_fallbacks": float(self._fallbacks)}


ROUTERS = {
    "round_robin": RoundRobinFleetRouter,
    "least_loaded": LeastLoadedFleetRouter,
    "carbon_greedy": CarbonGreedyFleetRouter,
    "carbon_slo": CarbonSloFleetRouter,
}


def make_router(name: str, n_sites: int, **params) -> FleetRouter:
    if name not in ROUTERS:
        raise KeyError(f"unknown router {name!r}; have {sorted(ROUTERS)}")
    return ROUTERS[name](n_sites, **params)
