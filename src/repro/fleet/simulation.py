"""Multi-site fleet simulation driver.

Generalizes the single-site event loop of ``repro.sim.simulator`` to a
heterogeneous fleet: every site runs its own continuous-batching
simulation (reusing ``ReplicaScheduler`` + ``ExecutionModel``), while a
``FleetRouter`` assigns each request to a site *at arrival time*
against the site's live carbon-intensity signal. Afterwards each
site's stage log becomes a load profile via the Eq. 5 aggregation
(``signals.aggregate_power``), runs through that site's microgrid
co-simulation (solar + battery, zero-capacity = pure grid), and the
results roll up into a fleet-level energy/carbon/latency report.

Energy semantics: per-site ``energy`` is the paper's Eq. 2-3 active
(stage-time) energy; the co-sim metrics additionally charge idle power
for bins where a site sits idle while the fleet is still serving.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.carbon import stage_attributed_carbon
from repro.core.cosim import run_cosim, trace_to_load_signal
from repro.core.datasets import ci_trace_signal, solar_signal
from repro.core.energy import EnergyReport, operational_energy_trace
from repro.core.microgrid import BatteryConfig, MicrogridConfig
from repro.core.power import DEVICES, PowerModel
from repro.core.signals import Signal
from repro.fleet.autoscale import ActiveSetRouter, ReplicaController
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.routing import RoundRobinRouter, make_router
from repro.schedule import (apply_admission, class_stats,
                            fleet_ci_forecast, make_admission,
                            make_forecaster)
from repro.sim.execmodel import ExecutionModel, cached_execution_model
from repro.sim.requests import Request, generate
from repro.sim.simulator import kv_budget_tokens, latency_stats
from repro.sim.trace import StageTrace, StageTraceBuilder


def _signal_horizon_h(requests: List[Request],
                      defer_slack_s: float = 0.0) -> float:
    """CI signals must cover every routing decision — those happen at
    request *release* times, which admission may push up to a deadline
    past the last arrival (``defer_slack_s`` bounds that from the
    workload config, since releases are assigned after the sites'
    signals exist). The post-sim co-sim regenerates longer traces if
    the service tail outruns this (the generators are prefix-stable in
    their seed)."""
    last_h = (max((r.arrival_s for r in requests), default=0.0)
              + defer_slack_s) / 3600.0
    return max(last_h * 1.1 + 0.5, 1.0)


class LoopSite:
    """One site's live state under the shared event loop ``drive``:
    a replica router, an execution model, per-replica clocks, and the
    stage log. ``run_simulation`` drives exactly one of these — the
    single-site simulator is the trivial fleet."""

    def __init__(self, replica_router, exec_model: ExecutionModel,
                 pp: int):
        self.replicas = replica_router
        self.exec_model = exec_model
        self.pp = pp
        self.clocks = [0.0] * len(replica_router.replicas)
        self.routed: List[Request] = []
        # incremental queue-pressure counter (total tokens of routed,
        # not-yet-finished requests) so per-request routing decisions
        # stay O(sites), not O(outstanding requests)
        self._outstanding_tokens = 0
        self.trace = StageTraceBuilder()
        # opt-in observability (repro.obs): the fleet driver points
        # these at its probe so the autoscale controller can report
        # transitions; None (default) keeps every hook dead
        self.probe = None
        self.site_index = 0

    def add(self, req: Request):
        """Route one request into the site. Replicas that were idle
        fast-forward to the request's ready time (its admission release,
        == arrival when no policy parked it): they cannot start earlier,
        and their stale clocks must not gate fleet-wide admission."""
        self.routed.append(req)
        self._outstanding_tokens += req.prefill_tokens + req.decode_tokens
        idle = {k for k, r in enumerate(self.replicas.replicas)
                if not r.has_work()}
        target = self.replicas.route(req)
        if target is None:          # router doesn't report its choice:
            bump = idle             # conservatively fast-forward all idle
        else:
            bump = {target} & idle
        for k in bump:
            self.clocks[k] = max(self.clocks[k], req.ready_s)

    def note_done(self, done: List[Request]):
        for r in done:
            self._outstanding_tokens -= r.prefill_tokens + r.decode_tokens

    def maybe_control(self, t_s: float) -> bool:
        """Autoscaling hook, polled by ``drive`` at processing events.
        Sites with a ``ReplicaController`` resize their active replica
        set here; the default site has none. Returns whether the
        active set changed (the loop then re-selects its event)."""
        return False

    def stage_log(self) -> StageTrace:
        return self.trace.build()


def drive(sites: List[LoopSite], route, requests: List[Request],
          max_sim_s: float = 10_000_000.0, probe=None) -> None:
    """THE continuous-batching event loop, shared by the single-site
    simulator and the fleet driver.

    ``route(req)`` assigns one arriving request to a site (calling
    ``LoopSite.add`` on its choice). Admission gating: a request is
    routed once its *ready* time — arrival, or the release an admission
    policy assigned (``repro.schedule``) — precedes the next
    *processing* event, the earliest clock among replicas with work
    (idle replicas don't hold admission back; ``LoopSite.add``
    fast-forwards them, so no request is ever served before it is
    ready).

    ``probe`` (``repro.obs.Probe``) observes committed stages; it is
    read-only and costs nothing when None — probe-off runs are bitwise
    identical to probe-attached ones (the neutrality contract).
    """
    pending = sorted(requests, key=lambda r: r.ready_s)
    pi = 0
    pairs = [(s, i) for s, st in enumerate(sites)
             for i in range(len(st.clocks))]
    stuck = set()       # replicas whose head-of-queue can never admit

    while True:
        candidates = [(s, i) for s, i in pairs if (s, i) not in stuck
                      and sites[s].replicas.replicas[i].has_work()]
        if candidates:
            s, i = min(candidates, key=lambda p: sites[p[0]].clocks[p[1]])
            t_event = sites[s].clocks[i]
        elif pi < len(pending):
            s, t_event = None, pending[pi].ready_s
        else:
            break

        if pi < len(pending) and pending[pi].ready_s <= t_event:
            while pi < len(pending) and pending[pi].ready_s <= t_event:
                route(pending[pi])
                pi += 1
            continue    # re-select: routed work may be an earlier event
        if s is None:
            continue

        st = sites[s]
        if st.maybe_control(t_event):
            continue    # active set changed: re-select the event
        rep = st.replicas.replicas[i]
        now = st.clocks[i]
        prefills, decodes = rep.next_batch()
        if not prefills and not decodes:
            # running empty and waiting blocked on this replica
            if pi < len(pending):
                st.clocks[i] = max(now, pending[pi].ready_s)
            else:
                # nothing will ever free this replica's KV budget;
                # park it instead of stalling the rest of the fleet
                stuck.add((s, i))
            continue

        # chunked prefill (Sarathi) yields mixed iterations: the chunk
        # token counts + offsets come from the scheduler (a chunk at
        # offset o re-reads o tokens of prior-chunk KV), and decodes of
        # already-prefilled sequences ride along in the same stage
        plens = list(rep.last_prefill_tokens)
        offs = list(rep.last_prefill_offsets)
        ctxs = [r.prefill_tokens + r.decoded for r in decodes]
        cost, npt, ndec, f_score, kv_rw = st.exec_model.stage_cost_scalar(
            plens, ctxs, offs)

        # one record per pipeline stage (replica-stage granularity)
        bs = len(prefills) + len(decodes)
        for ps in range(st.pp):
            st.trace.append(
                start_s=now + ps * cost.t_total / max(st.pp, 1),
                dur_s=cost.t_total, flops_mlp=cost.flops_mlp,
                flops_attn=cost.flops_attn, mfu=cost.mfu,
                n_prefill_tokens=npt,
                n_decode_tokens=ndec,
                replica=i * st.pp + ps, batch_size=bs,
                score_flops=f_score,
                kv_rw_bytes=kv_rw)

        if probe is not None:
            probe.on_stage(now, cost.t_total, s, i, rep, npt, ndec, bs)
        now += cost.t_total
        st.clocks[i] = now
        done = rep.complete_iteration(prefills, decodes, now)
        st.note_done(done)
        if probe is not None and done:
            probe.on_complete(now, s, i, done)
        if now > max_sim_s:
            break


class _SiteRuntime(LoopSite):
    """``LoopSite`` plus the fleet-only state: site config, grid CI
    signal, and the routing protocol the ``FleetRouter`` policies
    consume."""

    def __init__(self, cfg: FleetConfig, site: SiteConfig, horizon_h: float):
        self.site = site
        self.device = DEVICES[site.device]
        sched = site.scheduler
        if cfg.auto_kv_budget:
            budget = kv_budget_tokens(cfg.model, self.device, site.tp,
                                      site.pp)
            if budget <= 0:
                raise ValueError(
                    f"{cfg.model.name} does not fit {site.device} at "
                    f"TP={site.tp} PP={site.pp} (site {site.name})")
            sched = dataclasses.replace(sched, kv_budget_tokens=budget)
        self.controller = None
        if site.autoscaler.enabled:
            # allocate the ceiling up front (stable replica indices /
            # trace ids); the controller moves the active-set boundary
            router = ActiveSetRouter(site.max_replicas, sched,
                                     n_active=min(site.n_replicas,
                                                  site.max_replicas))
            self.controller = ReplicaController(site.autoscaler,
                                                site.n_replicas)
        else:
            router = RoundRobinRouter(site.n_replicas, sched)
        super().__init__(router,
                         cached_execution_model(cfg.model, site.device,
                                                site.tp, site.pp,
                                                cfg.execmodel),
                         site.pp)
        self.ci = ci_trace_signal(site.ci_trace, horizon_h)

    def maybe_control(self, t_s: float) -> bool:
        if self.controller is None:
            return False
        return self.controller.maybe_control(self, t_s)

    # ---- FleetRouter protocol ----
    def outstanding_tokens(self) -> int:
        """Total tokens of routed, not-yet-finished requests (O(1);
        maintained incrementally by add/note_done)."""
        return self._outstanding_tokens

    def outstanding_requests(self) -> int:
        return sum(len(rep.waiting) + len(rep.running)
                   for rep in self.replicas.replicas)

    def ci_at(self, t_s: float) -> float:
        return float(self.ci.at(t_s))


def _site_load_signal(stages: StageTrace, pm: PowerModel, n_devices: int,
                      pue: float, resolution_s: float, t_end_s: float,
                      device_signal=None) -> Signal:
    """The table2 Eq. 5 pipeline (``trace_to_load_signal``) padded
    onto the common fleet grid [0, t_end): bins outside this site's
    active span draw idle power while the fleet is still serving.

    ``device_signal`` — an optional ``(times, counts)`` step signal of
    *powered* devices from a replica autoscaler — replaces the fixed
    ``n_devices`` scale: each bin draws its per-device power times the
    devices actually powered then (cold replicas draw nothing, warm
    spares draw idle)."""
    n_bins = max(1, int(math.ceil(t_end_s / resolution_s)))
    times = np.arange(n_bins) * resolution_s
    if device_signal is not None:
        ts, counts = device_signal
        idx = np.clip(np.searchsorted(ts, times, side="right") - 1,
                      0, len(counts) - 1)
        devices = counts[idx].astype(np.float64)
    else:
        devices = np.full(n_bins, float(n_devices))
    vals = pm.dev.p_idle * devices * pue
    if len(stages.start_s):
        # per-device bin power, scaled by the live device count
        sig = trace_to_load_signal(stages, pm, n_devices=1, pue=1.0,
                                   resolution_s=resolution_s)
        off = int(round(sig.times[0] / resolution_s))
        n = min(len(sig.values), n_bins - off)
        if n > 0:
            vals[off:off + n] = (sig.values[:n] * devices[off:off + n]
                                 * pue)
    return Signal(times, vals, interp="previous")


@dataclasses.dataclass
class SiteResult:
    site: SiteConfig
    stages: StageTrace
    requests: List[Request]            # requests routed to this site
    energy: EnergyReport               # Eq. 2-3 active energy
    load: Signal                       # Eq. 5 profile (idle-filled)
    cosim: Dict[str, float]            # microgrid co-sim metrics
    avg_ci: float
    # request-attributable operational emissions: per-stage Eq. 2-3
    # energy x the live grid CI at each stage (no idle fill) — the
    # carbon that temporal/spatial scheduling actually moves, immune to
    # the Eq. 5 bin-quantization of the co-sim totals
    carbon_active_g: float = 0.0
    # replica-autoscaler counters (repro.fleet.autoscale); empty when
    # the site runs a fixed replica set
    autoscale: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def carbon_operational_g(self) -> float:
        """Net grid emissions after solar/battery (gCO2)."""
        return self.cosim["net_emissions_kg"] * 1000.0

    @property
    def carbon_embodied_g(self) -> float:
        dev = DEVICES[self.site.device]
        return self.energy.gpu_hours * dev.embodied_kg_per_hour * 1000.0


@dataclasses.dataclass
class FleetResult:
    cfg: FleetConfig
    sites: List[SiteResult]
    requests: List[Request]
    assignments: np.ndarray            # request rid -> site index
    router_stats: Dict[str, float]
    admission_stats: Dict[str, float]  # repro.schedule.apply_admission
    duration_s: float

    def summary(self) -> Dict[str, float]:
        """Fleet-total + per-site energy/carbon columns (tidy row)."""
        dur = sum(s.energy.duration_s for s in self.sites)
        energy_wh = sum(s.energy.energy_wh for s in self.sites)
        op_g = sum(s.carbon_operational_g for s in self.sites)
        nosolar_g = sum(s.cosim["total_emissions_nosolar_kg"] * 1000.0
                        for s in self.sites)
        emb_g = sum(s.carbon_embodied_g for s in self.sites)
        done = sum(1 for r in self.requests if r.t_done >= 0)
        out: Dict[str, float] = {
            "energy_wh": energy_wh,
            "energy_kwh": energy_wh / 1000.0,
            "avg_power_w": (sum(s.energy.avg_power_w * s.energy.duration_s
                                for s in self.sites) / max(dur, 1e-12)),
            "gpu_hours": sum(s.energy.gpu_hours for s in self.sites),
            "avg_mfu": (sum(s.energy.avg_mfu * s.energy.duration_s
                            for s in self.sites) / max(dur, 1e-12)),
            "duration_s": self.duration_s,
            "throughput_qps": done / max(self.duration_s, 1e-9),
            "carbon_operational_g": op_g,
            "carbon_active_g": sum(s.carbon_active_g for s in self.sites),
            "carbon_embodied_g": emb_g,
            "carbon_total_g": op_g + emb_g,
            "carbon_nosolar_g": nosolar_g,
            "carbon_offset_pct": 100.0 * (nosolar_g - op_g)
            / max(nosolar_g, 1e-9),
            "n_sites": float(len(self.sites)),
            "n_requests_done": float(done),
            "router_switches": self.router_stats.get("switches", 0.0),
            **latency_stats(self.requests),
            # per-workload-class latency/deferral columns (repro.schedule)
            **class_stats(self.requests),
            **self.admission_stats,
        }
        if any(s.autoscale for s in self.sites):
            # autoscaler columns appear only when a site scales, so
            # fixed-replica fleets keep their pre-autoscaler records
            # bit-for-bit (schema-bump pin)
            out["scale_ups"] = sum(s.autoscale.get("scale_ups", 0.0)
                                   for s in self.sites)
            out["scale_downs"] = sum(s.autoscale.get("scale_downs", 0.0)
                                     for s in self.sites)
        for s in self.sites:
            p = s.site.name
            out[f"{p}_n_requests"] = float(len(s.requests))
            out[f"{p}_energy_wh"] = s.energy.energy_wh
            out[f"{p}_carbon_g"] = s.carbon_operational_g
            out[f"{p}_carbon_active_g"] = s.carbon_active_g
            out[f"{p}_avg_ci"] = s.avg_ci
            out[f"{p}_renewable_share_pct"] = s.cosim["renewable_share_pct"]
        # plain floats only: numpy scalars would stringify through the
        # result cache's JSON encoding and break cached == fresh
        return {k: float(v) for k, v in out.items()}


def run_fleet_simulation(cfg: FleetConfig,
                         max_sim_s: float = 10_000_000.0,
                         probe=None) -> FleetResult:
    """``probe`` (``repro.obs.Probe``, optional) observes routing,
    stages, autoscaling and the per-site rollup; it never feeds back
    into the simulation (probe-off == probe-on, bitwise)."""
    requests = generate(cfg.workload)
    wl = cfg.workload
    defer_slack = (wl.deferrable_deadline_s
                   if wl.deferrable_frac > 0.0 else 0.0)
    horizon_h = _signal_horizon_h(requests, defer_slack)
    sites = [_SiteRuntime(cfg, s, horizon_h) for s in cfg.sites]

    # ---- temporal admission gate (repro.schedule), ahead of routing ----
    sched = cfg.schedule
    admission_stats: Dict[str, float] = {"n_deferred": 0.0,
                                         "backlog_peak": 0.0}
    if sched.policy != "immediate":
        forecaster = make_forecaster(sched.forecaster,
                                     **sched.forecaster_params)
        policy = make_admission(sched.policy, **sched.policy_params)
        forecast = fleet_ci_forecast(forecaster, [st.ci for st in sites],
                                     stat=sched.ci_stat)
        admission_stats = apply_admission(requests, policy, forecast)

    router = make_router(cfg.router, len(sites), **cfg.router_params)
    assignments = np.full(len(requests), -1, np.int32)

    if probe is not None:
        for idx, st in enumerate(sites):
            st.probe = probe
            st.site_index = idx

    def route(req: Request):
        # the geo decision sees each site's CI at the moment the
        # request becomes routable (its admission release; == arrival
        # under immediate admission)
        target = router.choose(req, req.ready_s, sites)
        assignments[req.rid] = target
        if probe is not None:
            probe.on_route(req.ready_s, req.rid, target)
        sites[target].add(req)

    drive(sites, route, requests, max_sim_s, probe=probe)

    # ---- roll up: Eq. 2-3 energy, Eq. 5 profiles, microgrid co-sim ----
    stage_logs = [st.stage_log() for st in sites]
    t_end = max([log.total_duration() for log in stage_logs]
                + [1.0, cfg.horizon_s or 0.0])
    if t_end / 3600.0 > horizon_h:
        # the service tail outran the arrival-sized CI traces: extend
        # them (prefix-stable generators, so the routed prefix is the
        # same trace the co-sim now integrates against)
        for st in sites:
            st.ci = ci_trace_signal(st.site.ci_trace,
                                    t_end / 3600.0 + 0.5)
    results = []
    for si, (st, log) in enumerate(zip(sites, stage_logs)):
        pm = PowerModel(st.site.device)
        energy = operational_energy_trace(log, pm,
                                          n_devices=st.site.n_devices,
                                          pue=cfg.pue)
        dev_sig = (st.controller.device_signal(
            t_end, st.site.tp * st.site.pp)
            if st.controller is not None else None)
        load = _site_load_signal(log, pm, st.site.n_devices, cfg.pue,
                                 cfg.resolution_s, t_end,
                                 device_signal=dev_sig)
        solar = solar_signal(max(t_end / 3600.0, 0.02),
                             capacity_w=st.site.solar_capacity_w,
                             seed=st.site.solar_seed,
                             cloudiness=st.site.cloudiness,
                             step_s=cfg.resolution_s)
        grid_cfg = MicrogridConfig(
            battery=BatteryConfig(
                capacity_wh=st.site.battery_capacity_wh,
                soc_init=st.site.soc_init, soc_min=st.site.soc_min,
                soc_max=st.site.soc_max),
            step_s=cfg.resolution_s)
        cos = run_cosim(load, solar, st.ci, grid_cfg)
        # stage-attributed carbon: same per-record energy convention as
        # operational_energy, weighted by the CI each stage ran under
        active_g = stage_attributed_carbon(log, pm, st.site.n_devices,
                                           cfg.pue, st.ci)
        results.append(SiteResult(
            site=st.site, stages=log, requests=st.routed, energy=energy,
            load=load, cosim=dict(cos.metrics),
            avg_ci=float(np.mean(st.ci.at(load.times))),
            carbon_active_g=active_g,
            autoscale=(st.controller.stats()
                       if st.controller is not None else {})))
        if probe is not None:
            probe.on_site_rollup(
                site=si, name=st.site.name, trace=log,
                device=st.site.device, row_devices=st.site.n_devices,
                pue=cfg.pue, ci=st.ci, total_devices=st.site.n_devices,
                device_signal=dev_sig, t_end_s=t_end,
                energy_wh=energy.energy_wh, carbon_active_g=active_g,
                cosim=dict(cos.metrics), load=load)

    if probe is not None:
        probe.on_requests(
            np.asarray([r.arrival_s for r in requests], np.float64),
            np.asarray([r.ready_s for r in requests], np.float64))

    return FleetResult(cfg=cfg, sites=results, requests=requests,
                       assignments=assignments,
                       router_stats=router.stats(),
                       admission_stats=admission_stats, duration_s=t_end)
