"""Pallas-TPU API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(~0.5.x); the pinned toolchain (0.4.x) only has the old name. Kernels
import the class from here so one build runs on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
