"""Flash-decoding Pallas TPU kernel: one query token vs. a long KV cache.

Grid: (batch, kv_heads, kv_blocks) with the kv dimension sequential, so
partial (max, denom, acc) accumulate in VMEM scratch — the TPU-native
analogue of GPU split-K flash decoding (TPU grids are sequential per
core; the LSE combine collapses into scratch accumulation). The query
block holds all G = H/KV query heads of one KV head so the (G, bk) score
matmul feeds the MXU. Per-sequence ``lengths`` mask the cache tail.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, bk: int, n_kv_blocks: int,
                   window: Optional[int]):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
    length = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if window is None:
        valid = kpos < length
    else:
        # ring cache: all W slots valid once the cache has wrapped
        valid = kpos < jnp.minimum(length, jnp.int32(window))
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            window: Optional[int] = None, bk: int = 512,
                            interpret: bool = False):
    """q: (B, KV, G, D); caches: (B, KV, W, D); lengths: (B,).
    Returns (B, KV, G, D)."""
    B, KV, G, D = q.shape
    W = k_cache.shape[2]
    bk = min(bk, W)
    pad = (-W) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (W + pad) // bk
    grid = (B, KV, nk)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(D), bk=bk, n_kv_blocks=nk,
        window=window)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out
