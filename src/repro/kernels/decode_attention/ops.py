"""Jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *,
                     window: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """Model layout: q (B, 1, H, D); caches (B, W, KV, D); lengths (B,).
    Returns (B, 1, H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, one, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qk = q.reshape(B, KV, G, D)
    kk = k_cache.transpose(0, 2, 1, 3)
    vk = v_cache.transpose(0, 2, 1, 3)
    out = decode_attention_pallas(qk, kk, vk, lengths, window=window,
                                  interpret=interpret)
    return out.reshape(B, 1, H, D)
