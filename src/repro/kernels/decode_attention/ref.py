"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_reference(q, k_cache, v_cache, lengths, *,
                               window: Optional[int] = None):
    """q: (B, KV, G, D); caches: (B, KV, W, D); lengths: (B,)."""
    B, KV, G, D = q.shape
    W = k_cache.shape[2]
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    slot = jnp.arange(W)[None, :]
    if window is None:
        valid = slot < lengths[:, None]
    else:
        valid = slot < jnp.minimum(lengths, window)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", w, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
