"""Flash-attention Pallas TPU kernel (prefill/training path).

Online-softmax attention with GQA, causal and sliding-window masking.
Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
innermost sequential ("arbitrary") dimension so the running max/denom/
accumulator live in VMEM scratch across kv steps. Block shapes are
128-aligned for the MXU; K/V blocks for a query head are fetched from its
GQA-mapped KV head via the BlockSpec index map.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  seq_len: int, bq: int, bk: int, n_kv_blocks: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (qpos < seq_len) & (kpos < seq_len)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KV, S, D). Returns (B, H, S, D).

    S is padded to block multiples internally; D should be MXU-friendly
    (the caller pads head_dim when needed).
    """
    B, H, S, D = q.shape
    KV = k.shape[1]
    assert H % KV == 0
    group = H // KV
    bq = min(bq, max(8, 1 << (S - 1).bit_length())) if S < bq else bq
    bk = min(bk, max(8, 1 << (S - 1).bit_length())) if S < bk else bk
    pad = (-S) % bq
    pad_k = (-S) % bk
    Sq, Sk = S + pad, S + pad_k
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(D), causal=causal,
        window=window, seq_len=S, bq=bq, bk=bk, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
