"""Jit'd public wrapper for the flash-attention kernel.

Selects interpret mode automatically off-TPU and handles head-dim padding
to MXU-friendly multiples.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """q: (B, S, H, D); k/v: (B, S, KV, D) (model layout). -> (B, S, H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, D = q.shape
    # kernel layout: heads-major
    qk = q.transpose(0, 2, 1, 3)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    # pad head_dim to a multiple of 128 for MXU alignment on TPU
    Dp = max(128, -(-D // 128) * 128) if not interpret else D
    if Dp != D:
        pad = ((0, 0), (0, 0), (0, 0), (0, Dp - D))
        qk, kk, vk = jnp.pad(qk, pad), jnp.pad(kk, pad), jnp.pad(vk, pad)
        # padded q/k dims change the softmax scale; rescale q to compensate
        qk = qk * (jnp.sqrt(Dp / D).astype(qk.dtype))
    out = flash_attention_pallas(qk, kk, vk, causal=causal, window=window,
                                 interpret=interpret)
    out = out[..., :D]
    return out.transpose(0, 2, 1, 3)
