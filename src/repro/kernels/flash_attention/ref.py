"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, H, S, D); k/v: (B, KV, S, D). Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    group = H // KV
    qg = q.reshape(B, KV, group, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / math.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", w, vf)
    return o.reshape(B, H, S, D).astype(q.dtype)
