from repro.kernels.gla_scan.ops import gla_scan
from repro.kernels.gla_scan.ref import gla_scan_reference

__all__ = ["gla_scan", "gla_scan_reference"]
