"""Chunked gated-linear-attention scan Pallas TPU kernel.

Serves RWKV6 (per-channel data-dependent decay + bonus ``u``) and
Mamba2/SSD (scalar-per-head decay). Grid: (batch, heads, chunks) with the
chunk dimension sequential; the recurrent state (K, V) is carried in VMEM
scratch across chunks. Per chunk:

  inter  = (q * exp(L_read)) @ S                         (MXU matmul)
  intra  = [q_t . k_j * exp(L_read_t - L_j)]_{j<=t} @ v   (pairwise-stable)
  S_new  = diag(exp(L_c)) S + (k * exp(L_c - L))^T v      (MXU matmul)

The pairwise log-difference form keeps strong decay (|log w| >> 1) from
overflowing — the same trick as the XLA path in
``repro.models.linear_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gla_kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref, s_scr,
                *, mode: str, chunk: int, n_chunks: int, has_u: bool):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    qb = q_ref[0, 0].astype(jnp.float32)   # (C, K)
    kb = k_ref[0, 0].astype(jnp.float32)   # (C, K)
    vb = v_ref[0, 0].astype(jnp.float32)   # (C, V)
    lw = lw_ref[0, 0].astype(jnp.float32)  # (C, K)

    L = jnp.cumsum(lw, axis=0)             # inclusive cumulative log decay
    Lc = L[-1:, :]                         # (1, K) total chunk decay
    if mode == "rwkv":
        L_read = L - lw                    # exclusive: state before token t
    else:
        L_read = L                         # inclusive: state after update

    state = s_scr[...]                     # (K, V)
    q_sc = qb * jnp.exp(L_read)
    o_inter = jax.lax.dot_general(q_sc, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # intra-chunk pairwise form: (C, C, K) log-difference tensor
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (t_idx > j_idx) if mode == "rwkv" else (t_idx >= j_idx)
    diff = L_read[:, None, :] - L[None, :, :]          # (C, C, K)
    w_pair = jnp.where(mask[:, :, None], jnp.exp(diff), 0.0)
    att = jnp.einsum("tk,jk,tjk->tj", qb, kb, w_pair)
    o_intra = jax.lax.dot_general(att, vb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    if has_u:
        u = u_ref[0].astype(jnp.float32)               # (K,)
        bonus = jnp.sum(qb * u[None, :] * kb, axis=1, keepdims=True)
        o_intra = o_intra + bonus * vb

    o_ref[0, 0] = (o_inter + o_intra).astype(o_ref.dtype)

    # state update
    k_dec = kb * jnp.exp(Lc - L)                       # (C, K)
    s_upd = jax.lax.dot_general(k_dec, vb, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s_scr[...] = jnp.exp(Lc).T * state + s_upd

    @pl.when(c == n_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0] = s_scr[...]


def gla_scan_pallas(q, k, v, log_w, u: Optional[jnp.ndarray] = None,
                    mode: str = "ssd", chunk: int = 128,
                    interpret: bool = False):
    """q/k/log_w: (B, H, T, K); v: (B, H, T, V); u: (H, K) or None.
    Returns (o (B, H, T, V), final_state (B, H, K, V))."""
    B, H, T, K = q.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        pz = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, pz), jnp.pad(k, pz), jnp.pad(v, pz)
        log_w = jnp.pad(log_w, pz)  # log w = 0 => no decay for padding
    n = (T + pad) // chunk
    grid = (B, H, n)
    has_u = u is not None
    if u is None:
        u = jnp.zeros((H, K), q.dtype)

    kernel = functools.partial(_gla_kernel, mode=mode, chunk=chunk,
                               n_chunks=n, has_u=has_u)

    o, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T + pad, V), v.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_w, u)
    return o[:, :, :T], s_final
