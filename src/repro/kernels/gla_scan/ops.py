"""Jit'd public wrapper for the GLA scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gla_scan.kernel import gla_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mode", "chunk", "interpret"))
def gla_scan(q, k, v, log_w, u: Optional[jnp.ndarray] = None,
             mode: str = "ssd", chunk: int = 128,
             interpret: Optional[bool] = None):
    """Model layout q/k/log_w: (B, T, H, K); v: (B, T, H, V).
    Returns (o (B, T, H, V), final_state (B, H, K, V))."""
    if interpret is None:
        interpret = not _on_tpu()
    tr = lambda x: x.transpose(0, 2, 1, 3)
    o, s = gla_scan_pallas(tr(q), tr(k), tr(v), tr(log_w), u=u, mode=mode,
                           chunk=chunk, interpret=interpret)
    return tr(o), s
