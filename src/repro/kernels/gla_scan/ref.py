"""Pure-jnp oracle for the GLA scan kernel: exact token-by-token scan."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.linear_attention import gla_reference


def gla_scan_reference(q, k, v, log_w, u: Optional[jnp.ndarray] = None,
                       mode: str = "ssd"):
    """Kernel layout (B, H, T, ·) -> delegates to the model-layer oracle
    (which uses (B, T, H, ·))."""
    tr = lambda x: x.transpose(0, 2, 1, 3)
    o, s = gla_reference(tr(q), tr(k), tr(v), tr(log_w), u=u, mode=mode)
    return tr(o), s
