import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

MUST be run as its own process (the XLA_FLAGS line above precedes any jax
import). Single cell:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod]

Orchestrate all cells (sequential subprocesses, resumable):

    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in results/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

from repro.obs.log import configure as configure_logging
from repro.obs.log import get_logger

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_log = get_logger("repro.launch.dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             attn_impl: str = "auto", out_path: Path = None,
             variant: str = "baseline", grad_accum=None) -> dict:
    import jax
    from repro.analysis.hlo import collective_bytes, program_stats
    from repro.configs import cell_is_runnable, get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_is_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "runnable": ok, "reason": reason, "attn_impl": attn_impl,
           "variant": variant}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    plan, fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh,
                                               attn_impl=attn_impl,
                                               variant=variant,
                                               grad_accum=grad_accum)
    jit_kwargs = dict(in_shardings=in_sh)
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    if shape.kind == "decode":
        jit_kwargs["donate_argnums"] = (2,)   # cache updated in place
    elif shape.kind == "train":
        jit_kwargs["donate_argnums"] = (0, 1)  # params + opt state
    with mesh:
        lowered = jax.jit(fn, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    # jax <= 0.4.x wraps the cost dict in a one-element list
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, default_trip=cfg.n_layers)
    stats = program_stats(hlo, default_trip=cfg.n_layers)

    rec.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
        "loop_aware": stats,
        "n_devices": len(jax.devices()),
        "hlo_chars": len(hlo),
    })
    if out_path is not None:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        # keep the HLO for §Perf iteration analysis (collectives, remat)
        (out_path.with_suffix(".hlo.txt")).write_text(hlo[:40_000_000])
    return rec


def orchestrate(multi_pod: bool, attn_impl: str, only_missing: bool = True,
                timeout: int = 3600):
    from repro.configs import all_cells
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    outdir = RESULTS / mesh_tag
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch, shape_name, ok, reason in all_cells():
        out_path = outdir / f"{arch}__{shape_name}.json"
        if only_missing and out_path.exists():
            rec = json.loads(out_path.read_text())
            if rec.get("runnable") is False or "compile_s" in rec or "error" not in rec:
                _log.info("[skip existing] %s %s", arch, shape_name)
                continue
        if not ok:
            out_path.write_text(json.dumps(
                {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                 "runnable": False, "reason": reason}, indent=1))
            _log.info("[skip n/a] %s %s: %s", arch, shape_name, reason)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name,
               "--attn-impl", attn_impl]
        if multi_pod:
            cmd.append("--multi-pod")
        _log.info("[run] %s %s (%s)", arch, shape_name, mesh_tag)
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                failures.append((arch, shape_name, r.stderr[-3000:]))
                out_path.write_text(json.dumps(
                    {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                     "runnable": True, "error": r.stderr[-3000:]}, indent=1))
                _log.warning("FAILED in %.0fs", time.time() - t0)
            else:
                _log.info("ok in %.0fs", time.time() - t0)
        except subprocess.TimeoutExpired:
            failures.append((arch, shape_name, "timeout"))
            _log.warning("TIMEOUT")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("-v", "--verbose", action="count", default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    configure_logging(verbosity=(-1 if args.quiet else args.verbose))

    if args.all:
        fails = orchestrate(args.multi_pod, args.attn_impl,
                            only_missing=not args.force)
        if fails:
            print(f"{len(fails)} failures:")
            for a, s, e in fails:
                print(f"  {a} {s}: {e[:200]}")
            sys.exit(1)
        print("all cells ok")
        return

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    if args.variant != "baseline":
        mesh_tag = f"{mesh_tag}-{args.variant}"
    out_path = RESULTS / mesh_tag / f"{args.arch}__{args.shape}.json"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.attn_impl,
                   out_path, variant=args.variant,
                   grad_accum=args.grad_accum)
    print(json.dumps({k: v for k, v in rec.items() if k != "hlo"}, indent=1))


if __name__ == "__main__":
    main()
