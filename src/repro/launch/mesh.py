"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — critical because
the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (run under xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)
