"""Serving launcher: the continuous-batching engine over a selectable
architecture, with energy accounting of the served trace.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --reduced --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import PowerModel, emissions
from repro.core.power import DEVICES
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--device", default="tpu-v5e")
    ap.add_argument("--ci", type=float, default=400.0,
                    help="grid carbon intensity gCO2/kWh")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_slots=args.slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(ServeRequest(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, rng.integers(4, 17)),
            max_new_tokens=args.new_tokens))
    done = engine.run()
    toks = sum(len(r.generated) for r in done)
    print(f"{cfg.name}: {len(done)} requests, {toks} tokens, "
          f"{toks/max(engine.clock, 1e-9):.1f} tok/s")

    dev = DEVICES[args.device]
    durs = np.array([l.dur_s for l in engine.logs])
    flops = np.array([2.0 * cfg.param_count() * l.n_tokens
                      for l in engine.logs])
    mfu = np.clip(flops / (np.maximum(durs, 1e-9) * dev.peak_flops), 0, 1)
    pm = PowerModel(dev)
    wh = float(np.sum(np.asarray(pm.power(mfu)) * durs)) / 3600.0
    rep = emissions(wh, engine.clock / 3600.0, dev, ci=args.ci)
    print(f"energy {wh*1000:.2f} mWh -> {rep.total_g:.4f} gCO2 "
          f"(CI={args.ci:.0f}, device={dev.name})")


if __name__ == "__main__":
    main()
