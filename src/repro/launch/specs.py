"""Abstract input specs + jit-able step builders for the dry-run and
launchers.

Everything here is ShapeDtypeStruct-based: no memory is allocated. The
same builders power the real launchers (which replace the abstract trees
with device arrays).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import axes as axlib
from repro.distributed.sharding import (ShardingPlan, batch_pspecs,
                                        cache_pspecs, make_plan, param_pspecs)
from repro.models.lm import Model, build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    batch: Dict[str, Any] = {}
    if cfg.embed_stub and shape.kind != "decode":
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    if (cfg.attention is not None and cfg.attention.rope == "mrope"
            and shape.kind != "decode"):
        batch["positions3"] = sds((B, S, 3), jnp.int32)
    return batch


def abstract_params(model: Model, dtype=jnp.float32):
    tree = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if dtype is not None:
        tree = jax.tree.map(
            lambda l: sds(l.shape, dtype) if l.dtype == jnp.float32 else l,
            tree)
    return tree


def abstract_cache(model: Model, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(model.init_cache, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Cell builder: (fn, abstract args, in/out shardings)
# ---------------------------------------------------------------------------

def auto_grad_accum(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    budget_bytes: float = 4e9,
                    batch_axes=("pod", "data"), seq_shards: int = 1) -> int:
    """Pick microbatch accumulation so the remat-scan's saved layer inputs
    (L x rows_per_device x S x d bf16) fit the activation budget."""
    n_batch_devs = 1
    for ax in batch_axes:
        n_batch_devs *= mesh.shape.get(ax, 1)
    rows = max(1, shape.global_batch // n_batch_devs)
    per_row = cfg.n_layers * shape.seq_len * cfg.d_model * 2 // seq_shards
    ga = 1
    while rows // ga > 1 and (rows // ga) * per_row > budget_bytes:
        ga *= 2
    if (rows // ga) * per_row > budget_bytes and rows // ga == 1:
        pass  # single row still over budget: remat scan is the floor
    return ga


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               attn_impl: str = "auto",
               opt_cfg: Optional[AdamWConfig] = None,
               grad_accum: Optional[int] = None,
               donate_cache: bool = True,
               variant: str = "baseline"):
    """Returns (plan, fn, args, in_shardings) ready for jit().lower(*args)."""
    plan = make_plan(cfg, mesh, "train" if shape.kind == "train" else shape.kind,
                     shape, variant=variant)
    c = plan.cfg
    mapping = plan.mapping
    batch_abs = input_specs(c, shape)
    b_specs = plan.tree_shardings(batch_pspecs(c, mapping, batch_abs))

    if shape.kind == "train":
        model = build_model(c, attn_impl=attn_impl, remat=True)
        p_abs = abstract_params(model, jnp.float32)
        p_specs = plan.tree_shardings(param_pspecs(p_abs, mapping))
        opt_abs = jax.eval_shape(lambda: adamw_init(p_abs))
        o_specs = {"mu": p_specs, "nu": p_specs,
                   "step": NamedSharding(mesh, P())}
        opt_cfg = opt_cfg or AdamWConfig()
        if grad_accum is None:
            baxes = mapping.get("batch") or ("data",)
            seq_ax = mapping.get("seq")
            seq_shards = mesh.shape.get(seq_ax, 1) if seq_ax else 1
            grad_accum = auto_grad_accum(c, shape, mesh, batch_axes=baxes,
                                         seq_shards=seq_shards)
        step = make_train_step(model, opt_cfg, grad_accum=grad_accum)

        def fn(params, opt_state, batch):
            with axlib.axis_env(mesh, mapping):
                return step(params, opt_state, batch)

        args = (p_abs, opt_abs, batch_abs)
        in_sh = (p_specs, o_specs, b_specs)
        out_sh = (p_specs, o_specs, None)
        return plan, fn, args, in_sh, out_sh

    model = build_model(c, attn_impl=attn_impl, remat=False)
    p_abs = abstract_params(model, jnp.bfloat16)
    p_specs = plan.tree_shardings(param_pspecs(p_abs, mapping))

    if shape.kind == "prefill":
        def fn(params, batch):
            with axlib.axis_env(mesh, mapping):
                return model.prefill(params, batch, max_len=shape.seq_len)

        args = (p_abs, batch_abs)
        in_sh = (p_specs, b_specs)
        return plan, fn, args, in_sh, None

    # decode: one new token against a cache of seq_len
    cache_abs = abstract_cache(model, shape.global_batch, shape.seq_len)
    # caches carry `lengths`; pretend the cache is (seq_len - 1) full
    c_specs = plan.tree_shardings(cache_pspecs(c, mapping, cache_abs))

    def fn(params, batch, cache):
        with axlib.axis_env(mesh, mapping):
            return model.decode_step(params, batch, cache)

    args = (p_abs, batch_abs, cache_abs)
    in_sh = (p_specs, b_specs, c_specs)
    out_sh = (None, c_specs)
    return plan, fn, args, in_sh, out_sh
