"""Distributed training launcher.

On a real TPU pod this runs under the production mesh; on CPU it runs the
same code path on a small test mesh (set
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise SPMD).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 20 --mesh 2x4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.distributed import axes as axlib
from repro.distributed.sharding import batch_pspecs, make_plan, param_pspecs
from repro.models import build_model
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import FaultToleranceConfig, FaultTolerantRunner
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="laptop-scale same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ckpt-dir", default="ckpts")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = make_plan(cfg, mesh, "train", shape, variant=args.variant)
    c = plan.cfg

    model = build_model(c, remat=False)
    print(f"training {c.name} ({c.param_count()/1e6:.1f} M params) on "
          f"mesh {dict(mesh.shape)} variant={args.variant}")

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    p_specs = plan.tree_shardings(param_pspecs(
        jax.eval_shape(lambda: params), plan.mapping))
    params = jax.tree.map(jax.device_put, params, p_specs)
    opt = {"mu": jax.tree.map(jax.device_put, opt["mu"], p_specs),
           "nu": jax.tree.map(jax.device_put, opt["nu"], p_specs),
           "step": opt["step"]}

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=max(100, args.steps))
    raw_step = make_train_step(model, opt_cfg)

    def fn(p, o, b):
        with axlib.axis_env(mesh, plan.mapping):
            return raw_step(p, o, b)

    step = jax.jit(fn, donate_argnums=(0, 1))
    ds = SyntheticLM(DataConfig(vocab_size=c.vocab_size, seq_len=args.seq,
                                global_batch=args.batch, seed=0))
    runner = FaultTolerantRunner(step, FaultToleranceConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 2)))
    params, opt, start = runner.try_restore(params, opt)
    if start >= args.steps:
        print(f"done: checkpoint already at step {start} (>= --steps)")
        return
    with mesh:
        out = runner.run(params, opt, ds.batch, n_steps=args.steps,
                         start_step=start)
    if out["losses"]:
        print(f"done: step {out['final_step']}, loss "
              f"{out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    else:
        print(f"done: step {out['final_step']} (no new steps)")


if __name__ == "__main__":
    main()
