"""Attention: training/prefill (chunked flash-style) and decode (KV cache).

Parameters use explicit per-head 3D layouts — wq (D, H, Dh), wk/wv
(D, KV, Dh), wo (H, Dh, D) — so tensor-parallel PartitionSpecs align with
head boundaries without resharding. Two TP modes are supported by the
sharding layer: "head" (shard H; KV heads replicated ``kv_repeat``x when
KV < TP) and "head_dim" (shard Dh; for head counts that don't divide TP).

Three math-identical implementations:
  - ``einsum``  : materialized scores — tiny shapes (CPU smoke tests)
  - ``xla``     : chunked online-softmax (flash-style) pure JAX; memory-
                  safe at 32k+ and transparent to ``cost_analysis()`` —
                  the dry-run/roofline path
  - ``pallas``  : Pallas TPU kernels from ``repro.kernels`` (real-TPU path)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.distributed.axes import constrain
from repro.models.layers import apply_mrope, apply_rope, truncated_normal_init

NEG_INF = -1e30


def attn_params(key, d_model: int, cfg: AttentionConfig) -> Dict:
    ks = jax.random.split(key, 4)
    import math
    s = 1.0 / math.sqrt(d_model)
    so = 1.0 / math.sqrt(cfg.q_dim)
    p = {
        "wq": truncated_normal_init(ks[0], (d_model, cfg.n_heads, cfg.head_dim), s),
        "wk": truncated_normal_init(ks[1], (d_model, cfg.n_kv_heads, cfg.head_dim), s),
        "wv": truncated_normal_init(ks[2], (d_model, cfg.n_kv_heads, cfg.head_dim), s),
        "wo": truncated_normal_init(ks[3], (cfg.n_heads, cfg.head_dim, d_model), so),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, cfg.head_dim), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    return p


def _project_qkv(x, p, cfg: AttentionConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    # "seq_inner" is never sharded: under sequence parallelism (variant
    # "sp") the residual stream is seq-sharded but attention internals
    # operate on the gathered sequence (Megatron-SP AG/RS placement)
    q = constrain(q, ("batch", "seq_inner", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq_inner", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq_inner", "kv_heads", "head_dim"))
    return q, k, v


def _apply_positional(q, k, cfg: AttentionConfig, positions):
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k


# ---------------------------------------------------------------------------
# Dense (einsum) attention — small shapes only
# ---------------------------------------------------------------------------

def attention_einsum(q, k, v, cfg: AttentionConfig, q_offset=0,
                     kv_valid: Optional[jnp.ndarray] = None):
    """q: (B,Sq,H,D), k/v: (B,Skv,KV_eff,D). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if cfg.causal:
        mask &= kpos <= qpos
    if cfg.sliding_window is not None:
        mask &= kpos > qpos - cfg.sliding_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_valid is not None:  # (B, Skv) padding mask
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked flash-style attention (pure XLA) — the long-sequence path
#
# The forward is an online-softmax over kv chunks; the backward is a
# *flash backward*: it saves only (q, k, v, out, lse) and recomputes the
# score blocks chunk-by-chunk, so training at 32k does not materialize
# S x S score tensors (neither forward nor backward).
# ---------------------------------------------------------------------------

def _flash_mask(cfg: AttentionConfig, qpos, kpos, seq_q, seq_k):
    pm = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if cfg.causal:
        pm &= kpos[None, :] <= qpos[:, None]
    if cfg.sliding_window is not None:
        pm &= kpos[None, :] > qpos[:, None] - cfg.sliding_window
    pm &= (qpos[:, None] < seq_q) & (kpos[None, :] < seq_k)
    return pm


def _flash_fwd_padded(q, k, v, cfg, q_chunk, kv_chunk, seq_q, seq_k):
    """q: (B,nq,cq,KV,G,D) chunked; k/v: (B,nk,ck,KV,D). Returns
    (out (B,nq,cq,KV,G,D), lse (B,nq,KV,G,cq))."""
    B, nq, cq, KV, G, D = q.shape
    nk, ck = k.shape[1], k.shape[2]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    def one_q_chunk(args):
        qi, q_blk = args  # (B,cq,KV,G,D)
        qpos = qi * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bskgd,btkd->bkgst", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            pm = _flash_mask(cfg, qpos, kpos, seq_q, seq_k)
            s = jnp.where(pm[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), k.transpose(1, 0, 2, 3, 4),
             v.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        lse = m + jnp.log(jnp.maximum(l, 1e-37))
        return out.transpose(0, 3, 1, 2, 4), lse  # (B,cq,KV,G,D), (B,KV,G,cq)

    outs, lses = jax.lax.map(one_q_chunk,
                             (jnp.arange(nq), q.transpose(1, 0, 2, 3, 4, 5)))
    return (outs.transpose(1, 0, 2, 3, 4, 5),
            lses.transpose(1, 0, 2, 3, 4))  # (B,nq,KV,G,cq)


def _flash_bwd_padded(cfg, q_chunk, kv_chunk, seq_q, seq_k, res, dout):
    q, k, v, out, lse = res
    B, nq, cq, KV, G, D = q.shape
    nk, ck = k.shape[1], k.shape[2]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    do = dout.astype(jnp.float32)
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)  # (B,nq,cq,KV,G)

    def q_step(carry, inputs):
        dk, dv = carry
        qi, q_blk, do_blk, lse_blk, delta_blk = inputs
        qpos = qi * cq + jnp.arange(cq)
        qf = q_blk.astype(jnp.float32)
        dof = do_blk  # (B,cq,KV,G,D) f32
        del_t = delta_blk.transpose(0, 2, 3, 1)  # (B,KV,G,cq)

        def kv_step(carry2, inputs2):
            dq_acc, dk, dv = carry2
            ki, k_blk, v_blk = inputs2
            kpos = ki * ck + jnp.arange(ck)
            kf = k_blk.astype(jnp.float32)
            vf = v_blk.astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
            pm = _flash_mask(cfg, qpos, kpos, seq_q, seq_k)
            s = jnp.where(pm[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])             # (B,KV,G,cq,ck)
            dp = jnp.einsum("bskgd,btkd->bkgst", dof, vf)
            ds = p * (dp - del_t[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgst,btkd->bskgd", ds, kf)
            dk_j = jnp.einsum("bkgst,bskgd->btkd", ds, qf)
            dv_j = jnp.einsum("bkgst,bskgd->btkd", p, dof)
            dk = jax.lax.dynamic_update_index_in_dim(
                dk, jax.lax.dynamic_index_in_dim(dk, ki, 1, False) + dk_j, ki, 1)
            dv = jax.lax.dynamic_update_index_in_dim(
                dv, jax.lax.dynamic_index_in_dim(dv, ki, 1, False) + dv_j, ki, 1)
            return (dq_acc, dk, dv), None

        dq0 = jnp.zeros((B, cq, KV, G, D), jnp.float32)
        (dq, dk, dv), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv),
            (jnp.arange(nk), k.transpose(1, 0, 2, 3, 4),
             v.transpose(1, 0, 2, 3, 4)))
        return (dk, dv), dq

    dk0 = jnp.zeros((B, nk, ck, KV, D), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0),
        (jnp.arange(nq), q.transpose(1, 0, 2, 3, 4, 5),
         do.transpose(1, 0, 2, 3, 4, 5), lse.transpose(1, 0, 2, 3, 4),
         delta.transpose(1, 0, 2, 3, 4)))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, cfg, q_chunk, kv_chunk, seq_q, seq_k):
    out, _ = _flash_fwd_padded(q, k, v, cfg, q_chunk, kv_chunk, seq_q, seq_k)
    return out


def _flash_core_fwd(q, k, v, cfg, q_chunk, kv_chunk, seq_q, seq_k):
    out, lse = _flash_fwd_padded(q, k, v, cfg, q_chunk, kv_chunk, seq_q, seq_k)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(cfg, q_chunk, kv_chunk, seq_q, seq_k, res, dout):
    return _flash_bwd_padded(cfg, q_chunk, kv_chunk, seq_q, seq_k, res,
                             dout.astype(jnp.float32))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def attention_flash_xla(q, k, v, cfg: AttentionConfig, q_offset=0,
                        kv_valid: Optional[jnp.ndarray] = None,
                        q_chunk: int = 512, kv_chunk: int = 1024):
    """Flash attention, XLA path. q: (B,S,H,D); k/v: (B,S,KV_eff,D).

    kv_valid=None (training/dry-run packed batches) uses the custom-VJP
    flash core (O(S) residuals); per-sequence masks fall back to the
    inline masked implementation (inference-only, no grads needed)."""
    if kv_valid is None and q_offset == 0:
        B, Sq, H, D = q.shape
        Skv = k.shape[1]
        KV = k.shape[2]
        G = H // KV
        cq = min(q_chunk, Sq)
        ck = min(kv_chunk, Skv)
        pq, pk = (-Sq) % cq, (-Skv) % ck
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
        kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
        vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
        nq, nk = (Sq + pq) // cq, (Skv + pk) // ck
        qc = qp.reshape(B, nq, cq, KV, G, D)
        kc = kp.reshape(B, nk, ck, KV, D)
        vc = vp.reshape(B, nk, ck, KV, D)
        out = _flash_core(qc, kc, vc, cfg, cq, ck, Sq, Skv)
        out = out.reshape(B, Sq + pq, H, D)
        return out[:, :Sq].astype(q.dtype)
    return _attention_flash_xla_varlen(q, k, v, cfg, q_offset, kv_valid,
                                       q_chunk, kv_chunk)


def _attention_flash_xla_varlen(q, k, v, cfg: AttentionConfig, q_offset=0,
                                kv_valid: Optional[jnp.ndarray] = None,
                                q_chunk: int = 512, kv_chunk: int = 1024):
    """Online-softmax attention, scanning q chunks (outer, lax.map) and kv
    chunks (inner, lax.scan). Memory per step is O(q_chunk * kv_chunk)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pad = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    valid = jnp.ones((B, Skv), bool) if kv_valid is None else kv_valid
    if pk:
        valid = jnp.pad(valid, ((0, 0), (0, pk)))
    nq, nk = (Sq + pad) // q_chunk, (Skv + pk) // kv_chunk
    qg = q.reshape(B, nq, q_chunk, KV, G, D)
    kc = k.reshape(B, nk, kv_chunk, KV, D)
    vc = v.reshape(B, nk, kv_chunk, KV, D)
    validc = valid.reshape(B, nk, kv_chunk)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    def one_q_chunk(args):
        qi, q_blk = args  # q_blk: (B, q_chunk, KV, G, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk, ok = inputs
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bskgd,btkd->bkgst", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = ok[:, None, None, None, :]  # (B,1,1,1,t)
            pm = jnp.ones((q_chunk, kv_chunk), bool)
            if cfg.causal:
                pm &= kpos[None, :] <= qpos[:, None]
            if cfg.sliding_window is not None:
                pm &= kpos[None, :] > qpos[:, None] - cfg.sliding_window
            mask = mask & pm[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kc.transpose(1, 0, 2, 3, 4),
             vc.transpose(1, 0, 2, 3, 4), validc.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return out.transpose(0, 3, 1, 2, 4)  # (B, q_chunk, KV, G, D)

    outs = jax.lax.map(one_q_chunk,
                       (jnp.arange(nq), qg.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq + pad, H, D)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------

def attention_decode(q, k_cache, v_cache, cfg: AttentionConfig,
                     lengths: jnp.ndarray, window: Optional[int] = None):
    """q: (B,1,H,D); caches: (B,W,KV_eff,D); lengths: (B,) tokens already
    in cache (including the newly inserted one). Returns (B,1,H,D)."""
    B, W, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    # mixed-precision dots (preferred_element_type) so the bf16 cache is
    # never materialized in f32 — scores accumulate in f32 on the MXU
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(D).astype(jnp.float32)
    slot = jnp.arange(W)[None, :]
    if window is None:
        mask = slot < lengths[:, None]
    else:
        # ring buffer: every slot valid once the cache has wrapped
        mask = slot < jnp.minimum(lengths, W)[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention_decode_pallas(q, k_cache, v_cache, cfg: AttentionConfig,
                            lengths: jnp.ndarray,
                            window: Optional[int] = None):
    from repro.kernels.decode_attention import decode_attention
    return decode_attention(q, k_cache, v_cache, lengths, window=window)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def cache_window(cfg: AttentionConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_kv_cache(n_layers: int, batch: int, cfg: AttentionConfig,
                  max_len: int, dtype=jnp.bfloat16) -> Dict:
    W = cache_window(cfg, max_len)
    shape = (n_layers, batch, W, cfg.n_kv_eff, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_insert_decode(cache_k, cache_v, k_new, v_new, lengths, window: int):
    """Insert one token per sequence at ring position lengths % window.

    cache_k/v: (B,W,KV,D); k_new/v_new: (B,1,KV,D); lengths: (B,)."""
    idx = lengths % window
    def upd(c, n, i):
        return jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    ck = jax.vmap(upd)(cache_k, k_new.astype(cache_k.dtype), idx)
    cv = jax.vmap(upd)(cache_v, v_new.astype(cache_v.dtype), idx)
    return ck, cv


# ---------------------------------------------------------------------------
# Full attention block
# ---------------------------------------------------------------------------

def attention_block(x, p, cfg: AttentionConfig, *, positions,
                    mode: str = "train",
                    cache: Optional[Tuple] = None,
                    lengths: Optional[jnp.ndarray] = None,
                    kv_valid: Optional[jnp.ndarray] = None,
                    impl: str = "auto"):
    """One attention application.

    mode: "train"/"prefill" (full sequence) or "decode" (one token w/ cache).
    cache (decode): (k_cache, v_cache) of shape (B,W,KV_eff,D).
    Returns (out (B,S,D), new_cache_kv or computed (k, v))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    q, k = _apply_positional(q, k, cfg, positions)

    if mode == "decode":
        assert cache is not None and lengths is not None
        ck, cv = cache
        W = ck.shape[1]
        window = cfg.sliding_window
        ck, cv = cache_insert_decode(ck, cv, k, v, lengths, W)
        if impl == "pallas":
            out = attention_decode_pallas(q, ck, cv, cfg, lengths + 1,
                                          window=window)
        else:
            out = attention_decode(q, ck, cv, cfg, lengths + 1, window=window)
        new_cache = (ck, cv)
    else:
        if impl == "pallas":
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v, causal=cfg.causal,
                                  window=cfg.sliding_window)
        elif impl == "einsum" or (impl == "auto" and S * k.shape[1] <= 256 * 256):
            out = attention_einsum(q, k, v, cfg, kv_valid=kv_valid)
        else:
            out = attention_flash_xla(q, k, v, cfg, kv_valid=kv_valid)
        new_cache = (k, v)

    out = constrain(out, ("batch", "seq_inner", "heads", "head_dim"))
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return proj, new_cache
