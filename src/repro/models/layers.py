"""Shared layer primitives: norms, projections, rotary embeddings.

All parameters are plain ``jnp`` arrays in nested dicts; initializers are
explicit so the whole model can be built under ``jax.eval_shape`` for the
dry-run without allocating memory.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    # 2-sigma truncation keeps init bounded, matching common LM inits.
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return truncated_normal_init(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return truncated_normal_init(key, (vocab, d), 1.0, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_params(key, d: int, kind: str) -> Dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(x, p: Dict, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu_sq":  # RWKV channel-mix
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {kind}")


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE / partial RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, rope_pct: float,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    inv = rope_freqs(D, rope_pct, theta)
    rot = inv.shape[0] * 2
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    xr = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr.astype(x.dtype), xp], axis=-1) if rot < D else xr.astype(x.dtype)


# M-RoPE (Qwen2-VL): head_dim split into 3 sections (t, h, w); each section
# rotated with its own position stream. For pure-text tokens all three
# position ids coincide and M-RoPE reduces to RoPE.
MROPE_SECTIONS = (0.25, 0.375, 0.375)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions3: (B, S, 3) multimodal position ids."""
    D = x.shape[-1]
    half = D // 2
    sec = [int(half * s) for s in MROPE_SECTIONS]
    sec[-1] = half - sec[0] - sec[1]
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # frequency index -> which position stream it uses
    stream = jnp.concatenate([
        jnp.zeros((sec[0],), jnp.int32),
        jnp.ones((sec[1],), jnp.int32),
        2 * jnp.ones((sec[2],), jnp.int32),
    ])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(stream[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1,
    )  # (B, S, half)
    ang = pos * inv  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, d: int, d_ff: int, gated: bool) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff), "down": dense_init(ks[1], d_ff, d)}
    if gated:
        p["gate"] = dense_init(ks[2], d, d_ff)
    return p


def apply_mlp(x: jnp.ndarray, p: Dict, act: str, gated: bool) -> jnp.ndarray:
    up = x @ p["up"].astype(x.dtype)
    if gated:
        g = activation(x @ p["gate"].astype(x.dtype), act)
        h = g * up
    else:
        h = activation(up, act)
    return h @ p["down"].astype(x.dtype)
