"""Generalized gated linear attention (GLA) recurrence.

Covers RWKV6 (per-channel data-dependent decay + current-token bonus) and
Mamba2/SSD (per-head scalar decay, inclusive current token):

    S_t = Diag(w_t) S_{t-1} + k_t v_t^T          state S: (K, V)
    rwkv:  o_t = q_t^T (S_{t-1} + Diag(u) k_t v_t^T)
    ssd:   o_t = q_t^T S_t

The chunked formulation (intra-chunk matmuls + inter-chunk state carry)
is the math the ``gla_scan`` Pallas kernel implements; this module is the
XLA/reference path used on CPU and in the dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def gla_step(q, k, v, log_w, state, u: Optional[jnp.ndarray] = None,
             mode: str = "ssd"):
    """Single-token decode step.

    q/k/log_w: (B, H, K); v: (B, H, V); state: (B, H, K, V);
    u: (H, K) bonus (rwkv) or None. Returns (o (B,H,V), new_state)."""
    w = jnp.exp(log_w.astype(jnp.float32))
    kv = k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    if mode == "rwkv":
        assert u is not None
        eff = state + u.astype(jnp.float32)[None, :, :, None] * kv
        o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), eff)
        new_state = w[..., None] * state + kv
    else:
        new_state = w[..., None] * state + kv
        o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), new_state)
    return o.astype(v.dtype), new_state


def gla_chunked(q, k, v, log_w, u: Optional[jnp.ndarray] = None,
                mode: str = "ssd", chunk: int = 32,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked parallel scan.

    q/k/log_w: (B, T, H, K); v: (B, T, H, V); u: (H, K) or None.
    Returns (o (B, T, H, V), final_state (B, H, K, V)).
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq)
        log_w = jnp.pad(log_w, zq)  # log w = 0 -> w = 1 for padding (no decay)
    n = (T + pad) // chunk

    def to_chunks(x):
        return x.reshape(B, n, chunk, H, -1).transpose(1, 0, 3, 2, 4)

    qc, kc, vc, lwc = map(to_chunks, (q, k, v, log_w))  # (n, B, H, c, ·)
    lwc = lwc.astype(jnp.float32)

    def chunk_step(state, inp):
        qb, kb, vb, lwb = inp                # (B, H, c, ·)
        qb = qb.astype(jnp.float32)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        L = jnp.cumsum(lwb, axis=2)          # cumulative log decay incl. t
        Lc = L[:, :, -1:, :]                 # total chunk decay
        if mode == "rwkv":
            # decay applied to state BEFORE reading at t: prod_{j<t} w_j
            L_read = L - lwb                 # exclusive cumsum
            strict = True
        else:
            L_read = L                       # inclusive: state after update
            strict = False
        # inter-chunk: o_inter[t] = (q_t * exp(L_read_t)) @ S_prev
        # (L_read <= 0 -> exp underflows at worst; never overflows)
        q_sc = qb * jnp.exp(L_read)
        o_inter = jnp.einsum("bhck,bhkv->bhcv", q_sc, state)
        # intra-chunk: pairwise log-difference exp(L_read_t - L_j), j <= t.
        # Computed as a difference (not factored exp(L_t)*exp(-L_j)) so that
        # strong decay (e.g. Mamba2 a*dt >> 1) cannot overflow: valid pairs
        # always have L_read_t - L_j <= 0. The Pallas kernel implements the
        # same math with two-level chunking.
        t_idx = jnp.arange(chunk)
        mask = t_idx[:, None] > t_idx[None, :] if strict else t_idx[:, None] >= t_idx[None, :]
        diff = L_read[:, :, :, None, :] - L[:, :, None, :, :]  # (B,H,t,j,K)
        diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
        att = jnp.einsum("bhck,bhjk,bhcjk->bhcj", qb, kb, jnp.exp(diff))
        o_intra = jnp.einsum("bhcj,bhjv->bhcv", att, vb)
        if mode == "rwkv":
            assert u is not None
            bonus = jnp.einsum("bhck,bhck->bhc",
                               qb * u.astype(jnp.float32)[None, :, None, :], kb)
            o_intra = o_intra + bonus[..., None] * vb
        # state update: S_new = Diag(exp(Lc)) S + sum_j (k_j exp(Lc - L_j)) v_j
        k_dec = kb * jnp.exp(Lc - L)
        s_upd = jnp.einsum("bhck,bhcv->bhkv", k_dec, vb)
        new_state = jnp.exp(Lc).transpose(0, 1, 3, 2) * state + s_upd
        return new_state, o_inter + o_intra

    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)
    final_state, outs = jax.lax.scan(chunk_step, initial_state, (qc, kc, vc, lwc))
    o = outs.transpose(1, 0, 3, 2, 4).reshape(B, T + pad, H, V)
    return o[:, :T].astype(v.dtype), final_state


def gla_reference(q, k, v, log_w, u: Optional[jnp.ndarray] = None,
                  mode: str = "ssd",
                  initial_state: Optional[jnp.ndarray] = None):
    """Token-by-token scan oracle (slow, exact)."""
    B, T, H, K = q.shape
    V = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)

    def step(state, inp):
        qt, kt, vt, lwt = inp
        o, ns = gla_step(qt, kt, vt, lwt, state, u=u, mode=mode)
        return ns, o

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (q, k, v, log_w))
    final, outs = jax.lax.scan(step, initial_state, xs)
    return outs.transpose(1, 0, 2, 3), final
