"""Unified model facade: init / train-loss / prefill / decode per family.

``build_model(cfg)`` returns a ``Model`` whose step functions are what the
launcher jits, the dry-run lowers, and the serving engine drives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import constrain
from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models import rwkv as rwkv_mod
from repro.models import zamba as zamba_mod


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over valid positions; logits promoted to fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if valid is None:
        return nll.mean()
    v = valid.astype(jnp.float32)
    return (nll * v).sum() / jnp.maximum(v.sum(), 1.0)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    attn_impl: str = "auto"
    remat: bool = False
    remat_policy: str = "minimal"  # "minimal" (save nothing) | "dots"

    # ---------------- init ----------------
    def init(self, rng) -> Dict:
        c = self.cfg
        if c.family == "ssm":
            return rwkv_mod.init_rwkv(rng, c)
        if c.family == "hybrid":
            return zamba_mod.init_zamba(rng, c)
        return tf.init_transformer(rng, c)

    # ---------------- embeddings ----------------
    def _embed(self, params, batch: Dict) -> jnp.ndarray:
        c = self.cfg
        if "embeds" in batch:  # modality stub (vlm / audio)
            x = batch["embeds"]
            return constrain(x.astype(jnp.bfloat16 if c.dtype == "bfloat16"
                                      else jnp.float32),
                             ("batch", "seq", "embed"))
        return tf.embed_tokens(params, c, batch["tokens"])

    def _positions(self, batch: Dict, S: int, lengths=None, decode=False):
        c = self.cfg
        if c.attention is not None and c.attention.rope == "mrope":
            if "positions3" in batch:
                return batch["positions3"]
            if decode:
                return jnp.broadcast_to(lengths[:, None, None],
                                        (lengths.shape[0], 1, 3))
            B = batch["tokens"].shape[0] if "tokens" in batch else batch["embeds"].shape[0]
            p = jnp.arange(S)[None, :, None]
            return jnp.broadcast_to(p, (B, S, 3))
        if decode:
            return lengths[:, None]
        return jnp.arange(S)[None, :]

    # ---------------- training ----------------
    def loss_fn(self, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        c = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S)
        if c.family == "ssm":
            h, _, aux = rwkv_mod.rwkv_forward(params, c, x, mode="train",
                                              remat=self.remat,
                                              remat_policy=self.remat_policy)
        elif c.family == "hybrid":
            h, _, aux = zamba_mod.zamba_forward(
                params, c, x, positions=positions, mode="train",
                remat=self.remat, attn_impl=self.attn_impl,
                remat_policy=self.remat_policy)
        else:
            h, _, aux = tf.transformer_forward(
                params, c, x, positions=positions, mode="train",
                remat=self.remat, attn_impl=self.attn_impl,
                remat_policy=self.remat_policy)
        if c.family == "ssm":
            from repro.models.layers import layernorm
            h = layernorm(h, params["final_scale"], params["final_bias"])
            logits = jnp.einsum("...d,vd->...v", h,
                                params["lm_head"].astype(h.dtype))
        else:
            logits = tf.lm_logits(params, c, h)
        valid = batch.get("valid")
        loss = cross_entropy(logits, batch["labels"], valid)
        loss = loss + aux
        return loss, {"ce": loss, "aux": aux}

    # ---------------- serving: prefill ----------------
    def prefill(self, params, batch: Dict, max_len: int
                ) -> Tuple[jnp.ndarray, Any]:
        """Full-sequence forward; returns (last-token logits (B,V), cache)."""
        c = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S)
        lengths = batch.get("lengths", jnp.full((B,), S, jnp.int32))
        kv_valid = None
        if "lengths" in batch:
            kv_valid = jnp.arange(S)[None, :] < lengths[:, None]
        if c.family == "ssm":
            h, pre, _ = rwkv_mod.rwkv_forward(params, c, x, mode="prefill")
            cache = pre
        elif c.family == "hybrid":
            h, pre, _ = zamba_mod.zamba_forward(
                params, c, x, positions=positions, mode="prefill",
                kv_valid=kv_valid, attn_impl=self.attn_impl)
            cache = zamba_mod.fill_zamba_cache_from_prefill(
                c, pre, S, max_len, B)
        else:
            h, pre, _ = tf.transformer_forward(
                params, c, x, positions=positions, mode="prefill",
                kv_valid=kv_valid, attn_impl=self.attn_impl)
            cache = tf.fill_cache_from_prefill(
                c, pre["computed_k"], pre["computed_v"], S, max_len, lengths)
        # last valid position logits only (serving does not need all logits)
        idx = jnp.maximum(lengths - 1, 0)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
        if c.family == "ssm":
            from repro.models.layers import layernorm
            h_last = layernorm(h_last, params["final_scale"], params["final_bias"])
            logits = jnp.einsum("...d,vd->...v", h_last,
                                params["lm_head"].astype(h_last.dtype))
        else:
            logits = tf.lm_logits(params, c, h_last)
        return logits[:, 0], cache

    # ---------------- serving: one decode step ----------------
    def decode_step(self, params, batch: Dict, cache: Any
                    ) -> Tuple[jnp.ndarray, Any]:
        """batch: {"tokens": (B,1)} (+ positions3). Returns ((B,V), cache)."""
        c = self.cfg
        x = self._embed(params, batch)
        lengths = cache["lengths"]
        positions = self._positions(batch, 1, lengths=lengths, decode=True)
        if c.family == "ssm":
            h, new_cache, _ = rwkv_mod.rwkv_forward(params, c, x, mode="decode",
                                                    cache=cache)
        elif c.family == "hybrid":
            h, new_cache, _ = zamba_mod.zamba_forward(
                params, c, x, positions=positions, mode="decode", cache=cache,
                attn_impl=self.attn_impl)
        else:
            h, new_cache, _ = tf.transformer_forward(
                params, c, x, positions=positions, mode="decode", cache=cache,
                attn_impl=self.attn_impl)
        if c.family == "ssm":
            from repro.models.layers import layernorm
            h = layernorm(h, params["final_scale"], params["final_bias"])
            logits = jnp.einsum("...d,vd->...v", h,
                                params["lm_head"].astype(h.dtype))
        else:
            logits = tf.lm_logits(params, c, h)
        return logits[:, 0], new_cache

    # ---------------- cache factory ----------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        c = self.cfg
        if c.family == "ssm":
            return rwkv_mod.init_rwkv_cache(c, batch, dtype)
        if c.family == "hybrid":
            return zamba_mod.init_zamba_cache(c, batch, max_len, dtype)
        return attn_mod.init_kv_cache(c.n_layers, batch, c.attention,
                                      max_len, dtype)


def build_model(cfg: ModelConfig, attn_impl: str = "auto",
                remat: bool = False, remat_policy: str = "minimal") -> Model:
    return Model(cfg=cfg, attn_impl=attn_impl, remat=remat,
                 remat_policy=remat_policy)
