"""Mamba2 (SSD) block for the Zamba2 hybrid backbone.

Projections are stored head-major — in_x/in_z (D, H, P), out (H, P, D) —
so TP PartitionSpecs align with head boundaries. B/C projections
(n_groups * d_state, shared across heads) stay replicated.

split projections -> depthwise causal conv over (x, B, C) -> selective
state-space recurrence with per-head scalar decay
``a_t = exp(-exp(A_log) * dt_t)`` via the generalized GLA scan ->
gated RMSNorm -> out projection.

Decode state per layer: conv_x (B, K-1, H, P), conv_bc (B, K-1, 2GN),
ssm state (B, H, N, P).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import constrain
from repro.models.layers import truncated_normal_init
from repro.models.linear_attention import gla_chunked, gla_step


def mamba_block_params(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    s = cfg.ssm
    H = s.n_heads(d)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    return {
        "in_z": truncated_normal_init(ks[0], (d, H, P), sc),
        "in_x": truncated_normal_init(ks[1], (d, H, P), sc),
        "in_B": truncated_normal_init(ks[2], (d, G * N), sc),
        "in_C": truncated_normal_init(ks[3], (d, G * N), sc),
        "in_dt": truncated_normal_init(ks[4], (d, H), sc),
        "conv_x_w": 0.1 * jax.random.normal(ks[5], (s.d_conv, H, P)),
        "conv_x_b": jnp.zeros((H, P), jnp.float32),
        "conv_bc_w": 0.1 * jax.random.normal(ks[6], (s.d_conv, 2 * G * N)),
        "conv_bc_b": jnp.zeros((2 * G * N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        # standard Mamba init: dt in [1e-3, 1e-1] log-uniform, via softplus^-1
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jnp.linspace(
            jnp.log(1e-3), jnp.log(1e-1), H)))),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((H, P), jnp.float32),
        "out_proj": truncated_normal_init(ks[7], (H, P, d),
                                          1.0 / math.sqrt(H * P)),
    }


def _causal_conv(x, w, b, conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv along time. x: (B,T,...C); w: (K,...C)."""
    K = w.shape[0]
    if conv_state is None:
        pad = [(0, 0)] * x.ndim
        pad[1] = (K - 1, 0)
        xp = jnp.pad(x, pad)
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, k:k + x.shape[1]] * w[k].astype(x.dtype) for k in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, xp.shape[1] - (K - 1):] if K > 1 else xp[:, :0]
    return jax.nn.silu(y), new_state


def mamba_block(x, p, cfg: ModelConfig, *, conv_state=None, ssm_state=None,
                mode: str = "train"):
    """x: (B,T,D) -> (out, (new_conv_x, new_conv_bc), new_ssm_state).

    conv_state: None or (conv_x_state, conv_bc_state)."""
    d = cfg.d_model
    s = cfg.ssm
    H = s.n_heads(d)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B_, T, _ = x.shape

    z = jnp.einsum("btd,dhp->bthp", x, p["in_z"].astype(x.dtype))
    xs = jnp.einsum("btd,dhp->bthp", x, p["in_x"].astype(x.dtype))
    xs = constrain(xs, ("batch", "seq", "heads", "head_dim"))
    Bmat = x @ p["in_B"].astype(x.dtype)
    Cmat = x @ p["in_C"].astype(x.dtype)
    dt = x @ p["in_dt"].astype(x.dtype)                              # (B,T,H)

    cx, cbc = conv_state if conv_state is not None else (None, None)
    xs, new_cx = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"], cx)
    bc, new_cbc = _causal_conv(jnp.concatenate([Bmat, Cmat], -1),
                               p["conv_bc_w"], p["conv_bc_b"], cbc)
    Bmat, Cmat = jnp.split(bc, 2, axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,T,H)
    a = jnp.exp(p["A_log"])                                          # (H,)
    log_w = -a * dt                                                  # (B,T,H)

    xs = xs * dt.astype(xs.dtype)[..., None]                         # dt-scaled
    rep = H // G
    Bm = jnp.repeat(Bmat.reshape(B_, T, G, N), rep, axis=2)          # (B,T,H,N)
    Cm = jnp.repeat(Cmat.reshape(B_, T, G, N), rep, axis=2)
    log_w_full = jnp.broadcast_to(log_w[..., None], (B_, T, H, N))

    if mode == "decode":
        o, ssm_state = gla_step(Cm[:, 0], Bm[:, 0], xs[:, 0], log_w_full[:, 0],
                                ssm_state, mode="ssd")
        o = o[:, None]
    else:
        o, ssm_state = gla_chunked(Cm, Bm, xs, log_w_full, mode="ssd",
                                   initial_state=ssm_state)
    o = o + xs * p["D_skip"].astype(xs.dtype)[None, None, :, None]

    # gated RMSNorm over the full inner dim (H*P), head-major layout
    g = o.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=(-2, -1), keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]
    out = jnp.einsum("bthp,hpd->btd", g.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    return out, (new_cx, new_cbc), ssm_state


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    H = s.n_heads(cfg.d_model)
    return {
        "conv_x": (cfg.n_layers, batch, s.d_conv - 1, H, s.head_dim),
        "conv_bc": (cfg.n_layers, batch, s.d_conv - 1, 2 * s.n_groups * s.d_state),
        "ssm": (cfg.n_layers, batch, H, s.d_state, s.head_dim),
    }
