"""Mixture-of-Experts with sort-based capacity dispatch.

TPU-friendly formulation (static shapes, dense einsums on the MXU):
dispatch is computed *per batch row* (vmap over B), so every
intermediate — router logits, sort indices, the (E, C, d) dispatch
buffer — keeps a leading batch dim and stays sharded over the data axes
under GSPMD. The expert dim of the buffer is sharded over the model axis
when the expert count divides it (expert parallelism; the scatter/gather
becomes the all-to-all), otherwise experts are TP-sharded internally
along d_expert.

Capacity is enforced per row: C = ceil(top_k * S * capacity_factor / E),
overflowing tokens are dropped (standard Switch/GShard semantics).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import activation, dense_init
from repro.distributed.axes import constrain


def moe_params(key, d_model: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_expert
    scale = 1.0 / math.sqrt(d_model)
    return {
        "router": dense_init(ks[0], d_model, E),
        "up": scale * jax.random.truncated_normal(ks[1], -2, 2, (E, d_model, F)),
        "gate": scale * jax.random.truncated_normal(ks[2], -2, 2, (E, d_model, F)),
        "down": (1.0 / math.sqrt(F)) * jax.random.truncated_normal(
            ks[3], -2, 2, (E, F, d_model)),
    }


def capacity_for(tokens_per_row: int, cfg: MoEConfig,
                 capacity_factor: float = 1.25) -> int:
    c = int(math.ceil(cfg.top_k * tokens_per_row * capacity_factor
                      / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # MXU-friendly multiple


def _dispatch_row(xt, probs, idx, gate_vals, E: int, K: int, C: int):
    """Per-row dispatch. xt: (S, D); idx/gate_vals: (S, K).
    Returns (buffer (E, C, D), combine metadata)."""
    S, D = xt.shape
    flat_expert = idx.reshape(S * K)
    flat_token = jnp.repeat(jnp.arange(S), K)
    flat_gate = gate_vals.reshape(S * K)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_expert].add(1)
    group_start = jnp.cumsum(group_sizes) - group_sizes
    pos_in_group = jnp.arange(S * K) - group_start[sorted_expert]
    keep = pos_in_group < C
    dest = jnp.where(keep, sorted_expert * C + pos_in_group, E * C)

    gathered = jnp.where(keep[:, None], xt[sorted_token], 0)
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[dest].set(gathered)
    return buf[:E * C].reshape(E, C, D), (sorted_token, sorted_gate, keep, dest)


def _combine_row(out_buf, meta, S: int, D: int):
    sorted_token, sorted_gate, keep, dest = meta
    E_C = out_buf.shape[0] * out_buf.shape[1]
    flat_out = out_buf.reshape(E_C, -1)
    picked = jnp.where(keep[:, None],
                       flat_out[jnp.minimum(dest, E_C - 1)], 0)
    weighted = picked.astype(jnp.float32) * sorted_gate[:, None]
    return jnp.zeros((S, D), jnp.float32).at[sorted_token].add(weighted)


def apply_moe(x: jnp.ndarray, p: Dict, cfg: MoEConfig, act: str = "silu",
              capacity_factor: float = 1.25,
              train: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity_for(S, cfg, capacity_factor)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                        # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), over all tokens
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32).mean(axis=(0, 1))
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    buf, meta = jax.vmap(
        lambda xt, pr, ix, gv: _dispatch_row(xt, pr, ix, gv, E, K, C)
    )(x, probs, idx, gate_vals)                                     # (B,E,C,D)
    buf = constrain(buf, ("batch", "expert", None, None))

    up = jnp.einsum("becd,edf->becf", buf, p["up"].astype(x.dtype))
    gt = jnp.einsum("becd,edf->becf", buf, p["gate"].astype(x.dtype))
    h = activation(gt, act) * up
    out_buf = jnp.einsum("becf,efd->becd", h, p["down"].astype(x.dtype))
    out_buf = constrain(out_buf, ("batch", "expert", None, None))

    out = jax.vmap(lambda ob, m: _combine_row(ob, m, S, D))(out_buf, meta)
    return out.astype(x.dtype), aux
