"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Faithful structure (token-shift LoRA mixers, low-rank decay, per-channel
bonus ``u``, per-head group norm) with the recurrence computed by the
generalized GLA scan (``repro.models.linear_attention`` on CPU/dry-run,
``repro.kernels.gla_scan`` on TPU).

Decode state per layer: time-mix shift (B, D), channel-mix shift (B, D),
wkv state (B, H, K, K).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, truncated_normal_init
from repro.models.linear_attention import gla_chunked, gla_step

MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_block_params(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    r = cfg.rwkv
    H = d // r.head_dim
    ks = jax.random.split(key, 16)
    import math
    sc = 1.0 / math.sqrt(d)
    hd = r.head_dim
    p = {
        # time-mix projections (head-major for TP alignment)
        "wr": truncated_normal_init(ks[0], (d, H, hd), sc),
        "wk": truncated_normal_init(ks[1], (d, H, hd), sc),
        "wv": truncated_normal_init(ks[2], (d, H, hd), sc),
        "wg": truncated_normal_init(ks[3], (d, H, hd), sc),
        "wo": truncated_normal_init(ks[4], (H, hd, d), sc),
        # token-shift base mixers + stacked LoRA for the 5 streams
        "maa_x": jnp.zeros((d,), jnp.float32),
        "maa": jnp.zeros((5, d), jnp.float32),
        "mix_lora_a": truncated_normal_init(ks[5], (5, d, r.mix_lora), 0.01),
        "mix_lora_b": truncated_normal_init(ks[6], (5, r.mix_lora, d), 0.01),
        # data-dependent decay (low-rank) + base
        "w0": (-6.0 + 5.0 * (jnp.arange(d) / max(d - 1, 1)) ** 0.9
               ).reshape(H, hd),
        "decay_lora_a": truncated_normal_init(ks[7], (d, r.decay_lora), 0.01),
        "decay_lora_b": truncated_normal_init(ks[8], (r.decay_lora, H, hd), 0.01),
        # per-channel bonus
        "u": truncated_normal_init(ks[9], (H, r.head_dim), 0.3),
        # per-head group norm
        "ln_x_scale": jnp.ones((H, hd), jnp.float32),
        "ln_x_bias": jnp.zeros((H, hd), jnp.float32),
        # channel-mix
        "cm_mu_k": jnp.full((d,), 0.5, jnp.float32),
        "cm_mu_r": jnp.full((d,), 0.5, jnp.float32),
        "cm_key": dense_init(ks[10], d, cfg.mlp.d_ff),
        "cm_value": dense_init(ks[11], cfg.mlp.d_ff, d),
        "cm_recept": dense_init(ks[12], d, d),
    }
    return p


def _group_norm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head layernorm over head_dim (RWKV's GroupNorm(H)).
    x: (B, T, H, hd); scale/bias: (H, hd)."""
    xh = x.astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = jnp.square(xh - mu).mean(-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return y * scale + bias


def _token_shift(x, shift_state: Optional[jnp.ndarray]):
    """Returns previous-token stream. x: (B,T,D); shift_state: (B,D)."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if shift_state is not None:
        prev = prev.at[:, 0].set(shift_state.astype(x.dtype))
    return prev


def rwkv_time_mix(x, p, cfg: ModelConfig, *, shift_state=None, wkv_state=None,
                  mode: str = "train"):
    d = cfg.d_model
    r = cfg.rwkv
    H = d // r.head_dim
    B, T, _ = x.shape
    xf = x.astype(jnp.float32)
    prev = _token_shift(xf, shift_state)
    xx = prev - xf
    xxx = xf + xx * p["maa_x"]
    # 5 low-rank token-shift mixers: (B,T,5,d)
    mix = jnp.einsum(
        "btsr,srd->btsd",
        jnp.tanh(jnp.einsum("btd,sdr->btsr", xxx, p["mix_lora_a"])),
        p["mix_lora_b"])
    streams = {}
    for i, name in enumerate(MIX_NAMES):
        streams[name] = xf + xx * (p["maa"][i] + mix[:, :, i])
    wt = streams["w"]
    kx = streams["k"].astype(x.dtype)
    vx = streams["v"].astype(x.dtype)
    rx = streams["r"].astype(x.dtype)
    gx = streams["g"].astype(x.dtype)

    rr = jnp.einsum("btd,dhk->bthk", rx, p["wr"].astype(x.dtype))
    kk = jnp.einsum("btd,dhk->bthk", kx, p["wk"].astype(x.dtype))
    vv = jnp.einsum("btd,dhk->bthk", vx, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("btd,dhk->bthk", gx, p["wg"].astype(x.dtype)))

    # data-dependent decay: log w = -exp(w0 + lora(wt)), in (-inf, 0)
    dlora = jnp.einsum("btr,rhk->bthk", jnp.tanh(wt @ p["decay_lora_a"]),
                       p["decay_lora_b"])
    log_w = -jnp.exp(jnp.clip(p["w0"] + dlora, -20.0, 10.0))

    if mode == "decode":
        o, new_state = gla_step(rr[:, 0], kk[:, 0], vv[:, 0], log_w[:, 0],
                                wkv_state, u=p["u"], mode="rwkv")
        o = o[:, None]  # (B,1,H,V)
    else:
        o, new_state = gla_chunked(rr, kk, vv, log_w, u=p["u"], mode="rwkv",
                                   initial_state=wkv_state)
    o = _group_norm_heads(o, p["ln_x_scale"], p["ln_x_bias"])
    out = jnp.einsum("bthk,hkd->btd", o.astype(x.dtype) * g,
                     p["wo"].astype(x.dtype))
    return out, xf[:, -1], new_state


def rwkv_channel_mix(x, p, *, shift_state=None):
    xf = x.astype(jnp.float32)
    prev = _token_shift(xf, shift_state)
    xx = prev - xf
    xk = (xf + xx * p["cm_mu_k"]).astype(x.dtype)
    xr = (xf + xx * p["cm_mu_r"]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cm_key"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["cm_recept"].astype(x.dtype)) * (
        k @ p["cm_value"].astype(x.dtype))
    return out, xf[:, -1]


def rwkv_state_shapes(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    H = d // cfg.rwkv.head_dim
    K = cfg.rwkv.head_dim
    return {
        "tm_shift": (cfg.n_layers, batch, d),
        "cm_shift": (cfg.n_layers, batch, d),
        "wkv": (cfg.n_layers, batch, H, K, K),
    }


# ---------------------------------------------------------------------------
# Full RWKV6 stack
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig) -> Dict:
    k_emb, k_layers, k_final, k0 = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: rwkv_block_params(k, cfg))(layer_keys)
    d = cfg.d_model
    return {
        "embed": truncated_normal_init(k_emb, (cfg.vocab_size, d), 1.0),
        "ln0_scale": jnp.ones((d,), jnp.float32),
        "ln0_bias": jnp.zeros((d,), jnp.float32),
        "layers": layers,
        # per-layer norms are stacked inside layers? kept separate for clarity
        "ln1_scale": jnp.ones((cfg.n_layers, d), jnp.float32),
        "ln1_bias": jnp.zeros((cfg.n_layers, d), jnp.float32),
        "ln2_scale": jnp.ones((cfg.n_layers, d), jnp.float32),
        "ln2_bias": jnp.zeros((cfg.n_layers, d), jnp.float32),
        "final_scale": jnp.ones((d,), jnp.float32),
        "final_bias": jnp.zeros((d,), jnp.float32),
        "lm_head": truncated_normal_init(k_final, (cfg.vocab_size, d), 1.0),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    ss = rwkv_state_shapes(cfg, batch)
    return {
        "tm_shift": jnp.zeros(ss["tm_shift"], jnp.float32),
        "cm_shift": jnp.zeros(ss["cm_shift"], jnp.float32),
        "wkv": jnp.zeros(ss["wkv"], jnp.float32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def rwkv_forward(params, cfg: ModelConfig, x, *, mode: str = "train",
                 cache: Optional[Dict] = None, remat: bool = False,
                 remat_policy: str = "minimal"):
    """x: (B,S,D) embeddings (post ln0 applied here). Returns
    (hidden, new_cache, aux=0)."""
    from repro.models.layers import layernorm
    from repro.distributed.axes import constrain

    B, S, _ = x.shape
    x = layernorm(x, params["ln0_scale"], params["ln0_bias"])
    lengths = cache["lengths"] if cache is not None else None

    if cache is not None:
        tm0, cm0, wkv0 = cache["tm_shift"], cache["cm_shift"], cache["wkv"]
    else:
        ss = rwkv_state_shapes(cfg, B)
        tm0 = jnp.zeros(ss["tm_shift"], jnp.float32)
        cm0 = jnp.zeros(ss["cm_shift"], jnp.float32)
        wkv0 = jnp.zeros(ss["wkv"], jnp.float32)

    use_state = cache is not None

    def body(h, inp):
        lp, l1s, l1b, l2s, l2b, tm_s, cm_s, wkv_s = inp
        hn = layernorm(h, l1s, l1b)
        out, tm_new, wkv_new = rwkv_time_mix(
            hn, lp, cfg,
            shift_state=tm_s if use_state else None,
            wkv_state=wkv_s if use_state else None,
            mode=mode if mode == "decode" else "train")
        h = h + out
        hn = layernorm(h, l2s, l2b)
        out, cm_new = rwkv_channel_mix(hn, lp, shift_state=cm_s if use_state else None)
        h = h + out
        h = constrain(h, ("batch", "seq", "embed"))
        return h, (tm_new, cm_new, wkv_new)

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    xs = (params["layers"], params["ln1_scale"], params["ln1_bias"],
          params["ln2_scale"], params["ln2_bias"], tm0, cm0, wkv0)
    h, (tm_new, cm_new, wkv_new) = jax.lax.scan(body, x, xs)

    new_cache = None
    if mode in ("prefill", "decode"):
        nl = (lengths + (1 if mode == "decode" else S)) if lengths is not None \
            else jnp.full((B,), S, jnp.int32)
        new_cache = {"tm_shift": tm_new, "cm_shift": cm_new, "wkv": wkv_new,
                     "lengths": nl}
    return h, new_cache, jnp.zeros((), jnp.float32)
