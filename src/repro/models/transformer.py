"""Decoder/encoder transformer stacks (dense / MoE / VLM / audio families).

Layers are *stacked* (leading ``n_layers`` dim) and executed with
``lax.scan`` so compile time stays flat for 56-layer models partitioned
over 512 devices. Remat is applied to the scan body for training.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import constrain
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, embed_init,
                                 mlp_params, norm_params)
from repro.models.moe import apply_moe, moe_params


def _layer_params(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": norm_params(ks[0], cfg.d_model, cfg.norm),
        "attn": attn.attn_params(ks[1], cfg.d_model, cfg.attention),
        "mlp_norm": norm_params(ks[2], cfg.d_model, cfg.norm),
    }
    if cfg.family == "moe":
        p["moe"] = moe_params(ks[3], cfg.d_model, cfg.moe)
    else:
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.mlp.d_ff, cfg.mlp.gated)
    return p


def init_transformer(key, cfg: ModelConfig) -> Dict:
    k_emb, k_layers, k_final = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_params(k, cfg))(layer_keys)
    params = {
        "layers": layers,
        "final_norm": norm_params(k_final, cfg.d_model, cfg.norm),
    }
    if not cfg.embed_stub or cfg.family in ("vlm",):
        params["embed"] = embed_init(k_emb, cfg.vocab_size, cfg.d_model)
    else:  # audio stub: inputs are frame embeddings; output head only
        params["embed"] = embed_init(k_emb, cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(
            jax.random.fold_in(k_emb, 1), cfg.vocab_size, cfg.d_model)
    return params


def _layer_apply(x, lp, cfg: ModelConfig, *, positions, mode, cache_kv,
                 lengths, kv_valid, impl):
    h = apply_norm(x, lp["attn_norm"], cfg.norm, cfg.norm_eps)
    h = constrain(h, ("batch", "seq_inner", "embed"))
    a_out, new_kv = attn.attention_block(
        h, lp["attn"], cfg.attention, positions=positions, mode=mode,
        cache=cache_kv, lengths=lengths, kv_valid=kv_valid, impl=impl)
    x = x + a_out
    x = constrain(x, ("batch", "seq", "embed"))
    h = apply_norm(x, lp["mlp_norm"], cfg.norm, cfg.norm_eps)
    h = constrain(h, ("batch", "seq_inner", "embed"))
    if cfg.family == "moe":
        m_out, aux = apply_moe(h, lp["moe"], cfg.moe,
                               act=cfg.mlp.activation if cfg.mlp else "silu")
    else:
        m_out = apply_mlp(h, lp["mlp"], cfg.mlp.activation, cfg.mlp.gated)
        aux = jnp.zeros((), jnp.float32)
    x = x + m_out
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_kv, aux


def transformer_forward(params, cfg: ModelConfig, x, *, positions,
                        mode: str = "train",
                        cache: Optional[Dict] = None,
                        kv_valid: Optional[jnp.ndarray] = None,
                        remat: bool = False,
                        attn_impl: str = "auto",
                        remat_policy: str = "minimal") -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B, S, D) embeddings. Returns (hidden (B,S,D), new_cache)."""
    lengths = cache["lengths"] if cache is not None else None

    def body(carry, lp_and_cache):
        h, aux_total = carry
        if mode == "decode":
            lp, ck, cv = lp_and_cache
            h, (nk, nv), aux = _layer_apply(
                h, lp, cfg, positions=positions, mode=mode, cache_kv=(ck, cv),
                lengths=lengths, kv_valid=kv_valid, impl=attn_impl)
            return (h, aux_total + aux), (nk, nv)
        lp = lp_and_cache
        h, (nk, nv), aux = _layer_apply(
            h, lp, cfg, positions=positions, mode=mode, cache_kv=None,
            lengths=lengths, kv_valid=kv_valid, impl=attn_impl)
        if mode == "prefill":
            return (h, aux_total + aux), (nk, nv)
        return (h, aux_total + aux), None

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    xs = params["layers"] if mode != "decode" else (
        params["layers"], cache["k"], cache["v"])
    (h, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)

    new_cache = None
    if mode == "decode":
        nk, nv = ys
        new_cache = {"k": nk, "v": nv, "lengths": lengths + 1}
    elif mode == "prefill":
        nk, nv = ys  # (L, B, S, KV, D)
        W = attn.cache_window(cfg.attention, cfg.max_seq_len)
        new_cache = {"computed_k": nk, "computed_v": nv}
    return h, new_cache, aux


def fill_cache_from_prefill(cfg: ModelConfig, computed_k, computed_v,
                            prefill_len: int, max_len: int,
                            lengths: Optional[jnp.ndarray] = None) -> Dict:
    """Build a decode cache from prefill-computed K/V (ring-aware for SWA)."""
    L, B, S, KV, D = computed_k.shape
    W = attn.cache_window(cfg.attention, max_len)
    keep = min(S, W)
    src_k = computed_k[:, :, S - keep:]
    src_v = computed_v[:, :, S - keep:]
    slots = (jnp.arange(keep) + (S - keep)) % W
    ck = jnp.zeros((L, B, W, KV, D), computed_k.dtype).at[:, :, slots].set(src_k)
    cv = jnp.zeros((L, B, W, KV, D), computed_v.dtype).at[:, :, slots].set(src_v)
    if lengths is None:
        lengths = jnp.full((B,), prefill_len, jnp.int32)
    return {"k": ck, "v": cv, "lengths": lengths}


def embed_tokens(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    e = params["embed"][tokens]
    e = constrain(e, ("batch", "seq", "embed"))
    return e.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)


def lm_logits(params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,vd->...v", h, head.astype(h.dtype))
    return constrain(logits, ("batch", "seq", "vocab"))
