"""Zamba2 hybrid stack: Mamba2 backbone + shared attention blocks.

Every ``shared_attn_every`` backbone layers, one of ``shared_attn_copies``
alternating shared transformer blocks (attention + MLP) is applied, each
application with its own KV-cache slot. The backbone scan uses
``lax.cond`` so the body compiles once.

Deviation from the released Zamba2 (documented in DESIGN.md): the shared
block input is the residual stream (not concat(embedding, hidden)), and
per-application LoRA adapters are omitted.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, embed_init,
                                 mlp_params, norm_params)
from repro.models.mamba import mamba_block, mamba_block_params, mamba_state_shapes
from repro.distributed.axes import constrain


def n_shared_applications(cfg: ModelConfig) -> int:
    every = cfg.zamba.shared_attn_every
    return (cfg.n_layers + every - 1) // every


def init_zamba(key, cfg: ModelConfig) -> Dict:
    k_emb, k_layers, k_shared, k_final = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: mamba_block_params(k, cfg))(layer_keys)

    def shared_block(k):
        ks = jax.random.split(k, 4)
        return {
            "attn_norm": norm_params(ks[0], cfg.d_model, cfg.norm),
            "attn": attn.attn_params(ks[1], cfg.d_model, cfg.attention),
            "mlp_norm": norm_params(ks[2], cfg.d_model, cfg.norm),
            "mlp": mlp_params(ks[3], cfg.d_model, cfg.mlp.d_ff, cfg.mlp.gated),
        }

    shared_keys = jax.random.split(k_shared, cfg.zamba.shared_attn_copies)
    shared = jax.vmap(shared_block)(shared_keys)
    return {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model),
        "layers": layers,
        "shared": shared,
        "final_norm": norm_params(k_final, cfg.d_model, cfg.norm),
        "lm_head": embed_init(jax.random.fold_in(k_emb, 1),
                              cfg.vocab_size, cfg.d_model),
    }


def init_zamba_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Dict:
    n_app = n_shared_applications(cfg)
    W = attn.cache_window(cfg.attention, max_len)
    a = cfg.attention
    ss = mamba_state_shapes(cfg, batch)
    return {
        "k": jnp.zeros((n_app, batch, W, a.n_kv_eff, a.head_dim), dtype),
        "v": jnp.zeros((n_app, batch, W, a.n_kv_eff, a.head_dim), dtype),
        "conv_x": jnp.zeros(ss["conv_x"], dtype),
        "conv_bc": jnp.zeros(ss["conv_bc"], dtype),
        "ssm": jnp.zeros(ss["ssm"], jnp.float32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def _shared_apply(x, sp, cfg: ModelConfig, *, positions, mode, cache_kv,
                  lengths, kv_valid, impl):
    h = apply_norm(x, sp["attn_norm"], cfg.norm, cfg.norm_eps)
    a_out, new_kv = attn.attention_block(
        h, sp["attn"], cfg.attention, positions=positions, mode=mode,
        cache=cache_kv, lengths=lengths, kv_valid=kv_valid, impl=impl)
    x = x + a_out
    h = apply_norm(x, sp["mlp_norm"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, sp["mlp"], cfg.mlp.activation, cfg.mlp.gated)
    return x, new_kv


def zamba_forward(params, cfg: ModelConfig, x, *, positions,
                  mode: str = "train", cache: Optional[Dict] = None,
                  kv_valid: Optional[jnp.ndarray] = None,
                  remat: bool = False, attn_impl: str = "auto",
                  remat_policy: str = "minimal"):
    """x: (B,S,D). Returns (hidden, new_cache, aux=0)."""
    every = cfg.zamba.shared_attn_every
    copies = cfg.zamba.shared_attn_copies
    n_app = n_shared_applications(cfg)
    B, S, _ = x.shape
    lengths = cache["lengths"] if cache is not None else None
    decode = mode == "decode"
    a = cfg.attention

    if cache is not None:
        cx0, cbc0, ssm0 = cache["conv_x"], cache["conv_bc"], cache["ssm"]
        kc0, vc0 = cache["k"], cache["v"]
    else:
        ss = mamba_state_shapes(cfg, B)
        cx0 = jnp.zeros(ss["conv_x"], x.dtype)
        cbc0 = jnp.zeros(ss["conv_bc"], x.dtype)
        ssm0 = jnp.zeros(ss["ssm"], jnp.float32)
        if mode == "prefill":
            # raw computed K/V per application; caller builds the ring cache
            kc0 = jnp.zeros((n_app, B, S, a.n_kv_eff, a.head_dim), x.dtype)
            vc0 = jnp.zeros_like(kc0)
        else:
            kc0 = vc0 = None

    def mamba_body(h, inp):
        lp, cx_s, cbc_s, ssm_s = inp
        h, (new_cx, new_cbc), new_ssm = mamba_block(
            h, lp, cfg, conv_state=(cx_s, cbc_s), ssm_state=ssm_s,
            mode="decode" if decode else "train")
        h = constrain(h, ("batch", "seq", "embed"))
        return h, (new_cx, new_cbc, new_ssm)

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        mamba_body = jax.checkpoint(mamba_body, policy=policy)

    # Segment structure (python loop => exact HLO op counts for roofline):
    # for each application g: shared attn block (copy g % copies), then a
    # lax.scan over the next `every` mamba layers.
    h = x
    kc, vc = kc0, vc0
    new_cx_segs, new_cbc_segs, new_ssm_segs = [], [], []
    for g in range(n_app):
        lo = g * every
        hi = min((g + 1) * every, cfg.n_layers)
        sp = jax.tree.map(lambda q: q[g % copies], params["shared"])
        if mode == "train":
            h, _ = _shared_apply(h, sp, cfg, positions=positions, mode=mode,
                                 cache_kv=None, lengths=lengths,
                                 kv_valid=kv_valid, impl=attn_impl)
        elif decode:
            h, (nk, nv) = _shared_apply(
                h, sp, cfg, positions=positions, mode=mode,
                cache_kv=(kc[g], vc[g]), lengths=lengths,
                kv_valid=kv_valid, impl=attn_impl)
            kc = kc.at[g].set(nk)
            vc = vc.at[g].set(nv)
        else:  # prefill
            h, (nk, nv) = _shared_apply(
                h, sp, cfg, positions=positions, mode=mode,
                cache_kv=None, lengths=lengths,
                kv_valid=kv_valid, impl=attn_impl)
            kc = kc.at[g].set(nk.astype(kc.dtype))
            vc = vc.at[g].set(nv.astype(vc.dtype))
        xs = (jax.tree.map(lambda t: t[lo:hi], params["layers"]),
              cx0[lo:hi], cbc0[lo:hi], ssm0[lo:hi])
        h, (cx_seg, cbc_seg, ssm_seg) = jax.lax.scan(mamba_body, h, xs)
        new_cx_segs.append(cx_seg)
        new_cbc_segs.append(cbc_seg)
        new_ssm_segs.append(ssm_seg)

    new_cx = jnp.concatenate(new_cx_segs, axis=0)
    new_cbc = jnp.concatenate(new_cbc_segs, axis=0)
    new_ssm = jnp.concatenate(new_ssm_segs, axis=0)

    new_cache = None
    if decode:
        new_cache = {"k": kc, "v": vc, "conv_x": new_cx,
                     "conv_bc": new_cbc, "ssm": new_ssm,
                     "lengths": lengths + 1}
    elif mode == "prefill":
        new_cache = {"computed_k": kc, "computed_v": vc,
                     "conv_x": new_cx, "conv_bc": new_cbc, "ssm": new_ssm}
    return h, new_cache, jnp.zeros((), jnp.float32)


def fill_zamba_cache_from_prefill(cfg: ModelConfig, pre: Dict, prefill_len: int,
                                  max_len: int, batch: int,
                                  dtype=jnp.bfloat16) -> Dict:
    """Convert prefill outputs into a ring decode cache."""
    a = cfg.attention
    W = attn.cache_window(a, max_len)
    ck_raw, cv_raw = pre["computed_k"], pre["computed_v"]
    S = ck_raw.shape[2]
    keep = min(S, W)
    slots = (jnp.arange(keep) + (S - keep)) % W
    n_app = ck_raw.shape[0]
    ck = jnp.zeros((n_app, batch, W, a.n_kv_eff, a.head_dim), dtype)
    cv = jnp.zeros_like(ck)
    ck = ck.at[:, :, slots].set(ck_raw[:, :, S - keep:].astype(dtype))
    cv = cv.at[:, :, slots].set(cv_raw[:, :, S - keep:].astype(dtype))
    return {"k": ck, "v": cv, "conv_x": pre["conv_x"],
            "conv_bc": pre["conv_bc"], "ssm": pre["ssm"],
            "lengths": jnp.full((batch,), prefill_len, jnp.int32)}
