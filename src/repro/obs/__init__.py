"""Dual-clock observability: sim-time flight recorder + wall-clock
sweep profiler, online physics-invariant auditing, and a
first-divergence explainer (``python -m repro.obs`` for the
record/diff CLI).

Two clocks, one contract:

* **sim-time** — the opt-in ``Probe`` protocol threaded through the
  event loop and the fleet/day drivers; ``FlightRecorder`` logs queue
  depth, batch occupancy, KV usage, routing, autoscaling, epoch
  evaluations and per-bin Eq. 1-5 power/CI/carbon timelines. Probe-off
  runs are bitwise identical to un-instrumented ones (neutrality,
  pinned by tests/test_obs.py).
* **wall-clock** — the ``SpanProfiler`` (module-global ``PROFILER``)
  over the sweep pipeline: cache lookups, trace grouping, event-loop
  runs, stacked passes, device-mode jit compile vs execute, worker
  fan-out.

On top of the probe layer:

* ``AuditProbe`` (``repro.obs.audit``) streams conservation-law and
  sanity checks — request/token conservation, Eq. 2-3 and Eq. 4-5
  closure, KV-budget/monotonic-clock invariants, power-range,
  autoscaler legality — into a structured ``AuditReport``; stack it
  with a recorder via ``MultiProbe``.
* ``repro.obs.diff`` localizes the *first* divergent (scenario,
  stage, column) cell between two runs — sweep records, golden
  records or flight traces — and classifies every divergence against
  the repo's named tolerance contracts.

Traces serialize to Perfetto-viewable Chrome trace-event JSON and tidy
CSV (``repro.obs.chrometrace``); divergence reports to markdown + JSON
under ``results/obs/divergence/``.
"""
from repro.obs.audit import (AuditError, AuditProbe, AuditReport,
                             AuditViolation)
from repro.obs.chrometrace import (chrome_trace_events, write_chrome_trace,
                                   write_csvs)
from repro.obs.diff import (DiffResult, DivergentCell, assert_golden,
                            diff_golden, diff_records, diff_stage_tables,
                            write_report)
from repro.obs.log import configure as configure_logging
from repro.obs.log import get_logger
from repro.obs.probe import (NULL_PROBE, MultiProbe, NullProbe, Probe,
                             SiteIndexProbe)
from repro.obs.recorder import ColumnBuilder, FlightRecorder
from repro.obs.spans import PROFILER, SpanProfiler

__all__ = [
    "Probe", "NullProbe", "NULL_PROBE", "MultiProbe", "SiteIndexProbe",
    "FlightRecorder", "ColumnBuilder",
    "AuditProbe", "AuditReport", "AuditViolation", "AuditError",
    "DiffResult", "DivergentCell", "diff_records", "diff_golden",
    "diff_stage_tables", "assert_golden", "write_report",
    "SpanProfiler", "PROFILER",
    "chrome_trace_events", "write_chrome_trace", "write_csvs",
    "get_logger", "configure_logging",
]
