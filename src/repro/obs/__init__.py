"""Dual-clock observability: sim-time flight recorder + wall-clock
sweep profiler (``python -m repro.obs`` for the record CLI).

Two clocks, one contract:

* **sim-time** — the opt-in ``Probe`` protocol threaded through the
  event loop and the fleet/day drivers; ``FlightRecorder`` logs queue
  depth, batch occupancy, KV usage, routing, autoscaling, epoch
  evaluations and per-bin Eq. 1-5 power/CI/carbon timelines. Probe-off
  runs are bitwise identical to un-instrumented ones (neutrality,
  pinned by tests/test_obs.py).
* **wall-clock** — the ``SpanProfiler`` (module-global ``PROFILER``)
  over the sweep pipeline: cache lookups, trace grouping, event-loop
  runs, stacked passes, device-mode jit compile vs execute, worker
  fan-out.

Both serialize to Perfetto-viewable Chrome trace-event JSON and tidy
CSV (``repro.obs.chrometrace``).
"""
from repro.obs.chrometrace import (chrome_trace_events, write_chrome_trace,
                                   write_csvs)
from repro.obs.log import configure as configure_logging
from repro.obs.log import get_logger
from repro.obs.probe import NULL_PROBE, NullProbe, Probe, SiteIndexProbe
from repro.obs.recorder import ColumnBuilder, FlightRecorder
from repro.obs.spans import PROFILER, SpanProfiler

__all__ = [
    "Probe", "NullProbe", "NULL_PROBE", "SiteIndexProbe",
    "FlightRecorder", "ColumnBuilder",
    "SpanProfiler", "PROFILER",
    "chrome_trace_events", "write_chrome_trace", "write_csvs",
    "get_logger", "configure_logging",
]
