"""Flight-recorder CLI: run one sweep scenario with full dual-clock
instrumentation and export Perfetto-viewable traces.

Examples:

    # record the day-smoke config's flight trace + CSVs
    PYTHONPATH=src python -m repro.obs record day --smoke \\
        --out results/obs/day_trace.json --csv-dir results/obs

    # list recordable scenarios
    PYTHONPATH=src python -m repro.obs list --smoke

``record`` executes one scenario from the sweep registry with a
``FlightRecorder`` attached and the wall-clock ``SpanProfiler``
enabled, then writes both clocks to one Chrome trace-event JSON
(open it at https://ui.perfetto.dev) and, optionally, tidy CSVs.
The probe only observes: the scenario's metrics are bit-identical to
an unrecorded run (tests/test_obs.py pins this).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs.chrometrace import write_chrome_trace, write_csvs
from repro.obs.log import configure, get_logger
from repro.obs.recorder import FlightRecorder
from repro.obs.spans import PROFILER

_log = get_logger("repro.obs")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Sim-time flight recorder + wall-clock profiler "
                    "over single sweep scenarios.")
    p.add_argument("-v", "--verbose", action="count", default=0)
    p.add_argument("--quiet", action="store_true")
    sub = p.add_subparsers(dest="cmd", required=True)

    ls = sub.add_parser("list", help="list recordable sweep scenarios")
    ls.add_argument("--smoke", action="store_true")

    rec = sub.add_parser("record",
                         help="record one scenario's flight trace")
    rec.add_argument("sweep", metavar="SWEEP",
                     help="sweep name from the registry "
                          "(python -m repro.obs list)")
    rec.add_argument("--index", type=int, default=0,
                     help="scenario index within the sweep (default 0)")
    rec.add_argument("--smoke", action="store_true",
                     help="smoke-scale grids (CI mode)")
    rec.add_argument("--n-requests", type=int, default=None)
    rec.add_argument("--resolution", type=float, default=60.0,
                     help="timeline bin width in sim seconds "
                          "(default 60; observer-only, never changes "
                          "the simulation)")
    rec.add_argument("--out", type=Path, default=None,
                     help="Chrome trace JSON path (default "
                          "results/obs/<sweep><index>.trace.json)")
    rec.add_argument("--csv-dir", type=Path, default=None,
                     help="also export tidy CSVs into this directory")
    return p


def _cmd_list(args) -> int:
    from repro.sweep.scenarios import SWEEPS
    for name, sweep in SWEEPS.items():
        scs = sweep.build(args.smoke)
        print(f"{name:8s} {len(scs):3d} scenario(s)  {sweep.title}")
    return 0


def _cmd_record(args) -> int:
    from repro.sweep.runner import execute_scenario
    from repro.sweep.scenarios import SWEEPS

    if args.sweep not in SWEEPS:
        print(f"unknown sweep {args.sweep!r}; available: "
              f"{', '.join(SWEEPS)}", file=sys.stderr)
        return 2
    scenarios = SWEEPS[args.sweep].build(args.smoke,
                                         n_requests=args.n_requests)
    if not 0 <= args.index < len(scenarios):
        print(f"--index {args.index} out of range "
              f"(sweep has {len(scenarios)} scenarios)", file=sys.stderr)
        return 2
    sc = scenarios[args.index]
    _log.info("recording %s (scenario %d/%d: %s)", args.sweep,
              args.index, len(scenarios), sc.tag)

    recorder = FlightRecorder(resolution_s=args.resolution)
    PROFILER.enable(reset=True)
    try:
        with PROFILER.span("execute_scenario"):
            record = execute_scenario(sc, probe=recorder)
    finally:
        PROFILER.disable()

    out = args.out or (Path("results") / "obs"
                       / f"{args.sweep}{args.index}.trace.json")
    info = write_chrome_trace(out, recorder, PROFILER)
    counts = recorder.counts()
    summary = {
        "sweep": args.sweep, "index": args.index,
        "scenario": record["scenario"], "key": record["key"],
        **counts,
        "has_carbon_timeline": any("carbon_g" in t for t in
                                   recorder.timelines.values()),
        "trace": info["path"], "trace_events": info["n_events"],
    }
    if args.csv_dir is not None:
        paths = write_csvs(args.csv_dir, recorder, PROFILER)
        summary["csv_files"] = [str(p) for p in paths]
    print(json.dumps(summary, indent=1))
    _log.info("open %s at https://ui.perfetto.dev", info["path"])
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    configure(verbosity=(-1 if args.quiet else args.verbose))
    if args.cmd == "list":
        return _cmd_list(args)
    return _cmd_record(args)


if __name__ == "__main__":
    sys.exit(main())
