"""Flight-recorder + divergence-explainer CLI.

Examples:

    # record the day-smoke config's flight trace + CSVs
    PYTHONPATH=src python -m repro.obs record day --smoke \\
        --out results/obs/day_trace.json --csv-dir results/obs

    # list recordable scenarios
    PYTHONPATH=src python -m repro.obs list --smoke

    # explain the first divergence between two sweep result sets
    PYTHONPATH=src python -m repro.obs diff \\
        results/a/fig1.json results/b/fig1.json

    # golden-drift gate: ANY divergence fails (exit 1)
    PYTHONPATH=src python -m repro.obs diff \\
        results/sweep/fig1.json golden.json --golden

``record`` executes one scenario from the sweep registry with a
``FlightRecorder`` attached and the wall-clock ``SpanProfiler``
enabled, then writes both clocks to one Chrome trace-event JSON
(open it at https://ui.perfetto.dev) and, optionally, tidy CSVs.
The probe only observes: the scenario's metrics are bit-identical to
an unrecorded run (tests/test_obs.py pins this).

``diff`` compares two artifacts — sweep result JSONs (a ``records``
payload), golden/metrics dicts, or flight-trace ``stages.csv``
exports — walks the columns in Eq. 1-5 dependency order to localize
the *first* divergent (scenario, stage, column) cell, classifies each
divergence against the named tolerance contracts, and writes the
markdown + JSON report under ``results/obs/divergence/``. Exit code:
1 when any cell is a ``regression`` (or, under ``--golden``, on any
divergence at all), else 0.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs.chrometrace import write_chrome_trace, write_csvs
from repro.obs.diff import (DIVERGENCE_DIR, diff_golden, diff_records,
                            diff_stage_tables, write_report)
from repro.obs.log import configure, get_logger
from repro.obs.recorder import FlightRecorder
from repro.obs.spans import PROFILER

_log = get_logger("repro.obs")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Sim-time flight recorder + wall-clock profiler "
                    "over single sweep scenarios.")
    p.add_argument("-v", "--verbose", action="count", default=0)
    p.add_argument("--quiet", action="store_true")
    sub = p.add_subparsers(dest="cmd", required=True)

    ls = sub.add_parser("list", help="list recordable sweep scenarios")
    ls.add_argument("--smoke", action="store_true")

    rec = sub.add_parser("record",
                         help="record one scenario's flight trace")
    rec.add_argument("sweep", metavar="SWEEP",
                     help="sweep name from the registry "
                          "(python -m repro.obs list)")
    rec.add_argument("--index", type=int, default=0,
                     help="scenario index within the sweep (default 0)")
    rec.add_argument("--smoke", action="store_true",
                     help="smoke-scale grids (CI mode)")
    rec.add_argument("--n-requests", type=int, default=None)
    rec.add_argument("--resolution", type=float, default=60.0,
                     help="timeline bin width in sim seconds "
                          "(default 60; observer-only, never changes "
                          "the simulation)")
    rec.add_argument("--out", type=Path, default=None,
                     help="Chrome trace JSON path (default "
                          "results/obs/<sweep><index>.trace.json)")
    rec.add_argument("--csv-dir", type=Path, default=None,
                     help="also export tidy CSVs into this directory")

    df = sub.add_parser(
        "diff", help="localize + classify the first divergence "
                     "between two runs")
    df.add_argument("a", metavar="A", type=Path,
                    help="sweep-result JSON, metrics/golden JSON, or "
                         "stage-table CSV")
    df.add_argument("b", metavar="B", type=Path,
                    help="artifact to compare against (same kinds)")
    df.add_argument("--golden", action="store_true",
                    help="treat B as a golden record: bit-exact gate, "
                         "exit 1 on any divergence")
    df.add_argument("--index", type=int, default=0,
                    help="with --golden and a records-file A: which "
                         "record's metrics to gate (default 0)")
    df.add_argument("--name", default="diff",
                    help="report basename (default 'diff')")
    df.add_argument("--report-dir", type=Path, default=None,
                    help=f"report directory (default {DIVERGENCE_DIR})")
    return p


def _cmd_list(args) -> int:
    from repro.sweep.scenarios import SWEEPS
    for name, sweep in SWEEPS.items():
        scs = sweep.build(args.smoke)
        print(f"{name:8s} {len(scs):3d} scenario(s)  {sweep.title}")
    return 0


def _cmd_record(args) -> int:
    from repro.sweep.runner import execute_scenario
    from repro.sweep.scenarios import SWEEPS

    if args.sweep not in SWEEPS:
        print(f"unknown sweep {args.sweep!r}; available: "
              f"{', '.join(SWEEPS)}", file=sys.stderr)
        return 2
    scenarios = SWEEPS[args.sweep].build(args.smoke,
                                         n_requests=args.n_requests)
    if not 0 <= args.index < len(scenarios):
        print(f"--index {args.index} out of range "
              f"(sweep has {len(scenarios)} scenarios)", file=sys.stderr)
        return 2
    sc = scenarios[args.index]
    _log.info("recording %s (scenario %d/%d: %s)", args.sweep,
              args.index, len(scenarios), sc.tag)

    recorder = FlightRecorder(resolution_s=args.resolution)
    PROFILER.enable(reset=True)
    try:
        with PROFILER.span("execute_scenario"):
            record = execute_scenario(sc, probe=recorder)
    finally:
        PROFILER.disable()

    out = args.out or (Path("results") / "obs"
                       / f"{args.sweep}{args.index}.trace.json")
    info = write_chrome_trace(out, recorder, PROFILER)
    counts = recorder.counts()
    summary = {
        "sweep": args.sweep, "index": args.index,
        "scenario": record["scenario"], "key": record["key"],
        **counts,
        "has_carbon_timeline": any("carbon_g" in t for t in
                                   recorder.timelines.values()),
        "trace": info["path"], "trace_events": info["n_events"],
    }
    if args.csv_dir is not None:
        paths = write_csvs(args.csv_dir, recorder, PROFILER)
        summary["csv_files"] = [str(p) for p in paths]
    print(json.dumps(summary, indent=1))
    _log.info("open %s at https://ui.perfetto.dev", info["path"])
    return 0


def _load_artifact(path: Path):
    """Classify + load one diff operand: ``("table", cols)`` for a
    stage-table CSV, ``("records", list)`` for a sweep-result payload,
    ``("metrics", dict)`` for a golden/metrics dict."""
    import csv

    import numpy as np
    if path.suffix.lower() == ".csv":
        with path.open(newline="") as f:
            rows = list(csv.reader(f))
        if not rows:
            return "table", {}
        header, body = rows[0], rows[1:]
        cols = {h: np.asarray([float(r[j]) for r in body], np.float64)
                for j, h in enumerate(header)}
        return "table", cols
    data = json.loads(path.read_text())
    if isinstance(data, list):
        return "records", data
    if isinstance(data, dict) and isinstance(data.get("records"), list):
        return "records", data["records"]
    if isinstance(data, dict) and isinstance(data.get("metrics"), dict):
        return "metrics", data["metrics"]
    if isinstance(data, dict):
        return "metrics", data
    raise ValueError(f"unrecognized artifact shape in {path}")


def _cmd_diff(args) -> int:
    try:
        kind_a, a = _load_artifact(args.a)
        kind_b, b = _load_artifact(args.b)
    except (ValueError, OSError, json.JSONDecodeError) as exc:
        print(f"cannot load artifacts: {exc}", file=sys.stderr)
        return 2
    la, lb = str(args.a), str(args.b)
    if args.golden:
        if kind_b == "table":
            print("--golden expects a metrics/records JSON for B",
                  file=sys.stderr)
            return 2
        if kind_b == "records":
            b = b[args.index].get("metrics", {}) \
                if 0 <= args.index < len(b) else {}
        if kind_a == "records":
            if not 0 <= args.index < len(a):
                print(f"--index {args.index} out of range "
                      f"(A has {len(a)} records)", file=sys.stderr)
                return 2
            a = a[args.index].get("metrics", {})
        elif kind_a == "table":
            print("--golden expects a metrics/records JSON for A",
                  file=sys.stderr)
            return 2
        result = diff_golden(a, b, scenario=args.name,
                             label_a=la, label_b=lb)
    elif kind_a != kind_b:
        print(f"cannot compare {kind_a} ({la}) against {kind_b} ({lb})",
              file=sys.stderr)
        return 2
    elif kind_a == "table":
        result = diff_stage_tables(a, b, scenario=args.name,
                                   label_a=la, label_b=lb)
    elif kind_a == "records":
        result = diff_records(a, b, label_a=la, label_b=lb)
    else:
        result = diff_golden(a, b, scenario=args.name,
                             label_a=la, label_b=lb)
    paths = write_report(result, args.name, outdir=args.report_dir)
    print(result.summary())
    print(f"report: {paths['md']}")
    if args.golden:
        return 0 if result.identical else 1
    return 1 if result.has_regression else 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    configure(verbosity=(-1 if args.quiet else args.verbose))
    if args.cmd == "list":
        return _cmd_list(args)
    if args.cmd == "diff":
        return _cmd_diff(args)
    return _cmd_record(args)


if __name__ == "__main__":
    sys.exit(main())
