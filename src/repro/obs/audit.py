"""Online physics-invariant auditing over the Probe protocol.

``AuditProbe`` rides the same hooks as the ``FlightRecorder`` (stack
them with ``MultiProbe``) and streams conservation-law and sanity
checks while the simulation runs:

* **clock-monotonic** — per-(site, replica) stage start times never go
  backwards (streamed, with epoch-boundary resets), routing instants
  are non-decreasing (requests are routed in ready order), and every
  trace row carries a positive finite duration with per-replica
  non-decreasing start times (vectorized at site rollup);
* **kv-budget** — the live scheduler's KV occupancy stays within
  ``[0, kv_budget_tokens + decode growth]`` at every committed stage
  (the budget gates admission by prompt tokens; decode then grows the
  cache one token per running request per iteration);
* **batch-cap** — recorded batch sizes never exceed ``batch_cap``
  (vectorized over the trace at site rollup);
* **request-conservation** — every request is routed at most once,
  completions never outnumber admissions (admitted = completed +
  in-flight at every event), and at finalize every generated request
  was routed exactly once;
* **request-lifecycle** — a completed request finished after its
  admission release, served its full token counts, and produced its
  first token before it was done;
* **token-conservation** — tokens of completed requests never exceed
  the tokens the stage log actually processed (completion events
  stream in; the exact totals close at site rollup, where the first
  breaching completion instant is localized against the trace);
* **autoscale-legality** — autoscaler transitions carry legal kinds,
  step the active set by exactly one in the advertised direction, and
  keep non-negative warm-spare counts;
* **admission-legality** — admission releases never precede arrivals;
* **mfu-range** / **power-range** — Eq. 1 inputs/outputs stay inside
  ``[0, 1]`` and ``[P_idle, P_peak]`` per device;
* **eq23-closure** — the per-stage attributed energy sums to the
  trace-level ``operational_energy_trace`` figure the driver reported
  (``EQ23_CLOSURE_RTOL``);
* **eq45-closure** — active + idle-bin energy/carbon integrated from
  the Eq. 5 load profile equals the microgrid co-sim totals
  (``EQ45_CLOSURE_RTOL``; the co-sim reduces in float32, hence the
  looser tolerance).

Violations accumulate into a structured ``AuditReport`` — each with
its contract name, run tag, first-violation sim-time, site, stage
index and expected/actual values — instead of raising mid-run, so one
auditor can survey a whole sweep. ``strict=True`` raises ``AuditError``
at the first violation (for tests).

The auditor is an *observer*: it never mutates schedulers, requests or
traces, so audit-on runs stay bitwise identical to probe-off runs
(neutrality, pinned by tests/test_audit.py) and its overhead is
bounded by ``benchmarks/perf_sweep.py --check-audit`` (<= 3% over
``NULL_PROBE``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.power import DEVICES
from repro.obs.probe import Probe

#: Eq. 2-3 closure: the auditor recomputes the per-stage attributed
#: energy *independently* (Eq. 1 power in float64 numpy) and compares
#: against the driver's float32-jax trace reduction — float32 power
#: evaluation bounds the agreement at ~1e-7; 1e-5 leaves two orders
#: of headroom.
EQ23_CLOSURE_RTOL = 1e-5
#: Eq. 4-5 closure: the microgrid co-sim reduces its load/CI arrays in
#: float32 (jax default dtype), so recomputing the same integrals in
#: float64 agrees to ~1e-6; 1e-4 leaves two orders of headroom.
EQ45_CLOSURE_RTOL = 1e-4
#: Eq. 1 range check headroom: power is evaluated in float32.
POWER_RANGE_RTOL = 1e-5

#: every contract the auditor can check (report rows appear in this
#: order; diff classes are unrelated — see repro.obs.diff)
CONTRACTS = (
    "clock-monotonic", "kv-budget", "batch-cap",
    "request-conservation", "request-lifecycle", "token-conservation",
    "autoscale-legality", "admission-legality",
    "mfu-range", "power-range", "eq23-closure", "eq45-closure",
)

_SCALE_KINDS = ("init", "up_warm", "up_cold", "down")


@dataclasses.dataclass
class AuditViolation:
    """One observed invariant breach, localized to its first offending
    event."""
    contract: str
    run: str                  # scenario tag ("" before any on_run_begin)
    site: int
    stage: int                # per-site stage index (-1: not stage-scoped)
    t_s: float                # sim-time of the event (-1.0: finalize)
    expected: str
    actual: str
    detail: str = ""

    def format(self) -> str:
        where = f"site {self.site}"
        if self.stage >= 0:
            where += f" stage {self.stage}"
        if self.t_s >= 0.0:
            where += f" t={self.t_s:.6g}s"
        run = f" [{self.run}]" if self.run else ""
        tail = f" ({self.detail})" if self.detail else ""
        return (f"{self.contract}{run} @ {where}: expected "
                f"{self.expected}, got {self.actual}{tail}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class AuditError(AssertionError):
    """Raised by ``AuditProbe(strict=True)`` at the first violation."""

    def __init__(self, violation: AuditViolation):
        super().__init__(violation.format())
        self.violation = violation


@dataclasses.dataclass
class AuditReport:
    """Structured audit outcome: every recorded violation (detection
    order — ``first`` is the earliest breach) plus per-contract check
    counters, so "clean" is distinguishable from "never checked"."""
    violations: List[AuditViolation]
    checks: Dict[str, int]          # contract -> checks evaluated
    runs: int                       # run boundaries observed
    dropped: int = 0                # violations beyond the per-contract cap

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def first(self) -> Optional[AuditViolation]:
        return self.violations[0] if self.violations else None

    @property
    def n_checks(self) -> int:
        return sum(self.checks.values())

    def by_contract(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.violations:
            out[v.contract] = out.get(v.contract, 0) + 1
        return out

    def summary(self) -> str:
        if self.ok:
            return (f"clean — {self.n_checks} check(s) across "
                    f"{len(self.checks)} contract(s), "
                    f"{self.runs} run(s)")
        extra = f" (+{self.dropped} beyond cap)" if self.dropped else ""
        return (f"{len(self.violations)} violation(s){extra} in "
                f"{len(self.by_contract())} contract(s); first: "
                f"{self.first.format()}")

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "runs": self.runs,
            "n_checks": self.n_checks,
            "checks": dict(self.checks),
            "dropped": self.dropped,
            "by_contract": self.by_contract(),
            "violations": [v.to_dict() for v in self.violations],
        }

    def to_markdown(self) -> str:
        lines = ["# Audit report", "", f"- result: {self.summary()}",
                 f"- runs observed: {self.runs}", ""]
        lines += ["| contract | checks | violations |",
                  "|---|---:|---:|"]
        by = self.by_contract()
        for c in CONTRACTS:
            if c in self.checks or c in by:
                lines.append(f"| {c} | {self.checks.get(c, 0)} | "
                             f"{by.get(c, 0)} |")
        if self.violations:
            lines += ["", "## Violations (detection order)", "",
                      "| contract | run | site | stage | t_s | "
                      "expected | actual |", "|---|---|---:|---:|---:|"
                      "---|---|"]
            for v in self.violations:
                lines.append(
                    f"| {v.contract} | {v.run} | {v.site} | {v.stage} "
                    f"| {v.t_s:.6g} | {v.expected} | {v.actual} |")
        return "\n".join(lines) + "\n"


class AuditProbe(Probe):
    """Streaming invariant auditor (see module docstring).

    ``strict=True`` raises ``AuditError`` at the first breach;
    ``max_per_contract`` caps stored violations per (run, contract)
    pair so a systematically-broken run can't grow the report without
    bound (overflow is counted in ``AuditReport.dropped``).
    """

    __slots__ = ("strict", "max_per_contract", "_violations", "_checks",
                 "_stored", "_dropped", "_runs", "_run", "_n_stage",
                 "_n_route", "_n_comp_cons", "_n_lifecycle", "_site",
                 "_last_start", "_fsite", "_frep", "_flast", "_fst",
                 "_fsched", "_fkv",
                 "_routed", "_rlog", "_rdrained", "_route_rids",
                 "_epoch_sites", "_last_route_t", "_scale_prev")

    def __init__(self, strict: bool = False, max_per_contract: int = 8):
        self.strict = strict
        self.max_per_contract = max_per_contract
        self._violations: List[AuditViolation] = []
        self._checks: Dict[str, int] = {}     # cold-path contract counts
        self._stored: Dict[tuple, int] = {}   # (run, contract) -> stored
        self._dropped = 0
        self._runs = 0
        self._run = ""
        # hot-loop check counts are *derived*, not incremented per
        # event: stage-event tallies come from the committed trace
        # length at rollup (_audit_trace), completion tallies live in
        # the per-site state lists and route counts in the drained
        # route log, so the report folds them lazily and the hooks
        # touch no counter at all — per-event bookkeeping would
        # otherwise dominate the auditor's cost (the <= 3% perf_sweep
        # pin). The ``_n_*`` attributes hold accumulated/folded totals.
        self._n_stage = 0
        self._n_route = 0
        self._n_comp_cons = 0
        self._n_lifecycle = 0
        # run-scoped containers are created once and cleared per run
        # boundary (reset is on the per-scenario path of a sweep)
        self._site: Dict[int, list] = {}
        self._last_start: Dict[tuple, float] = {}
        self._routed: Dict[int, int] = {}
        self._rlog: list = []
        self._route_rids: set = set()
        self._epoch_sites: set = set()
        self._scale_prev: Dict[int, tuple] = {}
        self._reset_run_state()

    # single-entry (site, replica) cache for the monotonic floor and
    # site state: single-site/single-replica runs (the perf grid the
    # overhead pin times) hit it on every stage, skipping the dict +
    # tuple-key machinery; fleet runs fall back through
    # _switch_replica on each alternation. The cache key is the
    # *scheduler identity* — each replica owns its Scheduler instance,
    # so one `is` test replaces two equality compares in the hottest
    # hook (epoch boundaries, which reuse a scheduler with a reset
    # clock, invalidate the cache in on_epoch_eval)
    def _switch_replica(self, t_s, site, replica, scheduler):
        if self._frep >= 0:
            self._last_start[(self._fsite, self._frep)] = self._flast
        st = self._site.get(site)
        if st is None:
            # budget/cap are per-site scheduler config (replicas of a
            # site share one SchedulerConfig), captured at first sight
            cfg = scheduler.cfg
            st = self._site[site] = [1, 0, cfg.kv_budget_tokens,
                                     cfg.batch_cap, 0, 0, [], 0]
        else:
            if st[2] is None:             # created by on_complete
                cfg = scheduler.cfg
                st[2] = cfg.kv_budget_tokens
                st[3] = cfg.batch_cap
            st[0] = 1                     # witnessed live (see
        last = self._last_start.get((site, replica))  # _audit_trace)
        self._fsite = site
        self._frep = replica
        self._fst = st
        self._fsched = scheduler
        self._fkv = st[2]
        if last is not None and t_s < last:
            self._violate("clock-monotonic", site, -1, t_s,
                          expected=f"start >= {last:.6g}",
                          actual=f"{t_s:.6g}",
                          detail=f"replica {replica} clock went backwards")
            self._flast = last
        else:
            self._flast = t_s
        return st

    # ---- report access ----

    def report(self) -> AuditReport:
        checks = dict(self._checks)
        ns, nr, ncc, nlc = self._folded_counts()

        def fold(contract: str, n: int) -> None:
            if n:
                checks[contract] = checks.get(contract, 0) + n

        # streamed checks only: the vectorized trace checks (row order,
        # durations, batch-cap, token-conservation) count themselves in
        # _checks at rollup time
        fold("clock-monotonic", ns + nr)
        fold("kv-budget", ns)
        fold("request-conservation", nr + ncc)
        fold("request-lifecycle", nlc)
        return AuditReport(violations=list(self._violations),
                           checks=checks, runs=self._runs,
                           dropped=self._dropped)

    # ---- internals ----

    def _live_routed(self) -> int:
        """Admissions observed in the live run (drains the route log)."""
        if self._rdrained < len(self._rlog):
            self._drain_routes()
        return sum(self._routed.values())

    def _folded_counts(self):
        """Check totals = accumulated/folded runs + the live run.

        Stage-event checks accumulate into ``_n_stage`` at rollup
        (trace length of live-witnessed sites), completions live in
        ``st[1]`` (requests) / ``len(st[6])`` (batches), admissions in
        the route cache + ``_routed`` — summing them here keeps the
        hot hooks free of counter writes.
        """
        ns = self._n_stage
        live = self._live_routed()
        nr = self._n_route + live
        ncc = self._n_comp_cons
        nlc = self._n_lifecycle
        for site, s in self._site.items():
            if s[7] < len(s[6]):    # completions not yet drained by a
                self._drain_completions(site, s)     # rollup: do now
            nlc += s[1]
            if live:      # conservation arms once admissions observed
                ncc += len(s[6])
        return ns, nr, ncc, nlc

    def _reset_run_state(self) -> None:
        if self._site or self._routed or self._rlog:
            # fold the finished run's derived counts into the bases
            (self._n_stage, self._n_route, self._n_comp_cons,
             self._n_lifecycle) = self._folded_counts()
        # site -> [witnessed, completed, kv_budget, cap, done_ptok,
        #          done_dtok, [(t, ptok, dtok) | (t, done), ...],
        #          drained-upto index]
        self._site.clear()
        self._last_start.clear()      # (site, rep) -> t
        self._fsite = -1              # cached floor entry (see
        self._frep = -1               # _switch_replica)
        self._flast = -math.inf
        self._fst: Optional[list] = None
        self._fsched = None
        self._fkv = -1
        self._routed.clear()          # site -> admitted (drained)
        self._rlog.clear()            # raw (t, rid, site) route events
        self._rdrained = 0            # log index processed so far
        self._route_rids.clear()
        self._epoch_sites.clear()
        self._last_route_t = -math.inf
        self._scale_prev.clear()      # site -> (t, act, warm)

    def _violate(self, contract: str, site: int, stage: int, t_s: float,
                 expected: str, actual: str, detail: str = "") -> None:
        key = (self._run, contract)
        stored = self._stored.get(key, 0)
        v = AuditViolation(contract=contract, run=self._run, site=site,
                           stage=stage, t_s=t_s, expected=expected,
                           actual=actual, detail=detail)
        if stored < self.max_per_contract:
            self._stored[key] = stored + 1
            self._violations.append(v)
        else:
            self._dropped += 1
        if self.strict:
            raise AuditError(v)

    def _count(self, contract: str, n: int = 1) -> None:
        self._checks[contract] = self._checks.get(contract, 0) + n

    # ---- run boundary ----

    def on_run_begin(self, tag):
        self._runs += 1
        # reset (which drains any unprocessed completions) BEFORE the
        # tag flips, so late violations carry the run they belong to
        if (self._site or self._routed or self._rlog
                or self._route_rids or self._last_start
                or self._scale_prev or self._epoch_sites):
            self._reset_run_state()
        self._run = str(tag)

    # ---- hot-loop hooks ----

    def on_stage(self, t_s, dur_s, site, replica, scheduler, n_prefill,
                 n_decode, batch_size):
        # hottest hook (every batch iteration): only the checks that
        # NEED live scheduler state run here — the monotonic floor (it
        # resets at epoch boundaries the trace can't show) and the KV
        # occupancy bound (kv_tokens is not a trace column). One fused
        # guard covers cache identity, the floor and the KV bound; the
        # clean path is a single conditional, no counter writes, no
        # allocation (stage-event check totals derive from the trace
        # length at rollup). Durations, batch caps and token staging
        # are audited vectorized from the committed trace at rollup
        # (_audit_trace); cache misses, violations and the decode-
        # grown KV allowance all take _stage_slow.
        if (scheduler is self._fsched and t_s >= self._flast
                and 0 <= scheduler.kv_tokens <= self._fkv):
            self._flast = t_s
            return
        self._stage_slow(t_s, site, replica, scheduler)

    def _stage_slow(self, t_s, site, replica, scheduler):
        if scheduler is self._fsched:
            if t_s >= self._flast:
                self._flast = t_s
            else:
                self._violate(
                    "clock-monotonic", site, -1, t_s,
                    expected=f"start >= {self._flast:.6g}",
                    actual=f"{t_s:.6g}",
                    detail=f"replica {replica} clock went backwards")
            st = self._fst
        else:
            st = self._switch_replica(t_s, site, replica, scheduler)
        kv = scheduler.kv_tokens
        if not 0 <= kv <= st[2]:
            # the budget gates *admission* (prompt tokens); decode
            # steps then grow the cache one token per running request,
            # so occupancy may legally exceed the budget by exactly
            # the decode growth of the running set — the scheduler's
            # true invariant is kv - sum(decoded) <= budget (only
            # computed once the O(1) bound has failed)
            grown = sum(r.decoded for r in scheduler.running)
            if not 0 <= kv <= st[2] + grown:
                self._violate(
                    "kv-budget", site, -1, t_s,
                    expected=f"0 <= kv_tokens <= {st[2]} + "
                             f"{grown} decode-grown",
                    actual=str(kv))

    def on_complete(self, t_s, site, replica, done):
        # per-event work is one append: the scheduler builds a fresh
        # `done` list every iteration and completed requests are
        # immutable, so holding the reference is sound — conservation,
        # lifecycle and token totals are processed in one cache-warm
        # pass per site at rollup/report/reset (_drain_completions)
        if site == self._fsite:     # completion follows its stage: the
            st = self._fst          # floor cache's site-state applies
        else:
            st = self._site.get(site)
            if st is None:
                # budget/cap unknown until the first stage reports its
                # scheduler — _switch_replica fills the None slots then
                st = self._site[site] = [0, 0, None, None, 0, 0, [], 0]
        st[6].append((t_s, done))

    def _drain_completions(self, site, st):
        """Deferred completion checks: lifecycle + conservation.

        Converts the ``(t, done)`` entries recorded by ``on_complete``
        in place to ``(t, ptok, dtok)`` and folds the per-site
        completed/token totals. The conservation compare uses the
        admission counts as of drain time — exact, because every
        admission precedes the rollup/report/reset that triggers the
        drain; a request completing before its own route event would
        still leave the cumulative count above the final admitted
        total. In strict mode the raise surfaces at drain time (the
        violation still carries the event's sim-time).
        """
        comps = st[6]
        i = st[7]
        n = len(comps)
        if self._rdrained < len(self._rlog):
            self._drain_routes()      # admission counts must be final
        # admitted = completed + in-flight at every event: the
        # in-flight term is non-negative iff completions never
        # outnumber admissions (day-mode windows route without the
        # probe, so the check arms only once routes are observed)
        admitted = (self._routed.get(site, 0) if self._routed
                    else -1)                # -1: no admissions observed
        comp = st[1]
        ptot, dtot = st[4], st[5]
        while i < n:
            t_s, done = comps[i]
            comp += len(done)
            if 0 <= admitted < comp:
                self._violate(
                    "request-conservation", site, -1, t_s,
                    expected=f"completed <= {admitted} admitted",
                    actual=f"{comp} completed")
            ptok = dtok = 0
            for r in done:
                ptok += r.prefill_tokens
                dtok += r.decode_tokens
                # first_token vs ready is deliberately NOT checked:
                # replica clocks are decoupled from the router clock,
                # so a lagging replica legally serves a request at
                # local times before its global ready instant (a
                # documented discretization of the event loop, not a
                # conservation breach). ready < arrival is expressed
                # on release_s directly (ready_s is a property; the
                # attribute read is cheaper per request)
                if (0.0 <= r.release_s < r.arrival_s
                        or not 0.0 <= r.t_first_token <= r.t_done
                        or r.decoded != r.decode_tokens
                        or r.prefill_done != r.prefill_tokens):
                    self._violate(
                        "request-lifecycle", site, -1, t_s,
                        expected="arrival <= ready, 0 <= first_token "
                                 "<= done, full token counts served",
                        actual=f"rid {r.rid}: "
                               f"arrival={r.arrival_s:.6g}, "
                               f"ready={r.ready_s:.6g}, "
                               f"first={r.t_first_token:.6g}, "
                               f"done={r.t_done:.6g}, "
                               f"decoded {r.decoded}/{r.decode_tokens}, "
                               f"prefilled {r.prefill_done}/"
                               f"{r.prefill_tokens}")
            ptot += ptok
            dtot += dtok
            comps[i] = (t_s, ptok, dtok)
            i += 1
        st[1] = comp
        st[4] = ptot
        st[5] = dtot
        st[7] = n

    def on_route(self, t_s, rid, site):
        # one append; the admission counts, duplicate-rid and ready-
        # order checks all run in one cache-warm pass at drain time
        # (_drain_routes) — before any consumer of admission state
        self._rlog.append((t_s, rid, site))

    def _drain_routes(self) -> None:
        """Deferred route checks: per-site counts, dup rids, order."""
        rlog = self._rlog
        i = self._rdrained
        n = len(rlog)
        routed = self._routed
        rids = self._route_rids
        prev = self._last_route_t
        while i < n:
            t_s, rid, site = rlog[i]
            routed[site] = routed.get(site, 0) + 1
            if rid in rids:
                self._violate("request-conservation", site, -1, t_s,
                              expected=f"rid {rid} routed once",
                              actual="routed again")
            else:
                rids.add(rid)
            if t_s < prev:
                self._violate(
                    "clock-monotonic", site, -1, t_s,
                    expected=f"route time >= {prev:.6g}",
                    actual=f"{t_s:.6g}",
                    detail="requests must route in ready order")
            else:
                prev = t_s
            i += 1
        self._last_route_t = prev
        self._rdrained = n

    def on_scale(self, t_s, site, n_active, n_warm, kind):
        self._count("autoscale-legality")
        prev = self._scale_prev.get(site)
        bad = None
        if kind not in _SCALE_KINDS:
            bad = f"kind={kind!r}"
        elif n_active < 1 or n_warm < 0:
            bad = f"n_active={n_active}, n_warm={n_warm}"
        elif prev is not None:
            pt, pact, pwarm = prev
            if t_s < pt:
                bad = f"t={t_s:.6g} < previous {pt:.6g}"
            elif kind.startswith("up") and n_active != pact + 1:
                bad = f"{kind}: n_active {pact} -> {n_active}"
            elif kind == "down" and n_active != pact - 1:
                bad = f"down: n_active {pact} -> {n_active}"
            elif kind == "up_warm" and n_warm != pwarm - 1:
                bad = f"up_warm: n_warm {pwarm} -> {n_warm}"
        if bad is not None:
            self._violate("autoscale-legality", site, -1, t_s,
                          expected="legal transition "
                                   f"({'|'.join(_SCALE_KINDS)}, "
                                   "active step of one, warm >= 0)",
                          actual=bad)
        self._scale_prev[site] = (t_s, n_active, n_warm)

    # ---- finalize hooks ----

    def on_requests(self, arrival_s, ready_s, site=-1):
        # drivers pass ndarrays (simulator.py builds them); the
        # asarray fallback covers synthetic/test callers only
        arrival = (arrival_s if type(arrival_s) is np.ndarray
                   else np.asarray(arrival_s, np.float64))
        ready = (ready_s if type(ready_s) is np.ndarray
                 else np.asarray(ready_s, np.float64))
        self._count("admission-legality")
        if len(arrival) != len(ready):
            self._violate("admission-legality", site, -1, -1.0,
                          expected="matched arrival/ready arrays",
                          actual=f"{len(arrival)} vs {len(ready)}")
        elif len(ready):
            queue_delay = ready - arrival
            if float(queue_delay.min()) < 0.0:
                i = int(np.argmin(queue_delay))
                self._violate("admission-legality", site, -1,
                              float(ready[i]),
                              expected=f"ready >= arrival "
                                       f"({arrival[i]:.6g})",
                              actual=f"{ready[i]:.6g}",
                              detail=f"request index {i}")
        if self._rlog and site < 0:
            # fleet/single-site drivers report the full request set
            # once at finalize: conservation closes when every
            # generated request was routed exactly once
            self._count("request-conservation")
            routed = self._live_routed()
            if routed != len(arrival):
                self._violate(
                    "request-conservation", site, -1, -1.0,
                    expected=f"{len(arrival)} requests routed",
                    actual=f"{routed} routed",
                    detail="admitted != completed + parked + in-flight")

    def on_epoch_eval(self, site, ev):
        # epoch windows restart replica clocks at the epoch start while
        # an exact epoch's service may spill past it — the monotonic
        # floor resets at the boundary (within a window it still
        # holds), and the site's tiled day trace concatenates epochs
        # whose spill legally rewinds across rows, so the rollup's
        # vectorized start-order check stands down for this site too
        self._epoch_sites.add(site)
        for key in [k for k in self._last_start if k[0] == site]:
            del self._last_start[key]
        if self._fsite == site:           # drop the cached floor too
            self._fsite = -1
            self._frep = -1
            self._flast = -math.inf
            self._fsched = None

    def _audit_trace(self, site, trace, start):
        """Vectorized structural checks over the committed stage log.

        Durations, per-replica start ordering, batch caps and token
        conservation are audited here with a handful of numpy
        reductions instead of per-event Python: the trace columns
        carry the same information once the run rolls up, and keeping
        them out of ``on_stage`` is what holds the perf_sweep overhead
        pin (every per-event check costs ~0.5 µs in situ; each tiny-
        array numpy op here ~2 µs — so the clean path is reductions
        only, with array indexing deferred to the violation branches).
        Returns the float64 duration column and its sum so the energy
        closure in ``on_site_rollup`` reuses both.
        """
        n = len(start)
        dur = np.asarray(trace.dur_s, np.float64)
        self._count("clock-monotonic", n)
        st = self._site.get(site)
        if st is not None:
            if st[0]:
                # the site was witnessed live: the trace rows are the
                # stage events the streamed floor + KV checks covered,
                # so the per-event check totals derive here instead of
                # a counter write in the hot hook
                self._n_stage += n
            if st[7] < len(st[6]):
                self._drain_completions(site, st)
        # two reductions decide the clean path (the sum doubles as the
        # energy integral's idle term): with every duration positive,
        # any absurd/inf/NaN entry drags the sum past the bound or
        # poisons a compare — NaN fails both
        dursum = float(dur.sum())
        if not (float(dur.min()) > 0.0 and dursum <= 1e30):
            bad = ~((dur > 0.0) & (dur <= 1e30))
            if bad.any():
                i = int(np.argmax(bad))
                self._violate(
                    "clock-monotonic", site, i, float(start[i]),
                    expected="finite stage with dur_s > 0",
                    actual=f"dur_s={float(dur[i])!r}")
        if (n > 1 and (st is None or st[0] == 0)
                and site not in self._epoch_sites
                and float(np.diff(start).min()) < 0.0):
            # start-order is audited from the trace only when the
            # auditor did NOT witness the event stream live (device-
            # mode evaluation emits no on_stage): witnessed streams
            # are already covered per replica by the monotonic floor,
            # and their logs may legally interleave replicas or
            # stagger pipeline stages. Unwitnessed logs are single-
            # pass, so a backwards start is a real ordering breach —
            # still refined per replica before violating.
            rep = getattr(trace, "replica", None)
            rep = (np.zeros(n) if rep is None
                   else np.asarray(rep, np.float64))
            order = np.argsort(rep, kind="stable")
            s2 = start[order]
            back = (np.diff(s2) < 0.0) & (rep[order][1:]
                                          == rep[order][:-1])
            if back.any():
                j = int(np.argmax(back))
                i = int(order[j + 1])
                self._violate(
                    "clock-monotonic", site, i, float(start[i]),
                    expected=f"replica trace start >= "
                             f"{float(s2[j]):.6g}",
                    actual=f"{float(s2[j + 1]):.6g}",
                    detail="trace rows out of start order")
        cap = st[3] if st is not None else None
        bs = getattr(trace, "batch_size", None)
        if cap is not None and bs is not None:
            self._count("batch-cap", n)
            if float(bs.max()) > cap:
                bs = np.asarray(bs, np.float64)
                i = int(np.argmax(bs))
                self._violate("batch-cap", site, i, float(start[i]),
                              expected=f"batch <= {cap}",
                              actual=f"batch={int(bs[i])}")
        comps = st[6] if st is not None else None
        ptoks = getattr(trace, "n_prefill_tokens", None)
        if comps and ptoks is not None:
            self._count("token-conservation", len(comps))
            staged_p = int(ptoks.sum())
            staged_d = int(trace.n_decode_tokens.sum())
            # completions and stages both only accumulate, so the exact
            # totals close the conservation law; a same-event
            # pipeline-parallel stage logs staggered starts while its
            # completion reports at the event's opening instant, which
            # makes finer-than-totals timing legally ambiguous
            if st[4] > staged_p or st[5] > staged_d:
                cum_p = np.cumsum([c[1] for c in comps])
                cum_d = np.cumsum([c[2] for c in comps])
                j = int(np.argmax((cum_p > staged_p)
                                  | (cum_d > staged_d)))
                t = float(comps[j][0])
                stage = int(np.searchsorted(np.sort(start), t,
                                            side="right")) - 1
                self._violate(
                    "token-conservation", site, stage, t,
                    expected=f"completed tokens <= staged "
                             f"({staged_p}p/{staged_d}d)",
                    actual=f"{int(cum_p[j])}p/{int(cum_d[j])}d "
                           f"completed")
        return dur, dursum

    def on_site_rollup(self, site, name, trace, device, row_devices,
                       pue=1.0, ci=None, total_devices=None,
                       device_signal=None, t_end_s=None, energy_wh=None,
                       idle_energy_wh=None, carbon_active_g=None,
                       carbon_idle_g=None, cosim=None, load=None):
        dev = DEVICES[device] if isinstance(device, str) else device
        stage_sum_wh = 0.0
        if len(trace):
            start = getattr(trace, "start_s", None)
            if start is not None:
                dur, dursum = self._audit_trace(
                    site, trace, np.asarray(start, np.float64))
            else:
                dur = np.asarray(trace.dur_s, np.float64)
                dursum = float(dur.sum())
            mfu = np.asarray(trace.mfu, np.float64)
            self._count("mfu-range")
            lo, hi = float(mfu.min()), float(mfu.max())
            if lo < 0.0 or hi > 1.0 + POWER_RANGE_RTOL:
                self._violate("mfu-range", site,
                              int(np.argmax(mfu)), -1.0,
                              expected="0 <= MFU <= 1",
                              actual=f"[{lo:.6g}, {hi:.6g}]")
            # Eq. 1 recomputed independently in float64 numpy (the
            # driver evaluates it in float32 jax — a per-scenario jit
            # dispatch here would dominate the auditor's cost; the
            # float32-vs-float64 gap is covered by EQ23_CLOSURE_RTOL)
            sat = dev.mfu_sat
            p_span = dev.p_max_inst - dev.p_idle
            gamma = dev.gamma
            # x^gamma @ dur with x = clip(mfu)/sat; when the clip is a
            # no-op (the common case) the /sat folds out of the array
            # pass: (mfu/sat)^g == mfu^g / sat^g
            if lo >= 0.0 and hi <= sat:
                xg_dot = float(np.power(mfu, gamma) @ dur) \
                    / sat ** gamma
            else:
                x = np.minimum(np.maximum(mfu, 0.0), sat) / sat
                xg_dot = float(np.power(x, gamma) @ dur)
            self._count("power-range")
            # P(u) is monotone in u, so the recomputed extrema follow
            # from the MFU extrema — no second array min/max pass
            xlo = min(max(lo, 0.0), sat) / sat
            xhi = min(max(hi, 0.0), sat) / sat
            pmin = dev.p_idle + p_span * xlo ** dev.gamma
            pmax = dev.p_idle + p_span * xhi ** dev.gamma
            if (pmin < dev.p_idle * (1.0 - POWER_RANGE_RTOL)
                    or pmax > dev.p_max_inst * (1.0 + POWER_RANGE_RTOL)):
                self._violate(
                    "power-range", site, int(np.argmax(mfu)), -1.0,
                    expected=f"{dev.p_idle:.6g} <= P(u) <= "
                             f"{dev.p_max_inst:.6g} W ({device})",
                    actual=f"[{pmin:.6g}, {pmax:.6g}] W")
            # P @ dur distributed over P(u) = P_idle + span*x^gamma:
            # the idle term folds onto the duration sum the trace
            # audit already produced, so no power array materializes
            stage_sum_wh = (dev.p_idle * dursum + p_span * xg_dot) \
                * float(row_devices) * float(pue) / 3600.0
        if energy_wh is not None:
            self._count("eq23-closure")
            ref = float(energy_wh)
            if abs(stage_sum_wh - ref) > \
                    EQ23_CLOSURE_RTOL * max(abs(ref), 1e-9):
                self._violate(
                    "eq23-closure", site, -1, -1.0,
                    expected=f"sum(P_i*dt_i)*G*PUE/3600 == "
                             f"{ref:.12g} Wh",
                    actual=f"{stage_sum_wh:.12g} Wh",
                    detail=f"rtol {EQ23_CLOSURE_RTOL:g}")
        if cosim is not None and load is not None:
            times = np.asarray(load.times, np.float64)
            vals = np.asarray(load.values, np.float64)
            if len(times) >= 2:
                step = float(times[1] - times[0])
                self._count("eq45-closure")
                e_kwh = float(vals.sum()) * step / 3600.0 / 1000.0
                ref_e = float(cosim["total_energy_kwh"])
                if abs(e_kwh - ref_e) > \
                        EQ45_CLOSURE_RTOL * max(abs(ref_e), 1e-9):
                    self._violate(
                        "eq45-closure", site, -1, -1.0,
                        expected=f"integral(load) == {ref_e:.9g} kWh "
                                 f"(co-sim total)",
                        actual=f"{e_kwh:.9g} kWh",
                        detail=f"rtol {EQ45_CLOSURE_RTOL:g}")
                if ci is not None:
                    self._count("eq45-closure")
                    civ = (np.asarray(ci.at(times), np.float64)
                           if hasattr(ci, "at")
                           else np.full(len(times), float(ci)))
                    kg = float(np.sum(vals * civ)) * step / 3600.0 / 1e6
                    ref_c = float(cosim["total_emissions_nosolar_kg"])
                    if abs(kg - ref_c) > \
                            EQ45_CLOSURE_RTOL * max(abs(ref_c), 1e-9):
                        self._violate(
                            "eq45-closure", site, -1, -1.0,
                            expected="active + idle-bin carbon == "
                                     f"{ref_c:.9g} kg (co-sim "
                                     "no-solar total)",
                            actual=f"{kg:.9g} kg",
                            detail=f"rtol {EQ45_CLOSURE_RTOL:g}; "
                                   f"driver split: active_g="
                                   f"{carbon_active_g}, idle_g="
                                   f"{carbon_idle_g}")
