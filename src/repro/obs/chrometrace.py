"""Chrome trace-event JSON + tidy CSV export for the dual clocks.

One trace file carries both clocks as separate process tracks, viewable
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:

* **pid 1 — wall-clock**: the ``SpanProfiler``'s nested spans as
  paired ``B``/``E`` duration events (ts = µs since the profiler
  origin);
* **pid 50 — admission/routing (sim-time)**: the deferral backlog as a
  counter track plus per-request routing instants (capped — see
  ``max_instants``);
* **pid 100+site — sim-time, one process per site**: stage iterations
  as ``X`` complete events on per-replica threads, per-replica queue
  depth / running set / KV-token / batch-occupancy counters, the
  Eq. 1-5 power/CI/carbon timeline counters, autoscaler instants with
  active/warm counters, and the day driver's epoch windows on a
  dedicated thread.

Sim-time seconds map to trace µs one-to-one (1 sim second = 1e6 ts
units), so both clocks read naturally in the same UI without unit
gymnastics. Events are sorted by ``ts`` (``E`` before ``B`` on ties)
— the monotonicity + pairing contract tests/test_obs.py pins.
"""
from __future__ import annotations

import csv
import json
from pathlib import Path
from typing import Dict, List, Optional

WALL_PID = 1
ADMISSION_PID = 50
SITE_PID_BASE = 100
EPOCH_TID = 999

#: route instants beyond this count are dropped from the trace (the
#: backlog counter still covers the full stream); CSV export is uncapped
DEFAULT_MAX_INSTANTS = 5000


def _meta(pid: int, name: str, tid: Optional[int] = None) -> dict:
    if tid is None:
        return {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": name}}
    return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": name}}


def _counter(pid: int, name: str, ts: float, **values) -> dict:
    return {"ph": "C", "name": name, "pid": pid, "tid": 0, "ts": ts,
            "args": values}


def chrome_trace_events(recorder=None, profiler=None,
                        max_instants: int = DEFAULT_MAX_INSTANTS
                        ) -> List[dict]:
    """Assemble the sorted Chrome trace-event list from either clock
    (both optional)."""
    meta: List[dict] = []
    events: List[dict] = []

    if profiler is not None:
        meta.append(_meta(WALL_PID, "wall-clock (sweep pipeline)"))
        meta.append(_meta(WALL_PID, "spans", tid=1))
        spans = profiler.spans()
        # B events in (start, depth) order so equal-ts parents precede
        # children; E events in (end, -depth) order so children close
        # first — the stable ts sort below preserves both
        for name, t0, dur, depth in sorted(
                spans, key=lambda s: (s[1], s[3])):
            events.append({"ph": "B", "name": name, "pid": WALL_PID,
                           "tid": 1, "ts": t0 * 1e6})
        for name, t0, dur, depth in sorted(
                spans, key=lambda s: (s[1] + s[2], -s[3])):
            events.append({"ph": "E", "name": name, "pid": WALL_PID,
                           "tid": 1, "ts": (t0 + dur) * 1e6})

    if recorder is not None:
        stages = recorder.stage_table()
        site_ids = sorted(
            set(int(s) for s in stages["site"])
            | set(recorder.timelines)
            | set(ev["site"] for ev in recorder.epochs)
            | set(ev["site"] for ev in recorder.scales))
        for s in site_ids:
            tl = recorder.timelines.get(s)
            label = f"sim-time site {s}" + \
                (f" ({tl['name']})" if tl else "")
            meta.append(_meta(SITE_PID_BASE + s, label))
            meta.append(_meta(SITE_PID_BASE + s, "epochs", tid=EPOCH_TID))

        n = len(stages["t_s"])
        for k in range(n):
            pid = SITE_PID_BASE + int(stages["site"][k])
            rep = int(stages["replica"][k])
            ts = float(stages["t_s"][k]) * 1e6
            events.append({
                "ph": "X", "name": "stage", "pid": pid, "tid": rep,
                "ts": ts, "dur": float(stages["dur_s"][k]) * 1e6,
                "args": {"batch": int(stages["batch_size"][k]),
                         "prefill_tokens":
                             int(stages["n_prefill_tokens"][k]),
                         "decode_tokens":
                             int(stages["n_decode_tokens"][k])}})
            events.append(_counter(
                pid, f"queue r{rep}", ts,
                waiting=int(stages["queue_depth"][k]),
                running=int(stages["n_running"][k])))
            events.append(_counter(
                pid, f"batch r{rep}", ts,
                batch=int(stages["batch_size"][k])))
            events.append(_counter(
                pid, f"kv_tokens r{rep}", ts,
                kv=int(stages["kv_tokens"][k])))

        for s, tl in sorted(recorder.timelines.items()):
            pid = SITE_PID_BASE + s
            t_us = tl["t_s"] * 1e6
            for k in range(len(tl["t_s"])):
                events.append(_counter(pid, "power_w", float(t_us[k]),
                                       power_w=float(tl["power_w"][k])))
                events.append(_counter(pid, "devices", float(t_us[k]),
                                       devices=float(tl["devices"][k])))
                if "carbon_g" in tl:
                    events.append(_counter(
                        pid, "ci_g_per_kwh", float(t_us[k]),
                        ci=float(tl["ci_g_per_kwh"][k])))
                    events.append(_counter(
                        pid, "carbon_g", float(t_us[k]),
                        carbon_g=float(tl["carbon_g"][k])))

        for ev in recorder.scales:
            pid = SITE_PID_BASE + ev["site"]
            ts = ev["t_s"] * 1e6
            events.append({"ph": "i", "name": f"scale:{ev['kind']}",
                           "pid": pid, "tid": 0, "ts": ts, "s": "p"})
            events.append(_counter(pid, "replicas", ts,
                                   active=ev["n_active"],
                                   warm=ev["n_warm"]))

        for ev in recorder.epochs:
            pid = SITE_PID_BASE + ev["site"]
            events.append({
                "ph": "X",
                "name": f"epoch {ev['executed']}:{ev['reason']}",
                "pid": pid, "tid": EPOCH_TID, "ts": ev["t0_s"] * 1e6,
                "dur": (ev["t1_s"] - ev["t0_s"]) * 1e6,
                "args": {k: ev[k] for k in
                         ("index", "planned", "executed", "reason",
                          "n_replicas", "n_requests", "n_simulated",
                          "weight")}})

        bt, depth = recorder.backlog_series()
        routes = recorder.route_table()
        if len(bt) or len(routes["t_s"]):
            meta.append(_meta(ADMISSION_PID, "admission/routing "
                                             "(sim-time)"))
        for k in range(len(bt)):
            events.append(_counter(ADMISSION_PID, "deferral_backlog",
                                   float(bt[k]) * 1e6,
                                   backlog=int(depth[k])))
        if len(routes["t_s"]) <= max_instants:
            for k in range(len(routes["t_s"])):
                events.append({
                    "ph": "i", "name": "route", "pid": ADMISSION_PID,
                    "tid": 0, "ts": float(routes["t_s"][k]) * 1e6,
                    "s": "t",
                    "args": {"rid": int(routes["rid"][k]),
                             "site": int(routes["site"][k])}})

    # metadata first, then a stable ts sort with E closing before B
    # opens on ties (keeps duration nesting valid)
    events.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "E" else 1))
    return meta + events


def write_chrome_trace(path, recorder=None, profiler=None,
                       max_instants: int = DEFAULT_MAX_INSTANTS) -> dict:
    """Write ``{"traceEvents": [...]}`` to ``path``; returns counts."""
    events = chrome_trace_events(recorder, profiler,
                                 max_instants=max_instants)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"traceEvents": events,
               "displayTimeUnit": "ms",
               "otherData": {"generator": "repro.obs",
                             "sim_time_unit": "1 sim second = 1e6 ts"}}
    path.write_text(json.dumps(payload) + "\n")
    return {"path": str(path), "n_events": len(events)}


# ------------------------------------------------------------------ CSV --


def _write_csv(path: Path, header: List[str], rows) -> Path:
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def write_csvs(outdir, recorder=None, profiler=None) -> List[Path]:
    """Tidy CSV export: one file per series (stage events, routes,
    scales, epochs, backlog, per-site Eq. 1-5 timelines, wall-clock
    spans)."""
    outdir = Path(outdir)
    paths: List[Path] = []

    if recorder is not None:
        stages = recorder.stage_table()
        fields = list(stages)
        paths.append(_write_csv(
            outdir / "stages.csv", fields,
            zip(*(stages[f] for f in fields))))
        routes = recorder.route_table()
        paths.append(_write_csv(
            outdir / "routes.csv", list(routes),
            zip(*(routes[f] for f in routes))))
        if recorder.scales:
            keys = list(recorder.scales[0])
            paths.append(_write_csv(
                outdir / "scales.csv", keys,
                ([ev[k] for k in keys] for ev in recorder.scales)))
        if recorder.epochs:
            keys = list(recorder.epochs[0])
            paths.append(_write_csv(
                outdir / "epochs.csv", keys,
                ([ev[k] for k in keys] for ev in recorder.epochs)))
        bt, depth = recorder.backlog_series()
        if len(bt):
            paths.append(_write_csv(outdir / "backlog.csv",
                                    ["t_s", "backlog"],
                                    zip(bt, depth)))
        for s, tl in sorted(recorder.timelines.items()):
            cols = ["t_s", "power_w", "energy_wh", "devices",
                    "busy_dev_s"]
            if "carbon_g" in tl:
                cols += ["ci_g_per_kwh", "carbon_g"]
            paths.append(_write_csv(
                outdir / f"timeline_site{s}.csv", cols,
                zip(*(tl[c] for c in cols))))

    if profiler is not None:
        paths.append(_write_csv(
            outdir / "spans.csv", ["name", "t0_s", "dur_s", "depth"],
            profiler.spans()))
    return paths
