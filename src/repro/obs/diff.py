"""First-divergence explainer for sweep records, golden records and
flight-trace stage tables.

``repro.obs.diff`` turns an opaque "arrays differ" failure into a
localized explanation: it aligns two runs (by scenario key and stage
index), walks their columns in the paper's dependency order —
composition → roofline time → power → energy → carbon → latency
percentiles — and reports the *first* divergent (scenario, stage,
column) cell, so the earliest broken link in the Eq. 1-5 chain is
named instead of its downstream fallout. Every divergent cell is then
classified against the repo's named tolerance contracts:

* ``host-bitwise`` (rtol 0) — the contract identical cells satisfy;
* ``DEVICE_MODE_RTOL`` — batched device-grid vs host numerics
  (``repro.sweep.device``);
* ``JAX_BACKEND_RTOL`` — jax vs numpy roofline backends
  (``repro.sim.execmodel``);
* ``DAY_FLUID_RTOL`` — fluid vs exact day epochs
  (``repro.sweep.scenarios``);
* ``regression`` — outside every named contract: a real drift.

Entry points: ``diff_records`` (two sweep result sets),
``diff_golden`` (a metrics dict vs a golden record, bit-exact),
``diff_stage_tables`` (two flight-recorder stage tables),
``assert_golden`` (test helper that raises through the explainer and
writes the report artifact), and ``python -m repro.obs diff A B``.

Reports render as markdown (CI artifact) and machine-readable JSON
(``schema`` 1) under ``results/obs/divergence/``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: where CI jobs and ``assert_golden`` drop divergence reports
DIVERGENCE_DIR = Path("results") / "obs" / "divergence"

#: report JSON schema version (pinned by tests/test_diff.py)
REPORT_SCHEMA = 1

#: dependency (walk) order of the Eq. 1-5 chain
PHASES = ("composition", "roofline", "power", "energy", "carbon",
          "latency", "other")

#: phase keyword tables, *matched* in specificity order (latency
#: before carbon before energy ... ) so e.g. ``grid_ci_g_per_kwh``
#: lands in carbon, not energy
_PHASE_KEYWORDS = (
    ("latency", ("ttft", "e2e", "tpot", "p50", "p90", "p95", "p99",
                 "latency", "slo")),
    ("carbon", ("carbon", "emission", "_ci", "ci_", "solar", "grid",
                "renewable", "offset", "soc", "battery", "charging",
                "discharging")),
    ("energy", ("energy", "_wh", "_kwh", "joule")),
    ("power", ("power", "watt")),
    ("roofline", ("duration", "dur", "time", "gpu_hours", "throughput",
                  "qps", "mfu", "t_s", "busy", "idle_s", "weight")),
    ("composition", ("stage", "batch", "prefill", "decode", "token",
                     "request", "queue", "running", "replica", "site",
                     "device", "epoch", "n_", "kv")),
)


def column_phase(column: str) -> str:
    """Map a metric/column name onto its Eq. 1-5 phase."""
    low = column.lower()
    for phase, words in _PHASE_KEYWORDS:
        if any(w in low for w in words):
            return phase
    return "other"


def _phase_rank(column: str) -> Tuple[int, str]:
    return PHASES.index(column_phase(column)), column


def tolerance_contracts() -> List[Tuple[str, float]]:
    """The named tolerance ladder, tightest first. Imported lazily so
    ``repro.obs`` never drags the sweep/sim layers in at import time."""
    from repro.sim.execmodel import JAX_BACKEND_RTOL
    from repro.sweep.device import DEVICE_MODE_RTOL
    from repro.sweep.scenarios import DAY_FLUID_RTOL
    return [("host-bitwise", 0.0),
            ("DEVICE_MODE_RTOL", DEVICE_MODE_RTOL),
            ("JAX_BACKEND_RTOL", JAX_BACKEND_RTOL),
            ("DAY_FLUID_RTOL", DAY_FLUID_RTOL),
            ("regression", math.inf)]


def classify(rel: float,
             contracts: Optional[Sequence[Tuple[str, float]]] = None
             ) -> str:
    """Name the tightest tolerance contract a relative divergence
    satisfies (``host-bitwise`` for identical, ``regression`` beyond
    every named rtol)."""
    for name, rtol in contracts or tolerance_contracts():
        if rel <= rtol:
            return name
    return "regression"


def _rel(a, b) -> float:
    """Relative divergence: 0.0 identical, inf incomparable."""
    if isinstance(a, bool) or isinstance(b, bool) \
            or not isinstance(a, (int, float)) \
            or not isinstance(b, (int, float)):
        return 0.0 if a == b else math.inf
    fa, fb = float(a), float(b)
    if fa == fb:
        return 0.0
    if math.isnan(fa) and math.isnan(fb):
        return 0.0
    if not (math.isfinite(fa) and math.isfinite(fb)):
        return math.inf
    return abs(fa - fb) / max(abs(fa), abs(fb))


@dataclasses.dataclass
class DivergentCell:
    """One (scenario, stage, column) cell where the two sides differ,
    with its contract classification."""
    scenario: str
    stage: int             # stage/row index; -1 for whole-run metrics
    column: str
    a: object
    b: object
    rel: float
    contract: str
    phase: str

    def format(self) -> str:
        where = self.scenario
        if self.stage >= 0:
            where += f" stage {self.stage}"
        rel = "inf" if math.isinf(self.rel) else f"{self.rel:.3g}"
        return (f"({where}, {self.column}) [{self.phase}]: "
                f"{self.a!r} vs {self.b!r} (rel {rel}, {self.contract})")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if isinstance(self.rel, float) and math.isinf(self.rel):
            d["rel"] = "inf"
        return d


@dataclasses.dataclass
class DiffResult:
    """Outcome of one comparison. ``cells`` holds every divergent cell
    in dependency-walk order — ``first`` is the earliest broken link in
    the chain, the cell to debug."""
    kind: str                       # records | golden | stage-table
    label_a: str
    label_b: str
    n_compared: int                 # cells compared
    n_scenarios: int                # aligned scenarios / tables
    cells: List[DivergentCell]
    only_a: List[str] = dataclasses.field(default_factory=list)
    only_b: List[str] = dataclasses.field(default_factory=list)

    @property
    def identical(self) -> bool:
        return not self.cells and not self.only_a and not self.only_b

    @property
    def first(self) -> Optional[DivergentCell]:
        return self.cells[0] if self.cells else None

    @property
    def worst_contract(self) -> str:
        order = [name for name, _ in tolerance_contracts()]
        worst = "host-bitwise"
        for c in self.cells:
            if order.index(c.contract) > order.index(worst):
                worst = c.contract
        return worst

    @property
    def has_regression(self) -> bool:
        return any(c.contract == "regression" for c in self.cells) \
            or bool(self.only_a or self.only_b)

    def by_contract(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.cells:
            out[c.contract] = out.get(c.contract, 0) + 1
        return out

    def summary(self) -> str:
        if self.identical:
            return (f"identical — {self.n_compared} cell(s) across "
                    f"{self.n_scenarios} scenario(s) (host-bitwise)")
        parts = [f"{n} {name}" for name, n in
                 sorted(self.by_contract().items())]
        extra = ""
        if self.only_a or self.only_b:
            extra = (f"; unmatched: {len(self.only_a)} only in A, "
                     f"{len(self.only_b)} only in B")
        return (f"{len(self.cells)}/{self.n_compared} cell(s) diverge "
                f"({', '.join(parts)}){extra}; first: "
                f"{self.first.format() if self.first else 'n/a'}")

    def to_dict(self) -> dict:
        return {
            "schema": REPORT_SCHEMA,
            "kind": self.kind,
            "a": self.label_a,
            "b": self.label_b,
            "identical": self.identical,
            "has_regression": self.has_regression,
            "worst_contract": self.worst_contract,
            "n_compared": self.n_compared,
            "n_scenarios": self.n_scenarios,
            "by_contract": self.by_contract(),
            "first": self.first.to_dict() if self.first else None,
            "cells": [c.to_dict() for c in self.cells],
            "only_a": list(self.only_a),
            "only_b": list(self.only_b),
        }

    def to_markdown(self) -> str:
        lines = [f"# Divergence report ({self.kind})", "",
                 f"- A: `{self.label_a}`",
                 f"- B: `{self.label_b}`",
                 f"- result: {self.summary()}", ""]
        if self.first is not None:
            lines += ["## First divergence (dependency order: "
                      + " → ".join(PHASES[:-1]) + ")", "",
                      f"`{self.first.format()}`", ""]
        if self.cells:
            lines += ["## Divergent cells", "",
                      "| scenario | stage | column | phase | A | B | "
                      "rel | contract |",
                      "|---|---:|---|---|---|---|---|---|"]
            for c in self.cells:
                rel = "inf" if math.isinf(c.rel) else f"{c.rel:.3g}"
                lines.append(
                    f"| {c.scenario} | {c.stage} | {c.column} | "
                    f"{c.phase} | {c.a} | {c.b} | {rel} | "
                    f"{c.contract} |")
            lines.append("")
        if self.only_a:
            lines += ["## Only in A", ""] + \
                [f"- {k}" for k in self.only_a] + [""]
        if self.only_b:
            lines += ["## Only in B", ""] + \
                [f"- {k}" for k in self.only_b] + [""]
        lines += ["## Tolerance ladder", "",
                  "| contract | rtol |", "|---|---|"]
        for name, rtol in tolerance_contracts():
            lines.append(f"| {name} | {rtol:g} |")
        return "\n".join(lines) + "\n"


# ----------------------------------------------------------- engines --


def _diff_metrics(scenario: str, ma: dict, mb: dict,
                  cells: List[DivergentCell],
                  contracts: Sequence[Tuple[str, float]]) -> int:
    """Walk one scenario's metric columns in dependency order; append
    divergent cells; return cells compared."""
    cols = sorted(set(ma) | set(mb), key=_phase_rank)
    for col in cols:
        a = ma.get(col)
        b = mb.get(col)
        rel = _rel(a, b) if col in ma and col in mb else math.inf
        if rel > 0.0:
            cells.append(DivergentCell(
                scenario=scenario, stage=-1, column=col, a=a, b=b,
                rel=rel, contract=classify(rel, contracts),
                phase=column_phase(col)))
    return len(cols)


def diff_records(recs_a: Sequence[dict], recs_b: Sequence[dict],
                 label_a: str = "A", label_b: str = "B") -> DiffResult:
    """Compare two sweep result sets, aligned by scenario ``key``
    (mode-independent, so event-loop and device runs of one grid
    align); falls back to positional alignment when the key spaces are
    disjoint (e.g. hand-built fixtures)."""
    contracts = tolerance_contracts()
    by_key_b = {r.get("key"): r for r in recs_b}
    common = [r for r in recs_a if r.get("key") in by_key_b]
    if not common and recs_a and recs_b:
        pairs = list(zip(recs_a, recs_b))
        only_a = [r.get("scenario", "?") for r in recs_a[len(pairs):]]
        only_b = [r.get("scenario", "?") for r in recs_b[len(pairs):]]
    else:
        pairs = [(r, by_key_b[r.get("key")]) for r in common]
        keys_a = {r.get("key") for r in recs_a}
        only_a = [r.get("scenario", "?") for r in recs_a
                  if r.get("key") not in by_key_b]
        only_b = [r.get("scenario", "?") for r in recs_b
                  if r.get("key") not in keys_a]
    cells: List[DivergentCell] = []
    n = 0
    for ra, rb in pairs:
        n += _diff_metrics(ra.get("scenario", "?"),
                           ra.get("metrics", {}), rb.get("metrics", {}),
                           cells, contracts)
    return DiffResult(kind="records", label_a=label_a, label_b=label_b,
                      n_compared=n, n_scenarios=len(pairs), cells=cells,
                      only_a=only_a, only_b=only_b)


def diff_golden(metrics: dict, golden: dict, scenario: str = "golden",
                label_a: str = "run", label_b: str = "golden"
                ) -> DiffResult:
    """Compare one metrics dict against a golden record. Golden pins
    are bit-exact (``host-bitwise``), so *any* divergent cell fails the
    guard — the classification then says which execution-path contract
    would have absorbed the drift (a ``DEVICE_MODE_RTOL`` cell points
    at numerics, a ``regression`` cell at semantics)."""
    contracts = tolerance_contracts()
    cells: List[DivergentCell] = []
    # goldens pin a deliberate subset of the metric columns — walk the
    # golden's keys only; a pinned key missing from the run is an
    # incomparable (inf) divergence, extra run columns are not drift
    pinned = {k: metrics[k] for k in golden if k in metrics}
    n = _diff_metrics(scenario, pinned, dict(golden), cells, contracts)
    return DiffResult(kind="golden", label_a=label_a, label_b=label_b,
                      n_compared=n, n_scenarios=1, cells=cells)


def diff_stage_tables(ta: Dict[str, np.ndarray],
                      tb: Dict[str, np.ndarray],
                      scenario: str = "trace",
                      label_a: str = "A", label_b: str = "B"
                      ) -> DiffResult:
    """Compare two flight-recorder stage tables (or any dict of
    equal-length columns). Rows align positionally; for each column —
    dependency order again — the *first* divergent row is reported, so
    the earliest (stage, column) breakage surfaces once instead of
    cascading down the trace."""
    contracts = tolerance_contracts()
    cells: List[DivergentCell] = []
    only_a = sorted(set(ta) - set(tb))
    only_b = sorted(set(tb) - set(ta))
    shared = sorted(set(ta) & set(tb), key=_phase_rank)
    n = 0
    rows_a = rows_b = 0
    for col in shared:
        ca = np.asarray(ta[col], np.float64)
        cb = np.asarray(tb[col], np.float64)
        rows_a, rows_b = len(ca), len(cb)
        m = min(rows_a, rows_b)
        n += m
        if m == 0:
            continue
        a, b = ca[:m], cb[:m]
        with np.errstate(invalid="ignore"):
            neq = ~((a == b) | (np.isnan(a) & np.isnan(b)))
        if not neq.any():
            continue
        i = int(np.argmax(neq))
        rel = _rel(float(a[i]), float(b[i]))
        cells.append(DivergentCell(
            scenario=scenario, stage=i, column=col,
            a=float(a[i]), b=float(b[i]), rel=rel,
            contract=classify(rel, contracts),
            phase=column_phase(col)))
    if rows_a != rows_b:
        only = only_a if rows_a > rows_b else only_b
        only.append(f"rows[{min(rows_a, rows_b)}:"
                    f"{max(rows_a, rows_b)}]")
    # dependency order *within* the run: earliest phase wins, ties
    # broken by the earlier stage row
    cells.sort(key=lambda c: (PHASES.index(c.phase), c.stage, c.column))
    return DiffResult(kind="stage-table", label_a=label_a,
                      label_b=label_b, n_compared=n, n_scenarios=1,
                      cells=cells, only_a=only_a, only_b=only_b)


# ----------------------------------------------------------- reports --


def write_report(result: DiffResult, name: str,
                 outdir: Optional[Path] = None) -> Dict[str, Path]:
    """Write ``<outdir>/<name>.md`` + ``.json`` (default
    ``results/obs/divergence/``) — the CI artifact pair."""
    outdir = Path(outdir) if outdir is not None else DIVERGENCE_DIR
    outdir.mkdir(parents=True, exist_ok=True)
    md = outdir / f"{name}.md"
    js = outdir / f"{name}.json"
    md.write_text(result.to_markdown())
    js.write_text(json.dumps(result.to_dict(), indent=1, default=str))
    return {"md": md, "json": js}


def assert_golden(metrics: dict, golden: dict, name: str,
                  outdir: Optional[Path] = None) -> DiffResult:
    """Golden-drift guard: bit-exact comparison that fails *through*
    the explainer. On any divergence it writes the markdown/JSON
    report (CI uploads it as an artifact) and raises an
    ``AssertionError`` naming the first divergent cell and the report
    path — instead of a bare numpy mismatch."""
    result = diff_golden(metrics, golden, scenario=name)
    if result.identical:
        return result
    paths = write_report(result, name, outdir=outdir)
    raise AssertionError(
        f"golden drift in {name}: {result.summary()}\n"
        f"divergence report: {paths['md']}")
