"""Structured stderr logging for the repro CLIs.

Library code logs through ``get_logger(...)`` (children of the
``repro`` logger) and stays silent unless a CLI entry point calls
``configure()`` — matching the historical behavior where progress
output only existed when a caller passed a ``progress=`` callback.
Diagnostics go to **stderr** so the machine-readable stdout lines the
CI jobs grep (sweep summary counts, JSON results) stay clean.

Level colors follow the ``NO_COLOR`` convention
(https://no-color.org): ANSI escapes are emitted only when the target
stream is a tty AND ``NO_COLOR`` is unset — piped/redirected output
and CI logs stay plain.

Verbosity mapping (the CLIs' ``-v`` / ``--quiet`` flags):
``-1`` -> WARNING, ``0`` -> INFO (default), ``>= 1`` -> DEBUG.
"""
from __future__ import annotations

import logging
import os
import sys

_ROOT_NAME = "repro"
_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"

_RESET = "\x1b[0m"
_LEVEL_COLORS = {
    logging.DEBUG: "\x1b[2m",       # dim
    logging.WARNING: "\x1b[33m",    # yellow
    logging.ERROR: "\x1b[31m",      # red
    logging.CRITICAL: "\x1b[1;31m",  # bold red
}


def _use_color(stream) -> bool:
    if os.environ.get("NO_COLOR") is not None:
        return False
    isatty = getattr(stream, "isatty", None)
    return bool(isatty and isatty())


class _ColorFormatter(logging.Formatter):
    """Wraps the formatted line in the record level's ANSI color
    (INFO stays uncolored — it is the default chatter)."""

    def format(self, record: logging.LogRecord) -> str:
        line = super().format(record)
        color = _LEVEL_COLORS.get(record.levelno)
        return f"{color}{line}{_RESET}" if color else line


def get_logger(name: str = _ROOT_NAME) -> logging.Logger:
    """A logger under the ``repro`` hierarchy (silent until a CLI
    calls ``configure()``)."""
    if name != _ROOT_NAME and not name.startswith(_ROOT_NAME + "."):
        name = f"{_ROOT_NAME}.{name}"
    return logging.getLogger(name)


def configure(verbosity: int = 0, stream=None) -> logging.Logger:
    """Install (or replace) the stderr handler on the ``repro`` root
    logger. Idempotent: repeated calls reconfigure rather than stack
    handlers."""
    root = logging.getLogger(_ROOT_NAME)
    for h in list(root.handlers):
        root.removeHandler(h)
    target = stream if stream is not None else sys.stderr
    handler = logging.StreamHandler(target)
    fmt_cls = _ColorFormatter if _use_color(target) else logging.Formatter
    handler.setFormatter(fmt_cls(_FORMAT, datefmt="%H:%M:%S"))
    root.addHandler(handler)
    if verbosity < 0:
        root.setLevel(logging.WARNING)
    elif verbosity == 0:
        root.setLevel(logging.INFO)
    else:
        root.setLevel(logging.DEBUG)
    root.propagate = False
    return root
