"""Structured stderr logging for the repro CLIs.

Library code logs through ``get_logger(...)`` (children of the
``repro`` logger) and stays silent unless a CLI entry point calls
``configure()`` — matching the historical behavior where progress
output only existed when a caller passed a ``progress=`` callback.
Diagnostics go to **stderr** so the machine-readable stdout lines the
CI jobs grep (sweep summary counts, JSON results) stay clean.

Verbosity mapping (the CLIs' ``-v`` / ``--quiet`` flags):
``-1`` -> WARNING, ``0`` -> INFO (default), ``>= 1`` -> DEBUG.
"""
from __future__ import annotations

import logging
import sys

_ROOT_NAME = "repro"
_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"


def get_logger(name: str = _ROOT_NAME) -> logging.Logger:
    """A logger under the ``repro`` hierarchy (silent until a CLI
    calls ``configure()``)."""
    if name != _ROOT_NAME and not name.startswith(_ROOT_NAME + "."):
        name = f"{_ROOT_NAME}.{name}"
    return logging.getLogger(name)


def configure(verbosity: int = 0, stream=None) -> logging.Logger:
    """Install (or replace) the stderr handler on the ``repro`` root
    logger. Idempotent: repeated calls reconfigure rather than stack
    handlers."""
    root = logging.getLogger(_ROOT_NAME)
    for h in list(root.handlers):
        root.removeHandler(h)
    handler = logging.StreamHandler(stream if stream is not None
                                    else sys.stderr)
    handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
    root.addHandler(handler)
    if verbosity < 0:
        root.setLevel(logging.WARNING)
    elif verbosity == 0:
        root.setLevel(logging.INFO)
    else:
        root.setLevel(logging.DEBUG)
    root.propagate = False
    return root
