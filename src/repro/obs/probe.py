"""The sim-time probe protocol.

A ``Probe`` is an *observer* of the simulation: the event loop
(``repro.fleet.simulation.drive``) and the drivers built on it call
its hooks at well-defined points, and the probe only ever reads the
state it is handed — it must never mutate schedulers, clocks or
requests. Probe-off runs (``probe=None``, the default everywhere) skip
every hook behind a single ``if probe is not None`` branch, so they
stay bitwise identical to an un-instrumented build; probe-attached
runs must produce the exact same simulation output (the neutrality
contract, pinned by tests/test_obs.py).

Hook taxonomy:

* **hot-loop hooks** fire inside the event loop (``on_stage``,
  ``on_complete``, ``on_route``, ``on_scale``) and are kept cheap: the
  loop passes the live scheduler object instead of precomputed
  aggregates, so a no-op probe costs one method call per stage;
* **finalize hooks** fire once per run/site after the loop drains
  (``on_requests``, ``on_epoch_eval``, ``on_site_rollup``) and hand
  the probe the read-only rollup inputs (stage trace, power model
  name, CI signal, driver-reported Eq. 2-5 totals) it needs to derive
  — or audit — the paper's Eq. 1-5 accounting;
* **``on_run_begin``** marks a run boundary: the sweep layer fires it
  before each executed scenario so stateful probes (the
  ``repro.obs.audit`` invariant auditor) can segment per-run state
  when one probe rides a whole sweep.

``NullProbe`` implements every hook as a no-op — attach it to measure
the pure dispatch overhead of instrumentation (what
``benchmarks/perf_sweep.py --check-obs`` bounds at <= 2%).
``MultiProbe`` fans every hook out to an ordered probe list, so a
``FlightRecorder`` and an ``AuditProbe`` can attach to one run.
"""
from __future__ import annotations

from typing import Iterable, List


class Probe:
    """Base probe: every hook is a no-op. Subclass and override what
    you need; unimplemented hooks stay free."""

    # ---- run boundary ----

    def on_run_begin(self, tag: str) -> None:
        """A new simulation run (one executed sweep scenario / trace
        group) is about to start. Stateful probes reset per-run stream
        state here; ``tag`` labels the run in their output."""

    # ---- hot-loop hooks (sim-time) ----

    def on_stage(self, t_s: float, dur_s: float, site: int, replica: int,
                 scheduler, n_prefill: int, n_decode: int,
                 batch_size: int) -> None:
        """One batch iteration committed at sim-time ``t_s`` on
        ``(site, replica)``. ``scheduler`` is the live
        ``ReplicaScheduler`` — read ``len(scheduler.waiting)`` /
        ``len(scheduler.running)`` / ``scheduler.kv_tokens`` here, do
        not hold a reference past the call."""

    def on_complete(self, t_s: float, site: int, replica: int,
                    done) -> None:
        """Requests that finished in the iteration committed at
        ``t_s`` on ``(site, replica)``. ``done`` is the live list of
        completed ``Request`` objects — read-only, same rules as the
        scheduler handle in ``on_stage``."""

    def on_route(self, t_s: float, rid: int, site: int) -> None:
        """Request ``rid`` routed to ``site`` at its ready time."""

    def on_scale(self, t_s: float, site: int, n_active: int,
                 n_warm: int, kind: str) -> None:
        """Autoscaler transition (``repro.fleet.autoscale``)."""

    # ---- finalize hooks (once per run / site) ----

    def on_requests(self, arrival_s, ready_s, site: int = -1) -> None:
        """Arrival/release arrays after admission assignment — the
        deferral backlog timeline derives from (arrival, ready)
        pairs."""

    def on_epoch_eval(self, site: int, ev) -> None:
        """One epoch's ``EpochEval`` from the day driver / hybrid."""

    def on_site_rollup(self, site: int, name: str, trace, device: str,
                       row_devices: float, pue: float = 1.0, ci=None,
                       total_devices=None, device_signal=None,
                       t_end_s=None, energy_wh=None,
                       idle_energy_wh=None, carbon_active_g=None,
                       carbon_idle_g=None, cosim=None,
                       load=None) -> None:
        """Finalize-time timeline inputs for one site: the full
        ``StageTrace``, the device key (-> ``PowerModel``), the device
        count each row's per-device power applies to
        (``row_devices``), the PUE, the CI (``Signal`` or static
        float), the total/powered device count for idle fill, and the
        horizon. See ``FlightRecorder.on_site_rollup``.

        Drivers that already computed their Eq. 2-5 totals also pass
        them through (``energy_wh`` = Eq. 2-3 active energy,
        ``idle_energy_wh``, ``carbon_active_g`` / ``carbon_idle_g`` =
        Eq. 4 attribution, ``cosim`` = microgrid co-sim metrics,
        ``load`` = the Eq. 5 load ``Signal``) so an auditing probe can
        close the accounting chain against them; all default to None
        and recorders may ignore them."""


class NullProbe(Probe):
    """Explicitly-attached no-op probe: exercises every hook dispatch
    without recording anything — the obs-overhead baseline."""


#: shared no-op instance (probes are stateless unless they record)
NULL_PROBE = NullProbe()


class MultiProbe(Probe):
    """Fan every hook out to an ordered list of probes, so e.g. a
    ``FlightRecorder`` and an ``AuditProbe`` attach to one run without
    N^2 combined-probe variants. Hooks forward in list order; the
    neutrality contract holds because each inner probe is itself an
    observer."""

    def __init__(self, probes: Iterable[Probe]):
        self.probes: List[Probe] = list(probes)
        if not self.probes:
            raise ValueError("MultiProbe needs at least one probe")

    def on_run_begin(self, tag):
        for p in self.probes:
            p.on_run_begin(tag)

    def on_stage(self, t_s, dur_s, site, replica, scheduler, n_prefill,
                 n_decode, batch_size):
        for p in self.probes:
            p.on_stage(t_s, dur_s, site, replica, scheduler, n_prefill,
                       n_decode, batch_size)

    def on_complete(self, t_s, site, replica, done):
        for p in self.probes:
            p.on_complete(t_s, site, replica, done)

    def on_route(self, t_s, rid, site):
        for p in self.probes:
            p.on_route(t_s, rid, site)

    def on_scale(self, t_s, site, n_active, n_warm, kind):
        for p in self.probes:
            p.on_scale(t_s, site, n_active, n_warm, kind)

    def on_requests(self, arrival_s, ready_s, site=-1):
        for p in self.probes:
            p.on_requests(arrival_s, ready_s, site=site)

    def on_epoch_eval(self, site, ev):
        for p in self.probes:
            p.on_epoch_eval(site, ev)

    def on_site_rollup(self, site, name, trace, device, row_devices,
                       pue=1.0, ci=None, total_devices=None,
                       device_signal=None, t_end_s=None, energy_wh=None,
                       idle_energy_wh=None, carbon_active_g=None,
                       carbon_idle_g=None, cosim=None, load=None):
        for p in self.probes:
            p.on_site_rollup(site, name, trace, device, row_devices,
                             pue=pue, ci=ci, total_devices=total_devices,
                             device_signal=device_signal, t_end_s=t_end_s,
                             energy_wh=energy_wh,
                             idle_energy_wh=idle_energy_wh,
                             carbon_active_g=carbon_active_g,
                             carbon_idle_g=carbon_idle_g, cosim=cosim,
                             load=load)


class SiteIndexProbe(Probe):
    """Re-tags the ``site`` index of every hook before forwarding to
    an inner probe. The day driver runs each site's epoch windows
    through single-site ``drive`` calls (which always report site 0);
    wrapping the recorder per site restores fleet-level indices."""

    def __init__(self, inner: Probe, site: int):
        self.inner = inner
        self.site = site

    def on_run_begin(self, tag):
        self.inner.on_run_begin(tag)

    def on_stage(self, t_s, dur_s, site, replica, scheduler, n_prefill,
                 n_decode, batch_size):
        self.inner.on_stage(t_s, dur_s, self.site, replica, scheduler,
                            n_prefill, n_decode, batch_size)

    def on_complete(self, t_s, site, replica, done):
        self.inner.on_complete(t_s, self.site, replica, done)

    def on_route(self, t_s, rid, site):
        self.inner.on_route(t_s, rid, self.site)

    def on_scale(self, t_s, site, n_active, n_warm, kind):
        self.inner.on_scale(t_s, self.site, n_active, n_warm, kind)

    def on_requests(self, arrival_s, ready_s, site=-1):
        self.inner.on_requests(arrival_s, ready_s, site=self.site)

    def on_epoch_eval(self, site, ev):
        self.inner.on_epoch_eval(self.site, ev)

    def on_site_rollup(self, site, name, trace, device, row_devices,
                       pue=1.0, ci=None, total_devices=None,
                       device_signal=None, t_end_s=None, energy_wh=None,
                       idle_energy_wh=None, carbon_active_g=None,
                       carbon_idle_g=None, cosim=None, load=None):
        self.inner.on_site_rollup(self.site, name, trace, device,
                                  row_devices, pue=pue, ci=ci,
                                  total_devices=total_devices,
                                  device_signal=device_signal,
                                  t_end_s=t_end_s, energy_wh=energy_wh,
                                  idle_energy_wh=idle_energy_wh,
                                  carbon_active_g=carbon_active_g,
                                  carbon_idle_g=carbon_idle_g,
                                  cosim=cosim, load=load)
