"""Sim-time flight recorder.

``FlightRecorder`` implements the ``Probe`` protocol and records the
simulation's internal dynamics as columnar time-series — the same
preallocated doubling-buffer idiom as ``repro.sim.trace
.StageTraceBuilder``, generalized to arbitrary field tuples
(``ColumnBuilder``):

* per-(site, replica) **stage series** — batch occupancy, queue depth,
  running set, KV-token usage at every committed iteration;
* **router decisions** (request -> site at ready time) and the
  **admission/deferral backlog** derived from (arrival, release)
  pairs;
* **autoscaler transitions** (active/warm counts per control event)
  and the day driver's **epoch evaluations** (planned/executed mode,
  pilot sizes, replica plan);
* per-site **Eq. 1-5 timelines**, computed at finalize from the full
  stage trace: per-bin power (Eq. 1 over MFU + idle fill, the Eq. 5
  binning), energy (Eq. 2-3), grid CI, and attributed carbon (Eq. 4).

The recorder never mutates what it observes: hot-loop hooks copy
scalars out of the live scheduler, finalize hooks compute on fresh
arrays. Probe-off runs are bitwise identical with or without this
module imported (tests/test_obs.py pins probe-attached == probe-off).

Timeline convention: active stage energy bins at each row's *start*
(the ``repro.fleet.day`` idiom); idle fill charges
``p_idle * (powered_devices * bin_s - busy_device_s)`` per bin, where
powered devices come from the autoscaler's device signal when one
exists, else the fixed device count. Both terms scale by PUE. With a
CI signal (or static CI) attached, per-bin carbon is
``energy_wh * ci / 1000`` (Eq. 4 operational term).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.probe import Probe

# ---------------------------------------------------------------- builder --


class ColumnBuilder:
    """Row accumulator over a preallocated (capacity, n_fields)
    float64 buffer that doubles on overflow — the ``StageTraceBuilder``
    idiom for arbitrary field tuples. ``build()`` returns a dict of
    columnar arrays, integer fields cast to int64."""

    def __init__(self, fields: Tuple[str, ...],
                 int_fields: Tuple[str, ...] = (),
                 capacity: int = 256):
        self.fields = tuple(fields)
        self._int = frozenset(int_fields)
        self._buf = np.empty((max(capacity, 16), len(self.fields)),
                             np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def append(self, *vals: float) -> None:
        if self._n == len(self._buf):
            grown = np.empty((2 * len(self._buf), len(self.fields)),
                             np.float64)
            grown[:self._n] = self._buf
            self._buf = grown
        self._buf[self._n] = vals
        self._n += 1

    def build(self) -> Dict[str, np.ndarray]:
        out = {}
        for j, name in enumerate(self.fields):
            col = self._buf[:self._n, j].copy()
            out[name] = col.astype(np.int64) if name in self._int else col
        return out


# ------------------------------------------------------------- recorder --

#: stage-series schema (one row per committed batch iteration)
STAGE_FIELDS = ("t_s", "dur_s", "site", "replica", "batch_size",
                "n_prefill_tokens", "n_decode_tokens", "queue_depth",
                "n_running", "kv_tokens")
_STAGE_INT = ("site", "replica", "batch_size", "n_prefill_tokens",
              "n_decode_tokens", "queue_depth", "n_running", "kv_tokens")

ROUTE_FIELDS = ("t_s", "rid", "site")
_ROUTE_INT = ("rid", "site")


class FlightRecorder(Probe):
    """Recording probe; see the module docstring for what it logs.

    ``resolution_s`` is the observer-owned timeline bin width — it is
    deliberately independent of the drivers' co-sim resolution, so a
    1 s diagnostic timeline never changes what the simulation
    computes."""

    def __init__(self, resolution_s: float = 60.0):
        if resolution_s <= 0:
            raise ValueError("resolution_s must be positive")
        self.resolution_s = float(resolution_s)
        self._stages = ColumnBuilder(STAGE_FIELDS, _STAGE_INT,
                                     capacity=1024)
        self._routes = ColumnBuilder(ROUTE_FIELDS, _ROUTE_INT,
                                     capacity=1024)
        # low-rate series stay plain lists
        self.scales: List[dict] = []
        self.epochs: List[dict] = []
        self._requests: List[Tuple[int, np.ndarray, np.ndarray]] = []
        #: site index -> Eq. 1-5 timeline dict (see ``on_site_rollup``)
        self.timelines: Dict[int, Dict[str, object]] = {}

    # ---- hot-loop hooks ----

    def on_stage(self, t_s, dur_s, site, replica, scheduler, n_prefill,
                 n_decode, batch_size):
        self._stages.append(t_s, dur_s, site, replica, batch_size,
                            n_prefill, n_decode,
                            len(scheduler.waiting),
                            len(scheduler.running),
                            scheduler.kv_tokens)

    def on_route(self, t_s, rid, site):
        self._routes.append(t_s, rid, site)

    def on_scale(self, t_s, site, n_active, n_warm, kind):
        self.scales.append({"t_s": float(t_s), "site": int(site),
                            "n_active": int(n_active),
                            "n_warm": int(n_warm), "kind": str(kind)})

    # ---- finalize hooks ----

    def on_requests(self, arrival_s, ready_s, site=-1):
        self._requests.append((int(site),
                               np.asarray(arrival_s, np.float64),
                               np.asarray(ready_s, np.float64)))

    def on_epoch_eval(self, site, ev):
        ep = ev.epoch
        self.epochs.append({
            "site": int(site), "index": int(ep.index),
            "t0_s": float(ep.t0), "t1_s": float(ep.t1),
            "planned": str(ep.planned), "executed": str(ev.executed),
            "reason": str(ep.reason),
            "n_replicas": int(ep.n_replicas),
            "n_requests": int(ev.n_requests),
            "n_simulated": int(ev.n_simulated),
            "weight": float(ev.weight)})

    def on_site_rollup(self, site, name, trace, device, row_devices,
                       pue=1.0, ci=None, total_devices=None,
                       device_signal=None, t_end_s=None, energy_wh=None,
                       idle_energy_wh=None, carbon_active_g=None,
                       carbon_idle_g=None, cosim=None, load=None):
        # the driver-reported Eq. 2-5 totals (energy_wh .. load) are
        # audit inputs (repro.obs.audit); the recorder derives its own
        # timelines from the trace and ignores them
        from repro.core.power import PowerModel

        pm = PowerModel(device)
        res = self.resolution_s
        t_end = float(t_end_s) if t_end_s else trace.total_duration()
        n_bins = max(1, int(math.ceil(max(t_end, res) / res)))
        times = np.arange(n_bins) * res
        act_ws = np.zeros(n_bins)
        busy_dev_s = np.zeros(n_bins)
        if len(trace):
            row_p = np.asarray(pm.power(trace.mfu), np.float64) \
                * float(row_devices)
            bin_idx = np.clip((trace.start_s / res).astype(int),
                              0, n_bins - 1)
            np.add.at(act_ws, bin_idx, row_p * trace.dur_s)
            np.add.at(busy_dev_s, bin_idx,
                      trace.dur_s * float(row_devices))
        if device_signal is not None:
            ts, counts = device_signal
            ts = np.asarray(ts, np.float64)
            counts = np.asarray(counts, np.float64)
            idx = np.clip(np.searchsorted(ts, times, side="right") - 1,
                          0, len(counts) - 1)
            devices = counts[idx]
        else:
            devices = np.full(
                n_bins, float(total_devices if total_devices is not None
                              else row_devices))
        idle_dev_s = np.maximum(devices * res - busy_dev_s, 0.0)
        power_w = (act_ws + pm.dev.p_idle * idle_dev_s) / res \
            * float(pue)                                    # Eq. 1-2 + 5
        energy_wh = power_w * res / 3600.0                  # Eq. 2-3
        timeline: Dict[str, object] = {
            "name": str(name), "device": str(device),
            "pue": float(pue), "resolution_s": res,
            "t_s": times, "power_w": power_w, "energy_wh": energy_wh,
            "devices": devices, "busy_dev_s": busy_dev_s,
        }
        if ci is not None:
            ci_vals = (np.asarray(ci.at(times), np.float64)
                       if hasattr(ci, "at")
                       else np.full(n_bins, float(ci)))
            timeline["ci_g_per_kwh"] = ci_vals
            timeline["carbon_g"] = energy_wh * ci_vals / 1000.0  # Eq. 4
        self.timelines[int(site)] = timeline

    # ---- views ----

    @property
    def n_stage_events(self) -> int:
        return len(self._stages)

    @property
    def n_route_events(self) -> int:
        return len(self._routes)

    def stage_table(self) -> Dict[str, np.ndarray]:
        return self._stages.build()

    def route_table(self) -> Dict[str, np.ndarray]:
        return self._routes.build()

    def backlog_series(self) -> Tuple[np.ndarray, np.ndarray]:
        """Admission/deferral backlog over sim-time: step series of
        requests parked between arrival and release, across every
        ``on_requests`` ingest. Empty when no request was deferred."""
        events: List[Tuple[float, int]] = []
        for _, arrival, ready in self._requests:
            held = ready > arrival + 1e-12
            for t in arrival[held]:
                events.append((float(t), 1))
            for t in ready[held]:
                events.append((float(t), -1))
        if not events:
            return np.empty(0), np.empty(0, np.int64)
        events.sort()
        times = np.asarray([t for t, _ in events])
        depth = np.cumsum([d for _, d in events]).astype(np.int64)
        return times, depth

    def counts(self) -> Dict[str, int]:
        """Event counts per series — the record CLI's summary."""
        return {"stage_events": len(self._stages),
                "route_events": len(self._routes),
                "scale_events": len(self.scales),
                "epoch_evals": len(self.epochs),
                "sites_with_timelines": len(self.timelines),
                "timeline_bins": sum(len(t["t_s"])
                                     for t in self.timelines.values())}
