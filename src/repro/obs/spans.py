"""Wall-clock span profiler for the sweep pipeline.

``SpanProfiler`` records nestable named spans (cache lookup, trace
grouping, event-loop runs, stacked passes, device jit compile vs
execute, worker fan-out) against ``time.perf_counter``. Disabled — the
default — ``span()`` returns a shared no-op context manager, so
instrumented call sites cost one attribute check when profiling is
off.

The module-level ``PROFILER`` is the process-wide instance the sweep
pipeline instruments against; enable it via ``PROFILER.enable()`` (the
CLI's ``--profile`` / ``--trace-out`` flags do). Worker processes in a
sweep's process pool each carry their own (initially disabled)
``PROFILER``; ``repro.sweep.vectorized.execute_scenario_group_profiled``
enables it per task and ships the per-phase aggregate back for
``merge()`` — merged phases contribute to ``aggregate()`` but carry no
span events of their own (cross-process clocks don't share an origin).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple


class _NullSpan:
    """Shared no-op context manager for the disabled path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_prof", "name", "t0", "depth")

    def __init__(self, prof: "SpanProfiler", name: str):
        self._prof = prof
        self.name = name

    def __enter__(self):
        self.depth = self._prof._depth
        self._prof._depth += 1
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        self._prof._depth -= 1
        self._prof._events.append((self.name, self.t0, dur, self.depth))
        return False


class SpanProfiler:
    """Nestable wall-clock spans with per-phase aggregation."""

    def __init__(self):
        self.enabled = False
        self.t_origin = time.perf_counter()
        self._depth = 0
        # (name, t0_abs, dur_s, depth) per completed span
        self._events: List[Tuple[str, float, float, int]] = []
        # phase aggregates merged from other processes
        self._merged: Dict[str, Dict[str, float]] = {}

    def enable(self, reset: bool = False) -> None:
        if reset:
            self.reset()
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        self._events.clear()
        self._merged.clear()
        self._depth = 0
        self.t_origin = time.perf_counter()

    def span(self, name: str):
        """``with PROFILER.span("phase"): ...`` — no-op when
        disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name)

    def spans(self) -> List[Tuple[str, float, float, int]]:
        """Completed spans as (name, t0_s_rel, dur_s, depth), t0
        relative to the profiler origin, chronological."""
        out = [(n, t0 - self.t_origin, d, depth)
               for n, t0, d, depth in self._events]
        out.sort(key=lambda e: (e[1], e[3]))
        return out

    def aggregate(self) -> Dict[str, Dict[str, float]]:
        """Per-phase totals: name -> {count, total_s} (own spans plus
        everything ``merge()``d in)."""
        agg: Dict[str, Dict[str, float]] = {}
        for name, _, dur, _ in self._events:
            a = agg.setdefault(name, {"count": 0, "total_s": 0.0})
            a["count"] += 1
            a["total_s"] += dur
        for name, m in self._merged.items():
            a = agg.setdefault(name, {"count": 0, "total_s": 0.0})
            a["count"] += m["count"]
            a["total_s"] += m["total_s"]
        return agg

    def merge(self, agg: Dict[str, Dict[str, float]]) -> None:
        """Fold another process's ``aggregate()`` into this one."""
        for name, m in agg.items():
            a = self._merged.setdefault(name,
                                        {"count": 0, "total_s": 0.0})
            a["count"] += int(m["count"])
            a["total_s"] += float(m["total_s"])

    def write_aggregate(self, path) -> None:
        """Persist ``aggregate()`` as JSON — the cross-process handoff
        format (remote sweep workers dump it per shard; the coordinator
        folds the files back in via ``merge_file``)."""
        import json
        from pathlib import Path
        Path(path).write_text(json.dumps(self.aggregate(), indent=1))

    def merge_file(self, path) -> None:
        """``merge()`` a JSON aggregate previously written by
        ``write_aggregate`` (possibly on another host)."""
        import json
        with open(path) as f:
            self.merge(json.load(f))

    def format_aggregate(self) -> str:
        """Human-readable per-phase table, longest total first."""
        agg = self.aggregate()
        if not agg:
            return "(no spans recorded)"
        rows = sorted(agg.items(), key=lambda kv: -kv[1]["total_s"])
        width = max(len(n) for n, _ in rows)
        return "\n".join(
            f"{n:<{width}s}  {a['total_s']:9.3f}s  x{a['count']}"
            for n, a in rows)


#: the process-wide profiler the sweep pipeline instruments against
PROFILER = SpanProfiler()
