"""Temporal carbon-aware scheduling: workload classes, CI forecasting,
and SLO-bounded admission policies operating inside the fleet event
loop (the temporal half of carbon-aware serving; ``repro.fleet.routing``
is the spatial half, and the two compose).
"""
from repro.schedule.admission import (ADMISSIONS, AdmissionPolicy,
                                      ForecastWindowAdmission,
                                      ImmediateAdmission,
                                      ThresholdDeferAdmission,
                                      apply_admission, fleet_ci_forecast,
                                      make_admission)
from repro.schedule.config import CI_STATS, ScheduleConfig
from repro.schedule.forecast import (FORECASTERS, DiurnalTemplateForecaster,
                                     Forecaster, OracleForecaster,
                                     PersistenceForecaster, make_forecaster)
from repro.schedule.metrics import class_stats

__all__ = [
    "ADMISSIONS", "AdmissionPolicy", "ForecastWindowAdmission",
    "ImmediateAdmission", "ThresholdDeferAdmission",
    "apply_admission", "fleet_ci_forecast", "make_admission",
    "CI_STATS", "ScheduleConfig",
    "FORECASTERS", "DiurnalTemplateForecaster", "Forecaster",
    "OracleForecaster", "PersistenceForecaster", "make_forecaster",
    "class_stats",
]
