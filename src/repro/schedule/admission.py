"""Request-level admission policies: the temporal half of carbon-aware
scheduling.

An admission policy sits *ahead of* site routing inside the fleet event
loop: every arriving request gets a release time >= its arrival, and
the router only sees it at release. Interactive requests are always
released immediately (their TTFT SLO is untouchable); deferrable
requests may be parked toward low-carbon windows, bounded by their
completion deadline and by a finite backlog.

Policies decide *at arrival time* using only the forecasted grid
signal (``repro.schedule.forecast``) — they are causal in the
simulation: the decision for request i depends on information
available at ``arrival_s(i)`` alone, so precomputing releases in
arrival order is equivalent to deciding inside the loop.

  - ``immediate``: release == arrival for every request (the PR-2
    event-loop semantics; the no-scheduling baseline).
  - ``threshold_defer``: park deferrable requests while forecast CI is
    above a high threshold, release at the first below-low-threshold
    window before the deadline (SPROUT-style hysteresis). Thresholds
    may be absolute or derived as percentiles of the forecast over the
    request's feasible window.
  - ``forecast_window``: greedy placement — release at the start of
    the cheapest forecast window (mean CI over the estimated service
    duration) that still meets the deadline.
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, Dict, List, Sequence, Type

import numpy as np

from repro.sim.requests import DEFERRABLE, Request

#: forecast callable handed to policies: future times -> predicted CI
ForecastFn = Callable[[np.ndarray], np.ndarray]


class AdmissionPolicy:
    """Decides when an arriving request becomes visible to routing."""

    name = "base"

    def release_time(self, req: Request, t_now_s: float,
                     forecast: ForecastFn, backlog: int) -> float:
        raise NotImplementedError


class ImmediateAdmission(AdmissionPolicy):
    name = "immediate"

    def release_time(self, req, t_now_s, forecast, backlog):
        return t_now_s


def _feasible_grid(t_now_s: float, latest_s: float,
                   step_s: float) -> np.ndarray:
    """Decision grid [t_now, latest] at step_s resolution (always
    contains t_now, so immediate release is always a candidate; never
    overshoots latest — a release past it would eat the service
    margin and blow the deadline)."""
    if latest_s <= t_now_s:
        return np.array([t_now_s])
    return np.arange(t_now_s, latest_s + 1e-9, step_s)


class ThresholdDeferAdmission(AdmissionPolicy):
    """Hysteresis deferral: park while the forecast is high, drain into
    the first low window before the deadline.

    ``ci_high``/``ci_low`` are absolute gCO2/kWh thresholds; left None
    they derive per request as the ``high_pct``/``low_pct`` percentiles
    of the forecast over the feasible window, which adapts the policy
    to any grid's level (hydro vs coal) without retuning. A full
    backlog (``max_backlog`` parked requests) forces immediate
    admission — bounded memory, no starvation pile-up.
    """

    name = "threshold_defer"

    def __init__(self, ci_high: float = None, ci_low: float = None,
                 high_pct: float = 70.0, low_pct: float = 30.0,
                 max_backlog: int = 4096, step_s: float = 300.0,
                 service_margin_s: float = 120.0):
        self.ci_high = ci_high
        self.ci_low = ci_low
        self.high_pct = high_pct
        self.low_pct = low_pct
        self.max_backlog = int(max_backlog)
        self.step_s = float(step_s)
        self.service_margin_s = float(service_margin_s)

    def release_time(self, req, t_now_s, forecast, backlog):
        if req.klass != DEFERRABLE or backlog >= self.max_backlog:
            return t_now_s
        latest = req.deadline_s - self.service_margin_s
        ts = _feasible_grid(t_now_s, latest, self.step_s)
        if len(ts) < 2:
            return t_now_s
        pred = np.asarray(forecast(ts), np.float64)
        hi = self.ci_high if self.ci_high is not None else \
            float(np.percentile(pred, self.high_pct))
        lo = self.ci_low if self.ci_low is not None else \
            float(np.percentile(pred, self.low_pct))
        if pred[0] <= hi:
            return t_now_s
        below = np.nonzero(pred <= lo)[0]
        idx = int(below[0]) if len(below) else int(np.argmin(pred))
        return float(ts[idx])


class ForecastWindowAdmission(AdmissionPolicy):
    """Greedy cheapest-window placement: release each deferrable
    request at the start of the minimum-mean-CI forecast window of
    width ``service_est_s`` that still meets its deadline. Ties (and
    windows not at least ``min_gain_frac`` cheaper than immediate)
    resolve to immediate admission."""

    name = "forecast_window"

    def __init__(self, service_est_s: float = 120.0,
                 step_s: float = 300.0, min_gain_frac: float = 0.0,
                 max_backlog: int = 4096):
        self.service_est_s = float(service_est_s)
        self.step_s = float(step_s)
        self.min_gain_frac = float(min_gain_frac)
        self.max_backlog = int(max_backlog)

    def release_time(self, req, t_now_s, forecast, backlog):
        if req.klass != DEFERRABLE or backlog >= self.max_backlog:
            return t_now_s
        latest = req.deadline_s - self.service_est_s
        ts = _feasible_grid(t_now_s, latest, self.step_s)
        if len(ts) < 2:
            return t_now_s
        # mean forecast CI over the service window starting at each ts
        w = max(1, int(math.ceil(self.service_est_s / self.step_s)))
        pad = ts[-1] + self.step_s * np.arange(1, w)
        pred = np.asarray(forecast(np.concatenate([ts, pad])), np.float64)
        win = np.convolve(pred, np.ones(w) / w, mode="valid")[:len(ts)]
        best = int(np.argmin(win))
        if win[best] >= win[0] * (1.0 - self.min_gain_frac):
            return t_now_s
        return float(ts[best])


ADMISSIONS: Dict[str, Type[AdmissionPolicy]] = {
    "immediate": ImmediateAdmission,
    "threshold_defer": ThresholdDeferAdmission,
    "forecast_window": ForecastWindowAdmission,
}


def make_admission(name: str, **params) -> AdmissionPolicy:
    if name not in ADMISSIONS:
        raise KeyError(
            f"unknown admission policy {name!r}; have {sorted(ADMISSIONS)}")
    return ADMISSIONS[name](**params)


def apply_admission(requests: Sequence[Request], policy: AdmissionPolicy,
                    forecast: Callable[[float, np.ndarray], np.ndarray]
                    ) -> Dict[str, float]:
    """Assign ``release_s`` to every request, in arrival order.

    ``forecast(t_now, ts)`` is the fleet-level CI prediction made at
    decision time ``t_now``. The parked-backlog occupancy seen by each
    decision is the number of earlier requests still awaiting release
    at that arrival (a heap of release times — O(n log n) total).
    Returns gate-side stats for the fleet report; per-request deferral
    delays are reported by ``metrics.class_stats`` (single source) from
    the release times written here.
    """
    parked: List[float] = []
    n_deferred = 0
    backlog_peak = 0
    for req in sorted(requests, key=lambda r: r.arrival_s):
        t = req.arrival_s
        while parked and parked[0] <= t:
            heapq.heappop(parked)
        rel = policy.release_time(
            req, t, lambda ts: forecast(t, np.asarray(ts)), len(parked))
        rel = min(max(rel, t), req.deadline_s)
        if rel > t:
            req.release_s = rel
            heapq.heappush(parked, rel)
            n_deferred += 1
            backlog_peak = max(backlog_peak, len(parked))
    return {
        "n_deferred": float(n_deferred),
        "backlog_peak": float(backlog_peak),
    }


def fleet_ci_forecast(forecaster, signals: Sequence,
                      stat: str = "mean"
                      ) -> Callable[[float, np.ndarray], np.ndarray]:
    """Collapse per-site CI signals into the one forecast the admission
    gate consults (``ScheduleConfig.ci_stat`` picks the combiner)."""
    combine = {"mean": np.mean, "min": np.min, "max": np.max}[stat]

    def fn(t_now_s: float, ts: np.ndarray) -> np.ndarray:
        preds = np.stack([np.asarray(forecaster.predict(sig, t_now_s, ts),
                                     np.float64) for sig in signals])
        return combine(preds, axis=0)

    return fn
