"""Temporal scheduling configuration attached to a fleet.

``ScheduleConfig`` names an admission policy (``repro.schedule.admission``)
and the carbon-intensity forecaster it consults
(``repro.schedule.forecast``), plus how the per-site CI signals are
combined into the single grid signal the admission gate sees. Plain
dataclass over primitives so it content-hashes into the sweep cache
through ``repro.sweep.grid.config_digest`` like every other config.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

#: valid per-site CI combiners for the admission gate's fleet signal
CI_STATS = ("mean", "min", "max")


@dataclasses.dataclass
class ScheduleConfig:
    """Admission gate ahead of site routing (temporal half; the spatial
    half is the ``FleetRouter``). ``immediate`` + no deferrable class
    reproduces the PR-2 event loop exactly."""
    policy: str = "immediate"         # repro.schedule.admission.ADMISSIONS
    forecaster: str = "oracle"        # repro.schedule.forecast.FORECASTERS
    policy_params: Dict[str, float] = dataclasses.field(default_factory=dict)
    forecaster_params: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # how per-site CI signals collapse into the one signal the admission
    # gate forecasts over: "mean" suits spatially-blind routers,
    # "min" suits carbon-aware routers (they will chase the clean site)
    ci_stat: str = "mean"

    def __post_init__(self):
        if self.ci_stat not in CI_STATS:
            raise ValueError(
                f"ci_stat must be one of {CI_STATS}, got {self.ci_stat!r}")
