"""Epoch-granular carbon-aware deferral for day-scale streams.

The request-level admission gate (``apply_admission``) walks a Python
heap per request — fine for thousands of requests, hopeless for a
day's millions. At day scale deferral instead operates on the
``ArrivalStream`` arrays at *epoch* granularity: deferrable arrivals
in a forecast-high-CI epoch shift their release to the start of the
cheapest feasible epoch within their deadline (one forecaster call
per source epoch, argmin over the feasible prefix — all array passes).

Releasing a batch at an epoch boundary concentrates load there by
design: that *deferral drain burst* is exactly one of the transients
the hybrid planner (``repro.sim.hybrid``) must catch, so this module
also returns per-epoch drain counts the planner folds into its
exact/fluid classification.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.workloads.stream import ArrivalStream


def epoch_deferral(stream: ArrivalStream, bounds: np.ndarray,
                   forecast: Callable, margin: float = 0.02,
                   service_margin_s: float = 120.0
                   ) -> Tuple[np.ndarray, Dict[str, float]]:
    """Shift deferrable releases toward forecast-low-CI epochs.

    Mutates ``stream.ready_s`` in place. A row moves only when the
    cheapest feasible epoch beats its own epoch's forecast CI by more
    than ``margin`` (relative); feasibility requires the target epoch
    start plus ``service_margin_s`` to precede the row's deadline.
    Returns (per-epoch drain counts, admission stats).
    """
    n_ep = len(bounds) - 1
    centers = 0.5 * (bounds[:-1] + bounds[1:])
    drain = np.zeros(n_ep)
    stats = {"n_deferred": 0.0, "deferral_mean_s": 0.0,
             "deferral_max_s": 0.0}
    if not stream.deferrable.any():
        return drain, stats

    arr = stream.arrival_s
    deadline = arr + stream.cfg.deferrable_deadline_s
    epoch_of = np.clip(np.searchsorted(bounds, arr, side="right") - 1,
                       0, n_ep - 1)
    shifts = []
    for e in np.unique(epoch_of[stream.deferrable]):
        rows = np.nonzero(stream.deferrable & (epoch_of == e))[0]
        ci = np.asarray(forecast(float(bounds[e]), centers[e:]),
                        np.float64)
        # prefix argmin: cheapest epoch among offsets [0..j]
        best_idx = np.zeros(len(ci), int)
        cur = 0
        for j in range(len(ci)):
            if ci[j] < ci[cur]:
                cur = j
            best_idx[j] = cur
        # last feasible offset per row (target start + margin <= deadline)
        last = np.searchsorted(bounds, deadline[rows] - service_margin_s,
                               side="right") - 2 - e
        last = np.clip(last, 0, len(ci) - 1)
        tgt = best_idx[last]
        move = (tgt > 0) & (ci[tgt] < ci[0] * (1.0 - margin))
        mrows, mtgt = rows[move], tgt[move]
        stream.ready_s[mrows] = bounds[e + mtgt]
        np.add.at(drain, e + mtgt, 1.0)
        shifts.append(stream.ready_s[mrows] - arr[mrows])

    if shifts:
        all_shifts = np.concatenate(shifts)
        if len(all_shifts):
            stats["n_deferred"] = float(len(all_shifts))
            stats["deferral_mean_s"] = float(all_shifts.mean())
            stats["deferral_max_s"] = float(all_shifts.max())
    return drain, stats
