"""Carbon-intensity forecasting for admission policies.

Policies never see the future of the actual grid signal — they see a
``Forecaster``'s prediction of it, so forecast error is a first-class
axis of the shifting experiments (oracle = perfect foresight upper
bound, persistence = no-skill baseline, diurnal template = the shape
prior a production scheduler would actually run on).

A forecaster maps (history-bearing signal, decision time, query times)
to predicted values; it must only read ``signal`` at times <= ``t_now``
— except the oracle, whose whole point is cheating.
"""
from __future__ import annotations

from typing import Dict, Type

import numpy as np

from repro.core.signals import Signal


class Forecaster:
    """Predict a signal's values at future times, from its past."""

    name = "base"

    def predict(self, signal: Signal, t_now_s: float,
                ts: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class OracleForecaster(Forecaster):
    """Perfect foresight: the prediction IS the trace. Upper bound on
    what any admission policy can extract from temporal shifting."""

    name = "oracle"

    def predict(self, signal, t_now_s, ts):
        return np.asarray(signal.at(np.asarray(ts, np.float64)))


class PersistenceForecaster(Forecaster):
    """No-skill baseline: CI stays at its current value forever. Under
    persistence every future instant looks equally good, so
    deferral-for-carbon degenerates to (almost) immediate admission —
    the floor any real forecaster must beat."""

    name = "persistence"

    def predict(self, signal, t_now_s, ts):
        now = float(np.asarray(signal.at(t_now_s)))
        return np.full(np.asarray(ts, np.float64).shape, now)


class DiurnalTemplateForecaster(Forecaster):
    """Shape-prior forecast: scale the current observation by a duck-
    curve template of hour-of-day (midday solar dip, evening ramp —
    the same structure as ``core.datasets.carbon_intensity_signal``).

        pred(t) = ci(t_now) * template(hod(t)) / template(hod(t_now))

    ``swing_frac`` is the template's relative amplitude; ``phase_h``
    shifts it (regions east/west of the template's reference zone).
    """

    name = "diurnal"

    def __init__(self, swing_frac: float = 0.3, phase_h: float = 0.0):
        self.swing_frac = float(swing_frac)
        self.phase_h = float(phase_h)

    def _template(self, t_s) -> np.ndarray:
        hod = (np.asarray(t_s, np.float64) / 3600.0 + self.phase_h) % 24.0
        dip = -np.exp(-0.5 * ((hod - 13.0) / 2.5) ** 2)
        peak = 0.9 * np.exp(-0.5 * ((hod - 19.5) / 1.8) ** 2)
        return np.clip(1.0 + self.swing_frac * (dip + peak), 0.2, None)

    def predict(self, signal, t_now_s, ts):
        now = float(np.asarray(signal.at(t_now_s)))
        scale = now / float(self._template(t_now_s))
        return scale * self._template(ts)


FORECASTERS: Dict[str, Type[Forecaster]] = {
    "oracle": OracleForecaster,
    "persistence": PersistenceForecaster,
    "diurnal": DiurnalTemplateForecaster,
}


def make_forecaster(name: str, **params) -> Forecaster:
    if name not in FORECASTERS:
        raise KeyError(
            f"unknown forecaster {name!r}; have {sorted(FORECASTERS)}")
    return FORECASTERS[name](**params)
