"""Per-workload-class latency/deferral metrics.

Carbon savings from deferral are only meaningful priced against what
each class paid for them: interactive requests in TTFT-vs-SLO terms,
deferrable requests in deferral delay and deadline hits. These columns
ride the fleet summary into the sweep reports (Eq. 5 pipeline -> CSV).

Convention matches ``sim.simulator.latency_stats``: latency is always
measured from *arrival* (the user's clock), so admission parking shows
up as latency paid, never hidden.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.sim.requests import DEFERRABLE, INTERACTIVE, Request


def _pctls(vals, prefix: str) -> Dict[str, float]:
    if not vals:
        return {f"{prefix}_p50_s": -1.0, f"{prefix}_p99_s": -1.0}
    return {f"{prefix}_p50_s": float(np.median(vals)),
            f"{prefix}_p99_s": float(np.percentile(vals, 99))}


def class_stats(requests: Sequence[Request]) -> Dict[str, float]:
    """Tidy per-class columns over a served request set."""
    inter = [r for r in requests if r.klass == INTERACTIVE]
    defer = [r for r in requests if r.klass == DEFERRABLE]
    deferred = [r for r in defer if r.release_s > r.arrival_s]
    delays = [r.release_s - r.arrival_s for r in deferred]

    out: Dict[str, float] = {
        "n_interactive": float(len(inter)),
        "n_deferrable": float(len(defer)),
        "deferred_fraction": len(deferred) / max(len(defer), 1),
        "mean_deferral_delay_s": float(np.mean(delays)) if delays else 0.0,
        "max_deferral_delay_s": float(np.max(delays)) if delays else 0.0,
    }
    out.update(_pctls([r.t_first_token - r.arrival_s for r in inter
                       if r.t_first_token >= 0], "interactive_ttft"))
    out.update(_pctls([r.t_done - r.arrival_s for r in inter
                       if r.t_done >= 0], "interactive_e2e"))
    out.update(_pctls([r.t_done - r.arrival_s for r in defer
                       if r.t_done >= 0], "deferrable_e2e"))
    out["interactive_slo_violations"] = float(sum(
        1 for r in inter
        if r.t_first_token >= 0 and np.isfinite(r.slo_s)
        and r.t_first_token - r.arrival_s > r.slo_s))
    out["deadline_violations"] = float(sum(
        1 for r in defer
        if r.t_done < 0 or r.t_done > r.deadline_s))
    return out
