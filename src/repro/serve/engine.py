"""Continuous-batching serving engine running the REAL JAX model.

Fixed-slot design (TPU-friendly static shapes): ``max_slots`` sequences
share one decode cache; free slots are refilled from the waiting queue
via single-sequence prefill + cache insertion. One decode step advances
every active slot by a token.

This engine is the runnable end-to-end driver (examples/serve_demo.py)
and doubles as ground truth for the simulator's scheduler semantics. It
also logs per-iteration (start, duration, token counts) so served traffic
can be fed straight into the energy/carbon pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import Model


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    # runtime
    generated: Optional[List[int]] = None
    slot: int = -1
    t_submit: float = 0.0
    t_first: float = -1.0
    t_done: float = -1.0


@dataclasses.dataclass
class IterationLog:
    start_s: float
    dur_s: float
    kind: str          # prefill | decode
    n_tokens: int
    batch: int


class ServingEngine:
    def __init__(self, model: Model, params, max_slots: int = 8,
                 max_len: int = 512, greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        self.cache = model.init_cache(max_slots, max_len)
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.waiting: List[ServeRequest] = []
        self.done: List[ServeRequest] = []
        self.logs: List[IterationLog] = []
        self.clock = 0.0

        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))

    # -------------- public API --------------
    def submit(self, req: ServeRequest):
        req.generated = []
        req.t_submit = self.clock
        self.waiting.append(req)

    def run(self, max_iters: int = 10_000):
        while (self.waiting or any(self.slots)) and max_iters > 0:
            self.step()
            max_iters -= 1
        return self.done

    # -------------- internals --------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _insert_cache(self, slot: int, req_cache, prefill_len: int):
        """Copy a single-sequence prefill cache into the shared cache."""
        def ins(shared, single):
            # cache layout is (L|n_app, B, ...): batch is axis 1
            if shared.ndim >= 2 and single.ndim == shared.ndim \
                    and single.shape[1] == 1:
                return shared.at[:, slot:slot + 1].set(
                    single.astype(shared.dtype))
            return shared
        new = {}
        for k, v in self.cache.items():
            if k == "lengths":
                new[k] = v.at[slot].set(prefill_len)
            elif k in req_cache:
                new[k] = ins(v, req_cache[k])
            else:
                new[k] = v
        self.cache = new

    def step(self):
        free = self._free_slots()
        t0 = time.time()
        if self.waiting and free:
            req = self.waiting.pop(0)
            slot = free[0]
            P = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            if (self.model.cfg.attention is not None
                    and self.model.cfg.attention.rope == "mrope"):
                pos = jnp.arange(P, dtype=jnp.int32)[None, :, None]
                batch["positions3"] = jnp.broadcast_to(pos, (1, P, 3))
            logits, req_cache = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0]))
            self._insert_cache(slot, req_cache, P)
            req.slot = slot
            req.generated.append(tok)
            req.t_first = self.clock
            self.slots[slot] = req
            dur = time.time() - t0
            self.logs.append(IterationLog(self.clock, dur, "prefill", P, 1))
            self.clock += dur
            self._retire(req)
            return

        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
        logits, self.cache = self._decode(
            self.params, {"tokens": jnp.asarray(tokens)}, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        dur = time.time() - t0
        self.logs.append(IterationLog(self.clock, dur, "decode",
                                      len(active), len(active)))
        self.clock += dur
        for i in active:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            self._retire(req)

    def _retire(self, req: ServeRequest):
        if len(req.generated) >= req.max_new_tokens:
            req.t_done = self.clock
            if req.slot >= 0:
                slot = req.slot
                self.slots[slot] = None
                # zero the slot's cache/state so a reused slot starts clean
                new = {}
                for k, v in self.cache.items():
                    if k == "lengths":
                        new[k] = v.at[slot].set(0)
                    elif k in ("tm_shift", "cm_shift", "wkv", "conv_x",
                               "conv_bc", "ssm") and v.ndim >= 2:
                        new[k] = v.at[:, slot].set(0)
                    else:
                        new[k] = v
                self.cache = new
            self.done.append(req)
