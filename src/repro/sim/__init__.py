from repro.sim.execmodel import (ExecModelConfig, ExecutionModel, StageBatch,
                                 StageCost, StageCostBatch,
                                 cached_execution_model)
from repro.sim.requests import Request, WorkloadConfig, generate
from repro.sim.scheduler import ReplicaScheduler, SchedulerConfig
from repro.sim.simulator import (SimConfig, SimResult, StageLog, energy_report,
                                 run_simulation)
from repro.sim.trace import StageTrace, StageTraceBuilder
from repro.sim.defaults import INTEGRATION_DEFAULT, PAPER_DEFAULT, PAPER_PUE

__all__ = [
    "ExecModelConfig", "ExecutionModel", "StageBatch", "StageCost",
    "StageCostBatch", "cached_execution_model",
    "Request", "WorkloadConfig", "generate",
    "ReplicaScheduler", "RoundRobinRouter", "SchedulerConfig",
    "SimConfig", "SimResult", "StageLog", "energy_report", "run_simulation",
    "StageTrace", "StageTraceBuilder",
    "INTEGRATION_DEFAULT", "PAPER_DEFAULT", "PAPER_PUE",
]


def __getattr__(name):
    # moved to the routing layer; lazy so repro.sim <-> repro.fleet
    # imports never cycle at module load
    if name == "RoundRobinRouter":
        from repro.fleet.routing import RoundRobinRouter
        return RoundRobinRouter
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
