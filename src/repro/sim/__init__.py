from repro.sim.execmodel import ExecModelConfig, ExecutionModel, StageCost
from repro.sim.requests import Request, WorkloadConfig, generate
from repro.sim.scheduler import ReplicaScheduler, RoundRobinRouter, SchedulerConfig
from repro.sim.simulator import (SimConfig, SimResult, StageLog, energy_report,
                                 run_simulation)
from repro.sim.defaults import INTEGRATION_DEFAULT, PAPER_DEFAULT, PAPER_PUE

__all__ = [
    "ExecModelConfig", "ExecutionModel", "StageCost",
    "Request", "WorkloadConfig", "generate",
    "ReplicaScheduler", "RoundRobinRouter", "SchedulerConfig",
    "SimConfig", "SimResult", "StageLog", "energy_report", "run_simulation",
    "INTEGRATION_DEFAULT", "PAPER_DEFAULT", "PAPER_PUE",
]
