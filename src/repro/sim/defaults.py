"""Paper Table 1 default parameterizations."""
from repro.configs.paper_models import LLAMA3_8B, LLAMA2_7B
from repro.sim.execmodel import ExecModelConfig
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig
from repro.sim.simulator import SimConfig

# Table 1(a): default Vidur configuration
PAPER_DEFAULT = SimConfig(
    model=LLAMA3_8B,
    device="a100",
    n_replicas=1, tp=1, pp=1,
    workload=WorkloadConfig(n_requests=1024, qps=6.45, arrival="poisson",
                            length_dist="zipf", zipf_theta=0.6,
                            min_len=128, max_len=4096, pd_ratio=20.0,
                            seed=0),
    scheduler=SchedulerConfig(batch_cap=128, max_tokens=4096),
)

# Table 1(b): Vidur-Vessim integration case study
INTEGRATION_DEFAULT = SimConfig(
    model=LLAMA2_7B,
    device="a100",
    n_replicas=1, tp=1, pp=1,
    workload=WorkloadConfig(n_requests=400_000, qps=20.0, arrival="poisson",
                            length_dist="zipf", zipf_theta=0.6,
                            min_len=1024, max_len=4096, pd_ratio=20.0,
                            seed=7),
    scheduler=SchedulerConfig(batch_cap=128, max_tokens=4096),
)
PAPER_PUE = 1.2
