"""Analytical batch-stage execution model (the Vidur random-forest
replacement — see DESIGN.md §3.2).

Stage latency is a three-term roofline over the batch composition:

  t_compute = FLOPs / (eff(tokens) * peak * TP)        per pipeline stage
  t_memory  = bytes(weights/TP + KV + activations) / (HBM_bw * TP)
  t_coll    = TP all-reduce traffic / link_bw (+ PP activation handoff)
  t_stage   = max(t_compute, t_memory) + (1 - overlap) * t_coll + t_0

The matmul efficiency curve eff(tokens) saturates with batched tokens
(arithmetic intensity): calibrated so Meta-Llama-3-8B on A100 plateaus
near MFU 0.45 at 5-8 QPS, reproducing the paper's Fig. 1. On TPU the
same form is calibrated against the dry-run's compiled cost analysis
(`calibrate_from_dryrun`).

Array-native core: a stage's composition reduces to four aggregates —
summed prefill tokens, decode count, score FLOPs, KV read/write bytes
(``StageBatch``) — and the roofline over those aggregates is a pure
elementwise kernel (``stage_cost_batch``) that evaluates ONE stage or a
whole trace of stages in a single numpy pass (optionally ``jax.jit``).
The scalar ``stage_cost`` is a thin length-1 view over the batched
kernel, so scalar (event-loop) and batched (sweep replay) paths are
bit-identical by construction.

All per-model constants (active parameter count, KV bytes/token,
per-token FLOP totals, score coefficients) are computed once at
``ExecutionModel`` construction, not per stage-cost call.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.power import DEVICES, DeviceProfile


@dataclasses.dataclass(frozen=True)
class ExecModelConfig:
    eff_max: float = 0.52          # peak matmul efficiency (fraction of peak)
    eff_half_tokens: float = 192.0  # tokens at which eff reaches half of max
    stage_overhead_s: float = 200e-6
    activation_bytes_factor: float = 8.0  # bytes/token/layer ~ f*d_model
    collective_overlap: float = 0.0       # 0 = no overlap (baseline)
    kv_dtype_bytes: int = 2
    weight_dtype_bytes: int = 2


@dataclasses.dataclass
class StageCost:
    t_total: float
    t_compute: float
    t_memory: float
    t_collective: float
    flops_mlp: float
    flops_attn: float
    mfu: float


@dataclasses.dataclass
class StageBatch:
    """Per-stage batch-composition aggregates, over N stages.

    These four arrays — plus the per-model invariants cached on the
    ``ExecutionModel`` — fully determine the roofline, so a logged
    trace of them can be re-costed in one array pass.
    """
    prefill_tokens: np.ndarray   # summed prefill (chunk) tokens per stage
    decode_count: np.ndarray     # sequences decoding one token per stage
    score_flops: np.ndarray      # context-dependent attention score FLOPs
    kv_rw_bytes: np.ndarray      # KV cache read+write traffic per stage

    def __len__(self) -> int:
        return len(self.prefill_tokens)

    @classmethod
    def concat(cls, batches: Sequence["StageBatch"]) -> "StageBatch":
        return cls(*(np.concatenate([getattr(b, f.name) for b in batches])
                     for f in dataclasses.fields(cls)))

    @classmethod
    def from_trace(cls, trace) -> "StageBatch":
        """Rebuild the aggregates from a logged ``StageTrace``."""
        return cls(
            prefill_tokens=np.asarray(trace.n_prefill_tokens, np.float64),
            decode_count=np.asarray(trace.n_decode_tokens, np.float64),
            score_flops=np.asarray(trace.score_flops, np.float64),
            kv_rw_bytes=np.asarray(trace.kv_rw_bytes, np.float64))


@dataclasses.dataclass
class StageCostBatch:
    """Roofline outputs over N stages (arrays aligned with StageBatch)."""
    t_total: np.ndarray
    t_compute: np.ndarray
    t_memory: np.ndarray
    t_collective: np.ndarray
    flops_mlp: np.ndarray
    flops_attn: np.ndarray
    mfu: np.ndarray

    def __len__(self) -> int:
        return len(self.t_total)

    def row(self, i: int = 0) -> StageCost:
        return StageCost(
            t_total=float(self.t_total[i]),
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_collective=float(self.t_collective[i]),
            flops_mlp=float(self.flops_mlp[i]),
            flops_attn=float(self.flops_attn[i]),
            mfu=float(self.mfu[i]))


@dataclasses.dataclass(frozen=True)
class _Params:
    """Scalar roofline parameters, resolved once per ExecutionModel.
    The kernel below reads only this (plus the StageBatch arrays), so
    the numpy and jax paths share one implementation."""
    fpt_mlp: float
    fpt_proj: float
    weight_bytes: float
    act_bytes_per_token: float
    coll_s_per_token: float
    coll_scale: float
    overhead_s: float
    eff_max: float
    eff_half_tokens: float
    peak_chips: float
    hbm_chips: float
    pp: float


#: flat field order of the roofline parameter vector
#: (``ExecutionModel.params_vector`` / the device-mode batched program,
#: which reconstructs ``_Params(*row)`` per trace group inside vmap)
PARAMS_FIELDS = tuple(f.name for f in dataclasses.fields(_Params))

#: relative tolerance for ``stage_cost_batch(backend="jax")`` against
#: the ``"numpy"`` reference: the jitted kernel runs in float32 on
#: default jax builds (eps ~1.2e-7) and the roofline chains ~6
#: elementwise ops, so the observed divergence is a few f32 ulps;
#: 1e-5 leaves roughly two decades of margin (pinned per paper model
#: by tests/test_device_mode.py).
JAX_BACKEND_RTOL = 1e-5


def _roofline(prefill_tokens, decode_count, score_flops, kv_rw_bytes,
              p, xp=np):
    """The three-term roofline, elementwise over stages. ``xp`` is
    ``numpy`` (default) or ``jax.numpy`` — same ops either way."""
    tokens = prefill_tokens + decode_count
    live = tokens > 0
    safe_tokens = xp.where(live, tokens, 1.0)

    f_mlp = tokens * p.fpt_mlp
    f_attn = tokens * p.fpt_proj + score_flops
    flops_st = (f_mlp + f_attn) / p.pp
    mem_st = (p.weight_bytes + kv_rw_bytes
              + tokens * p.act_bytes_per_token) / p.pp

    eff = p.eff_max * safe_tokens / (safe_tokens + p.eff_half_tokens)
    t_comp = flops_st / (eff * p.peak_chips)
    t_mem = mem_st / p.hbm_chips
    t_coll = tokens * p.coll_s_per_token
    t = (xp.maximum(t_comp, t_mem) + p.coll_scale * t_coll
         + p.overhead_s)
    mfu = flops_st / (p.peak_chips * xp.where(live, t, 1.0))

    zero = xp.zeros_like(tokens)
    out = []
    for v in (t, t_comp, t_mem, t_coll, f_mlp / p.pp, f_attn / p.pp, mfu):
        out.append(xp.where(live, v, zero))
    return tuple(out)


class ExecutionModel:
    def __init__(self, model: ModelConfig, device: DeviceProfile,
                 tp: int = 1, pp: int = 1,
                 cfg: ExecModelConfig = ExecModelConfig()):
        self.model = model
        self.dev = device
        self.tp = tp
        self.pp = pp
        self.cfg = cfg

        # ---- per-model invariants, computed ONCE (not per stage) ----
        m, c = model, cfg
        self.active_params = m.active_param_count()
        self.kv_bytes_per_token = float(m.kv_bytes_per_token(c.kv_dtype_bytes))
        self.fpt_mlp = m.flops_per_token_mlp_total()
        self.fpt_proj = m.flops_per_token_attn_proj_total()
        # score(ctx) = score_coef * min(ctx, window) + score_const:
        # the context-linear attention part plus the constant ssm/rwkv
        # per-token mixing terms (flops_attn_score_per_token's shape)
        self.score_const = float(m.flops_attn_score_per_token(0))
        self.score_coef = float(m.flops_attn_score_per_token(1)
                                - self.score_const)
        a = m.attention
        self.sliding_window = (float(a.sliding_window)
                               if (a and a.sliding_window) else math.inf)

        chips = tp
        coll = 0.0
        if tp > 1:
            # 2 all-reduces per layer of the activation block (ring)
            coll += (2.0 * m.d_model * 2 * (m.n_layers / pp)
                     * 2.0 * (tp - 1) / tp) / device.link_bw
        if pp > 1:
            coll += m.d_model * 2 / device.link_bw
        self._params = _Params(
            fpt_mlp=float(self.fpt_mlp),
            fpt_proj=float(self.fpt_proj),
            weight_bytes=float(self.active_params * c.weight_dtype_bytes),
            act_bytes_per_token=float(m.n_layers * m.d_model
                                      * c.activation_bytes_factor),
            coll_s_per_token=float(coll),
            coll_scale=float(1.0 - c.collective_overlap),
            overhead_s=float(c.stage_overhead_s),
            eff_max=float(c.eff_max),
            eff_half_tokens=float(c.eff_half_tokens),
            peak_chips=float(device.peak_flops * chips),
            hbm_chips=float(device.hbm_bw * chips),
            pp=float(pp))
        self._jax_kernel = None

    def _eff(self, tokens: float) -> float:
        c = self.cfg
        return c.eff_max * tokens / (tokens + c.eff_half_tokens)

    def params_vector(self) -> np.ndarray:
        """The resolved roofline parameters as a flat float64 vector in
        ``PARAMS_FIELDS`` order — the per-group row the device-mode
        sweep stacks into its (groups, params) tensor."""
        return np.array([getattr(self._params, name)
                         for name in PARAMS_FIELDS], np.float64)

    def replica_tokens_per_s(self, batch_cap: int, kv_budget_tokens: int,
                             mean_prefill: float, mean_decode: float
                             ) -> float:
        """Model-derived steady-state per-replica token throughput at
        full batching: ``B`` requests of the mean shape served per
        ``t_prefill(B*L) + D * t_decode(B @ mid-context)`` seconds,
        with ``B`` capped by the batch cap and the KV budget.

        Used by the day planner's saturation guard as a *capacity
        floor* alongside the autoscaler's configured estimate — a
        config estimate far above what the roofline can actually
        serve would otherwise let a queue-saturated epoch slip
        through the fluid path (whose pilot tiles a growing queue).
        """
        L = max(float(mean_prefill), 1.0)
        D = max(float(mean_decode), 1.0)
        per_req = L + D
        b = min(float(batch_cap), float(kv_budget_tokens) / per_req)
        b = max(1.0, np.floor(b))
        t_pre = self.stage_cost_scalar([L] * int(b), [])[0].t_total
        mid_ctx = L + np.floor(D / 2.0)
        t_dec = self.stage_cost_scalar([], [mid_ctx] * int(b))[0].t_total
        return b * per_req / max(t_pre + D * t_dec, 1e-9)

    def _score_per_token(self, ctx):
        """score FLOPs per token at context length(s) ctx (array op)."""
        return (self.score_coef * np.minimum(ctx, self.sliding_window)
                + self.score_const)

    def aggregate(self, prefill_lens: Sequence[int],
                  decode_ctxs: Sequence[int],
                  prefill_offsets: Optional[Sequence[int]] = None
                  ) -> StageBatch:
        """Reduce ONE stage's composition to its StageBatch aggregates
        (length-1 arrays).

        prefill_lens: prompt (chunk) token counts prefilled this stage.
        decode_ctxs: context lengths of sequences generating one token.
        prefill_offsets: tokens of each prompt ALREADY prefilled by
        earlier chunks (Sarathi chunking); 0/None = fresh prefill. A
        chunk at offset o attends over the o previously-prefilled
        context tokens, so it re-reads their KV (the cross-chunk read
        term) and its score FLOPs see an average context of o + L/2
        instead of L/2.
        """
        plens = np.asarray(prefill_lens, np.float64)
        ctxs = np.asarray(decode_ctxs, np.float64)
        if prefill_offsets is None:
            offs = np.zeros_like(plens)
        else:
            offs = np.asarray(prefill_offsets, np.float64)

        npt = float(np.sum(plens))
        nd = float(len(ctxs))

        # causal prefill: average context = offset + L/2
        avg_ctx = np.maximum(offs + np.floor(plens / 2.0), 1.0)
        f_score = (float(np.sum(plens * self._score_per_token(avg_ctx)))
                   + float(np.sum(self._score_per_token(ctxs))))

        kvpt = self.kv_bytes_per_token
        w = self.sliding_window
        # prefill writes its chunk's K/V and re-reads the already-
        # prefilled context (bounded by the attention window)
        kv_pre = np.sum(plens * kvpt + np.minimum(offs, w) * kvpt)
        # decode reads the cache (window-bounded) + writes one token
        kv_dec = np.sum(np.minimum(ctxs, w) * kvpt + kvpt)
        kv_rw = float(kv_pre + kv_dec)

        return StageBatch(prefill_tokens=np.array([npt]),
                          decode_count=np.array([nd]),
                          score_flops=np.array([f_score]),
                          kv_rw_bytes=np.array([kv_rw]))

    def stage_cost_batch(self, batch: StageBatch,
                         backend: str = "numpy") -> StageCostBatch:
        """Evaluate the roofline over N stages in one array pass.

        ``backend="numpy"`` (default) is the reference path — bit-
        identical to the scalar ``stage_cost``. ``backend="jax"`` jits
        the same kernel (float32 on most platforms, so outputs are
        close but not bit-equal; use it for throughput, not pinning).
        """
        args = (np.asarray(batch.prefill_tokens, np.float64),
                np.asarray(batch.decode_count, np.float64),
                np.asarray(batch.score_flops, np.float64),
                np.asarray(batch.kv_rw_bytes, np.float64))
        if backend == "numpy":
            return StageCostBatch(*_roofline(*args, self._params, np))
        if backend == "jax":
            if self._jax_kernel is None:
                import jax
                import jax.numpy as jnp
                p = self._params
                self._jax_kernel = jax.jit(
                    lambda npt, nd, sc, kv: _roofline(npt, nd, sc, kv,
                                                      p, jnp))
            out = self._jax_kernel(*args)
            return StageCostBatch(*(np.asarray(v) for v in out))
        raise ValueError(f"unknown backend {backend!r}")

    def stage_cost(self, prefill_lens: Sequence[int],
                   decode_ctxs: Sequence[int],
                   prefill_offsets: Optional[Sequence[int]] = None
                   ) -> StageCost:
        """Cost of ONE batch stage (= one scheduler iteration on one
        pipeline stage's share of layers) — a length-1 view over
        ``stage_cost_batch``."""
        batch = self.aggregate(prefill_lens, decode_ctxs, prefill_offsets)
        return self.stage_cost_batch(batch).row(0)

    def stage_cost_scalar(self, prefill_lens: Sequence[int],
                          decode_ctxs: Sequence[int],
                          prefill_offsets: Optional[Sequence[int]] = None):
        """One stage's cost without the length-1 array round-trip:
        ``aggregate`` + ``stage_cost_batch().row(0)`` spend most of
        their time wrapping four scalars into arrays and dispatching
        elementwise kernels over them — pure overhead on the event
        loop's hot path, where a day-scale exact epoch evaluates
        hundreds of thousands of single stages.

        Bit-identical to the batched path by construction: the batch-
        composition reductions keep numpy's pairwise summation (same
        expressions, ``.sum()`` method instead of the ``np.sum``
        wrapper), and the roofline runs the same IEEE-double operation
        sequence on Python floats. Pinned by tests.

        Returns ``(StageCost, prefill_tokens, decode_count,
        score_flops, kv_rw_bytes)`` — the cost plus the stage's
        StageBatch aggregates as plain floats (what the trace logs).
        """
        plens = np.asarray(prefill_lens, np.float64)
        ctxs = np.asarray(decode_ctxs, np.float64)
        offs = (np.zeros_like(plens) if prefill_offsets is None
                else np.asarray(prefill_offsets, np.float64))

        npt = float(plens.sum())
        nd = float(len(ctxs))
        avg_ctx = np.maximum(offs + np.floor(plens / 2.0), 1.0)
        f_score = (float((plens * self._score_per_token(avg_ctx)).sum())
                   + float(self._score_per_token(ctxs).sum()))
        kvpt = self.kv_bytes_per_token
        w = self.sliding_window
        kv_pre = (plens * kvpt + np.minimum(offs, w) * kvpt).sum()
        kv_dec = (np.minimum(ctxs, w) * kvpt + kvpt).sum()
        kv_rw = float(kv_pre + kv_dec)

        p = self._params
        tokens = npt + nd
        if tokens > 0:
            f_mlp = tokens * p.fpt_mlp
            f_attn = tokens * p.fpt_proj + f_score
            flops_st = (f_mlp + f_attn) / p.pp
            mem_st = (p.weight_bytes + kv_rw
                      + tokens * p.act_bytes_per_token) / p.pp
            eff = p.eff_max * tokens / (tokens + p.eff_half_tokens)
            t_comp = flops_st / (eff * p.peak_chips)
            t_mem = mem_st / p.hbm_chips
            t_coll = tokens * p.coll_s_per_token
            t = (max(t_comp, t_mem) + p.coll_scale * t_coll
                 + p.overhead_s)
            cost = StageCost(
                t_total=t, t_compute=t_comp, t_memory=t_mem,
                t_collective=t_coll, flops_mlp=f_mlp / p.pp,
                flops_attn=f_attn / p.pp,
                mfu=flops_st / (p.peak_chips * t))
        else:
            cost = StageCost(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
        return cost, npt, nd, f_score, kv_rw


@functools.lru_cache(maxsize=512)
def cached_execution_model(model: ModelConfig, device_name: str,
                           tp: int, pp: int,
                           cfg: ExecModelConfig) -> ExecutionModel:
    """Per-process memoized ExecutionModel construction.

    ExecutionModel is stateless after __init__ (pure roofline
    functions over cached invariants), so sweep workers reuse one
    instance across every grid point that shares (model, device,
    TP, PP, exec config) instead of reconstructing it per scenario.
    """
    return ExecutionModel(model, DEVICES[device_name], tp, pp, cfg)


def calibrate_from_dryrun(exec_cfg: ExecModelConfig, hlo_dot_flops: float,
                          analytic_flops: float) -> ExecModelConfig:
    """Scale eff_max by the compiled-vs-analytic FLOP ratio so the
    simulator's time model reflects what XLA actually emits."""
    if analytic_flops <= 0 or hlo_dot_flops <= 0:
        return exec_cfg
    ratio = analytic_flops / hlo_dot_flops
    return dataclasses.replace(exec_cfg,
                               eff_max=exec_cfg.eff_max * min(1.0, ratio))
