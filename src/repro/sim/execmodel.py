"""Analytical batch-stage execution model (the Vidur random-forest
replacement — see DESIGN.md §3.2).

Stage latency is a three-term roofline over the batch composition:

  t_compute = FLOPs / (eff(tokens) * peak * TP)        per pipeline stage
  t_memory  = bytes(weights/TP + KV + activations) / (HBM_bw * TP)
  t_coll    = TP all-reduce traffic / link_bw (+ PP activation handoff)
  t_stage   = max(t_compute, t_memory) + (1 - overlap) * t_coll + t_0

The matmul efficiency curve eff(tokens) saturates with batched tokens
(arithmetic intensity): calibrated so Meta-Llama-3-8B on A100 plateaus
near MFU 0.45 at 5-8 QPS, reproducing the paper's Fig. 1. On TPU the
same form is calibrated against the dry-run's compiled cost analysis
(`calibrate_from_dryrun`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.power import DeviceProfile


@dataclasses.dataclass(frozen=True)
class ExecModelConfig:
    eff_max: float = 0.52          # peak matmul efficiency (fraction of peak)
    eff_half_tokens: float = 192.0  # tokens at which eff reaches half of max
    stage_overhead_s: float = 200e-6
    activation_bytes_factor: float = 8.0  # bytes/token/layer ~ f*d_model
    collective_overlap: float = 0.0       # 0 = no overlap (baseline)
    kv_dtype_bytes: int = 2
    weight_dtype_bytes: int = 2


@dataclasses.dataclass
class StageCost:
    t_total: float
    t_compute: float
    t_memory: float
    t_collective: float
    flops_mlp: float
    flops_attn: float
    mfu: float


class ExecutionModel:
    def __init__(self, model: ModelConfig, device: DeviceProfile,
                 tp: int = 1, pp: int = 1,
                 cfg: ExecModelConfig = ExecModelConfig()):
        self.model = model
        self.dev = device
        self.tp = tp
        self.pp = pp
        self.cfg = cfg

    def _eff(self, tokens: float) -> float:
        c = self.cfg
        return c.eff_max * tokens / (tokens + c.eff_half_tokens)

    def stage_cost(self, prefill_lens: Sequence[int],
                   decode_ctxs: Sequence[int]) -> StageCost:
        """Cost of ONE batch stage (= one scheduler iteration on one
        pipeline stage's share of layers).

        prefill_lens: prompt lengths being prefilled this iteration.
        decode_ctxs: context lengths of sequences generating one token."""
        m = self.model
        c = self.cfg
        n_prefill = int(np.sum(prefill_lens)) if len(prefill_lens) else 0
        n_decode = len(decode_ctxs)
        tokens = n_prefill + n_decode
        if tokens == 0:
            return StageCost(0, 0, 0, 0, 0, 0, 0)

        f_mlp = tokens * m.flops_per_token_mlp_total()
        f_proj = tokens * m.flops_per_token_attn_proj_total()
        f_score = 0.0
        for L in prefill_lens:
            # causal prefill: average context = L/2
            f_score += L * m.flops_attn_score_per_token(max(L // 2, 1))
        for ctx in decode_ctxs:
            f_score += m.flops_attn_score_per_token(ctx)
        f_attn = f_proj + f_score
        flops = f_mlp + f_attn

        # memory traffic
        w_bytes = m.active_param_count() * c.weight_dtype_bytes
        kv_rw = 0.0
        kvpt = m.kv_bytes_per_token(c.kv_dtype_bytes)
        for L in prefill_lens:
            kv_rw += L * kvpt                     # write K/V
        for ctx in decode_ctxs:
            a = m.attention
            eff_ctx = min(ctx, a.sliding_window) if (a and a.sliding_window) else ctx
            kv_rw += eff_ctx * kvpt + kvpt        # read cache + write one
        act_bytes = tokens * m.n_layers * m.d_model * c.activation_bytes_factor
        mem_bytes = w_bytes + kv_rw + act_bytes

        # per pipeline stage (layers split across PP)
        flops_st = flops / self.pp
        mem_st = mem_bytes / self.pp

        chips = self.tp
        t_comp = flops_st / (self._eff(tokens) * self.dev.peak_flops * chips)
        t_mem = mem_st / (self.dev.hbm_bw * chips)

        t_coll = 0.0
        if self.tp > 1:
            # 2 all-reduces per layer of the activation block (ring)
            ar_bytes = (2 * tokens * m.d_model * 2
                        * (m.n_layers / self.pp)
                        * 2.0 * (self.tp - 1) / self.tp)
            t_coll += ar_bytes / self.dev.link_bw
        if self.pp > 1:
            t_coll += tokens * m.d_model * 2 / self.dev.link_bw

        t = (max(t_comp, t_mem)
             + (1.0 - c.collective_overlap) * t_coll
             + c.stage_overhead_s)
        mfu = flops_st / (self.dev.peak_flops * chips * t)
        return StageCost(t_total=t, t_compute=t_comp, t_memory=t_mem,
                         t_collective=t_coll, flops_mlp=f_mlp / self.pp,
                         flops_attn=f_attn / self.pp, mfu=mfu)


def calibrate_from_dryrun(exec_cfg: ExecModelConfig, hlo_dot_flops: float,
                          analytic_flops: float) -> ExecModelConfig:
    """Scale eff_max by the compiled-vs-analytic FLOP ratio so the
    simulator's time model reflects what XLA actually emits."""
    if analytic_flops <= 0 or hlo_dot_flops <= 0:
        return exec_cfg
    ratio = analytic_flops / hlo_dot_flops
    return dataclasses.replace(exec_cfg,
                               eff_max=exec_cfg.eff_max * min(1.0, ratio))
