"""Fluid/request hybrid day simulation: epoch planning + fluid epochs.

Day-scale workloads (millions of requests) cannot event-step every
request. The hybrid mode partitions the day into fixed epochs, and for
each epoch either

* runs the **exact** continuous-batching event loop over the epoch's
  arrivals (transient epochs: load ramps, burst windows, saturation
  onset, deferral drain bursts, autoscale events), or
* evaluates a **fluid** approximation: event-step only a pilot slice
  of the epoch's arrivals, discard a warmup prefix, and tile the
  steady-state stage block across the epoch — synthesizing a
  representative ``StageTrace`` whose energy/carbon evaluate through
  the same batched array passes as an exact trace, with latency
  percentiles taken from the pilot sample at proportional weight.

Both day modes (``hybrid`` and ``event_loop``) segment the day into
the *same* epochs with fresh replica state at each epoch start, so an
epoch the planner marks exact sees bit-identical inputs in either mode
— transient windows agree bit-for-bit by construction, which is what
the day-smoke CI job pins. A fluid epoch whose pilot covers all its
arrivals degenerates to the exact run (weight 1, no tiling), giving
the fluid==exact property on windows with no transients.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.sim.trace import StageTrace
from repro.workloads.stream import ArrivalStream

DAY_MODES = ("hybrid", "event_loop")

EXACT, FLUID = "exact", "fluid"


@dataclasses.dataclass(frozen=True)
class DayConfig:
    """Epoch segmentation + fluid-approximation knobs for a day run."""
    mode: str = "hybrid"              # hybrid | event_loop
    epoch_s: float = 900.0            # epoch length (s)
    pilot_requests: int = 256         # fluid: sampled requests per epoch
    warmup_requests: int = 64         # fluid: discarded pilot prefix
    ramp_threshold: float = 0.25      # epoch-over-epoch rate change
    burst_threshold: float = 0.5      # within-epoch sub-bin rate swing
    util_threshold: float = 0.85      # saturation onset
    drain_threshold: float = 0.15     # deferral-release mass fraction

    def __post_init__(self):
        if self.mode not in DAY_MODES:
            raise ValueError(f"unknown day mode {self.mode!r}; "
                             f"have {DAY_MODES}")
        if self.epoch_s <= 0:
            raise ValueError("epoch_s must be positive")


@dataclasses.dataclass
class Epoch:
    """One planned epoch of a site's day."""
    index: int
    t0: float
    t1: float
    i0: int                           # stream row range [i0, i1)
    i1: int
    planned: str = FLUID              # exact | fluid (planner label)
    reason: str = "steady"            # why exact / "steady" for fluid
    n_replicas: int = 1               # active replicas this epoch
    n_warm: int = 0                   # warm spares (idle power only)
    cold_from: Optional[int] = None   # replicas >= this index start at
    scale_latency_s: float = 0.0      # t0 + scale_latency_s (cold adds)


def epoch_bounds(t_end: float, epoch_s: float) -> np.ndarray:
    """[0, e, 2e, ...] covering [0, t_end] (at least one epoch)."""
    n = max(1, int(np.ceil(max(t_end, 1e-9) / epoch_s)))
    return np.arange(n + 1, dtype=np.float64) * epoch_s


def plan_epochs(stream: ArrivalStream, bounds: np.ndarray, day: DayConfig,
                tokens_per_s: float, replica_plan: np.ndarray,
                warm_plan: Optional[np.ndarray] = None,
                scale_latency_s: float = 0.0,
                drain_counts: Optional[np.ndarray] = None,
                sat_tokens_per_s: Optional[float] = None) -> List[Epoch]:
    """Classify each epoch exact/fluid from the arrival stream alone.

    ``stream`` must be sorted by ready time. ``tokens_per_s`` is the
    per-replica service-capacity estimate used for the saturation
    check; ``replica_plan``/``warm_plan`` are per-epoch active/warm
    replica counts (the autoscale plan — a count change marks the
    epoch transient). The classification never looks at simulation
    output, so both day modes plan identically.

    ``sat_tokens_per_s`` overrides the capacity used by the saturation
    check only (``util_threshold``). The day driver passes the min of
    the autoscaler's configured estimate and the roofline-derived
    ``ExecutionModel.replica_tokens_per_s`` — an optimistic configured
    estimate must not hide a queue-saturated epoch from the planner
    (the fluid pilot would tile a growing queue, losing the latency
    tail), while the autoscaler itself keeps planning replicas off its
    own estimate.
    """
    n_ep = len(bounds) - 1
    edges = np.searchsorted(stream.ready_s, bounds, side="left")
    counts = np.diff(edges)
    dts = np.diff(bounds)
    rates = counts / np.maximum(dts, 1e-9)
    tok_sums = np.zeros(n_ep)
    np.add.at(tok_sums, np.clip(
        np.searchsorted(bounds, stream.ready_s, side="right") - 1,
        0, n_ep - 1), stream.tokens.astype(np.float64))
    mean_tok = tok_sums / np.maximum(counts, 1)
    util1 = rates * mean_tok / max(tokens_per_s, 1e-9)
    util_sat = (util1 if sat_tokens_per_s is None
                else rates * mean_tok / max(sat_tokens_per_s, 1e-9))
    warm_plan = (np.zeros(n_ep, int) if warm_plan is None
                 else np.asarray(warm_plan))
    drain_counts = (np.zeros(n_ep) if drain_counts is None
                    else np.asarray(drain_counts, np.float64))

    epochs: List[Epoch] = []
    for e in range(n_ep):
        t0, t1 = float(bounds[e]), float(bounds[e + 1])
        i0, i1 = int(edges[e]), int(edges[e + 1])
        n_act = int(replica_plan[e])
        reason = None
        prev_act = int(replica_plan[e - 1]) if e > 0 else n_act
        if n_act != prev_act:
            reason = "autoscale"
        elif util_sat[e] / max(n_act, 1) > day.util_threshold:
            reason = "saturation"
        elif e > 0 and (abs(rates[e] - rates[e - 1])
                        / max(rates[e], rates[e - 1], 1e-9)
                        > day.ramp_threshold):
            reason = "ramp"
        elif drain_counts[e] / max(counts[e], 1) > day.drain_threshold:
            reason = "drain"
        elif counts[e] >= 8:
            sub = np.histogram(stream.ready_s[i0:i1],
                               bins=4, range=(t0, t1))[0]
            if (sub.max() - sub.min()) / max(sub.mean(), 1e-9) \
                    > day.burst_threshold:
                reason = "burst"
        cold = None
        if reason == "autoscale" and n_act > prev_act:
            # replicas beyond the previous active set spin up; warm
            # spares from the previous epoch reactivate instantly,
            # the rest pay the cold-start latency
            warm_prev = int(warm_plan[e - 1]) if e > 0 else 0
            first_cold = prev_act + warm_prev
            cold = first_cold if first_cold < n_act else None
        epochs.append(Epoch(
            index=e, t0=t0, t1=t1, i0=i0, i1=i1,
            planned=EXACT if reason else FLUID,
            reason=reason or "steady", n_replicas=n_act,
            n_warm=int(warm_plan[e]), cold_from=cold,
            scale_latency_s=scale_latency_s))
    return epochs


@dataclasses.dataclass
class EpochEval:
    """One epoch's evaluation: a (synthesized or exact) stage trace
    plus weighted latency samples."""
    epoch: Epoch
    trace: StageTrace
    ttft_s: np.ndarray                # per sampled request
    e2e_s: np.ndarray
    weight: float                     # requests represented per sample
    n_requests: int                   # arrivals accounted to the epoch
    n_simulated: int                  # arrivals actually event-stepped
    executed: str = EXACT             # what actually ran


def _latencies(reqs, skip: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Queueing+service latency, measured from the *ready* time
    (admission release for deferred requests, arrival otherwise) —
    the deferral wait is accounted separately (``deferral_mean_s``/
    ``deferral_max_s`` in the day summary), not folded into the
    service tail. Interactive requests are never deferred, so their
    ready time IS their arrival (PR 3's ``interactive_ttft``
    convention)."""
    ttft = np.asarray([r.t_first_token - r.ready_s for r in reqs[skip:]
                       if r.t_first_token >= 0], np.float64)
    e2e = np.asarray([r.t_done - r.ready_s for r in reqs[skip:]
                      if r.t_done >= 0], np.float64)
    return ttft, e2e


def _tile_trace(trace: StageTrace, mask: np.ndarray, t_w: float,
                span: float, t0: float, t1: float) -> StageTrace:
    """Tile the steady-state stage block (rows where ``mask``) across
    [t0, t1): copy j gets start ``(start - t_w) + t0 + j * span``."""
    reps = max(1, int(np.ceil((t1 - t0) / span)))
    base = trace.start_s[mask] - t_w + t0
    starts = np.concatenate([base + j * span for j in range(reps)])
    keep = starts < t1
    cols = {}
    for f in dataclasses.fields(StageTrace):
        col = getattr(trace, f.name)[mask]
        cols[f.name] = (starts if f.name == "start_s"
                        else np.tile(col, reps))[keep]
    return StageTrace(**cols)


def evaluate_epoch(epoch: Epoch, stream: ArrivalStream, day: DayConfig,
                   run_window: Callable, force_exact: bool = False,
                   probe=None) -> EpochEval:
    """Evaluate one epoch. ``run_window(epoch, lo, hi)`` must run the
    exact event loop over stream rows [lo, hi) with fresh replicas
    (clocked from the epoch start) and return ``(StageTrace,
    List[Request])``.

    A fluid epoch whose pilot budget covers every arrival short-
    circuits to the exact run — tiling a complete sample is the
    identity, so hybrid == event_loop bitwise on such epochs.

    ``probe`` (``repro.obs.Probe``) receives ``on_epoch_eval(0, ev)``
    for every evaluation (site 0 — the day driver re-tags through
    ``SiteIndexProbe``); it never affects the result.
    """
    def _emit(ev: EpochEval) -> EpochEval:
        if probe is not None:
            probe.on_epoch_eval(0, ev)
        return ev

    n = epoch.i1 - epoch.i0
    pilot_n = day.warmup_requests + day.pilot_requests
    skip, pilot_end = day.warmup_requests, pilot_n
    exact = (force_exact or epoch.planned == EXACT or n <= pilot_n)
    if not exact:
        # Deferral releases land at a single ready instant. When a
        # sub-threshold drain clump swallows the whole default pilot
        # (t_p == t_w), extend the warmup past the clump to the first
        # organically-spread arrival so the steady-state window keeps
        # positive span — falling back to exact here would silently
        # event-step every epoch the deferral policy targets, which at
        # day scale is most of the overnight trough.
        ready = stream.ready_s[epoch.i0:epoch.i1]
        if ready[pilot_n - 1] - ready[skip] <= 1e-9:
            skip = int(np.searchsorted(ready, ready[skip] + 1e-9))
            pilot_end = skip + day.pilot_requests
            if pilot_end >= n:
                exact = True    # the clump IS the epoch: run it exactly
    if exact:
        trace, reqs = run_window(epoch, epoch.i0, epoch.i1)
        ttft, e2e = _latencies(reqs)
        return _emit(EpochEval(epoch, trace, ttft, e2e, 1.0, n, n,
                               executed=EXACT if (force_exact or
                                                  epoch.planned == EXACT)
                               else FLUID))

    trace, reqs = run_window(epoch, epoch.i0, epoch.i0 + pilot_end)
    t_w = float(reqs[skip].ready_s)
    t_p = float(reqs[-1].ready_s)
    mask = (trace.start_s >= t_w) & (trace.start_s < t_p)
    if t_p - t_w <= 1e-9 or not mask.any():
        # degenerate pilot (clumped arrivals): fall back to exact
        trace, reqs = run_window(epoch, epoch.i0, epoch.i1)
        ttft, e2e = _latencies(reqs)
        return _emit(EpochEval(epoch, trace, ttft, e2e, 1.0, n, n,
                               executed=FLUID))
    synth = _tile_trace(trace, mask, t_w, t_p - t_w, epoch.t0, epoch.t1)
    ttft, e2e = _latencies(reqs, skip=skip)
    n_sample = len(reqs) - skip
    return _emit(EpochEval(epoch, synth, ttft, e2e,
                           weight=n / max(n_sample, 1), n_requests=n,
                           n_simulated=len(reqs), executed=FLUID))


def concat_traces(traces: List[StageTrace]) -> StageTrace:
    cols = {}
    for f in dataclasses.fields(StageTrace):
        parts = [getattr(t, f.name) for t in traces if len(t)]
        cols[f.name] = (np.concatenate(parts) if parts
                        else np.empty(0, np.int64
                                      if f.name in ("n_prefill_tokens",
                                                    "n_decode_tokens",
                                                    "replica", "batch_size")
                                      else np.float64))
    return StageTrace(**cols)


def weighted_percentile(values: np.ndarray, weights: np.ndarray,
                        q: float) -> float:
    """Weighted percentile (q in [0, 100]) via the cumulative-weight
    inverse CDF; -1 when empty (matching ``latency_stats``)."""
    if len(values) == 0:
        return -1.0
    order = np.argsort(values)
    v, w = np.asarray(values)[order], np.asarray(weights)[order]
    cum = np.cumsum(w)
    return float(np.interp(q / 100.0 * cum[-1], cum, v))
