"""Workload generation: Poisson arrivals, Zipf request lengths, P:D split.

Matches the paper's Table 1 parameterization: request lengths drawn from
a Zipf distribution over [min_len, max_len] (theta=0.6 in the
integration case study), arrivals Poisson at a configured QPS, and a
prefill:decode token-ratio knob.

Workload classes (``repro.schedule``): a configurable fraction of
requests is tagged ``deferrable`` — batch-style work (evals, embedding
jobs, summarization queues) that tolerates delay up to a per-request
deadline. The rest stay ``interactive`` with a TTFT SLO. Class tags are
drawn *after* the arrival/length streams, so a workload with
``deferrable_frac=0`` is bit-identical to one generated before classes
existed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

INTERACTIVE = "interactive"
DEFERRABLE = "deferrable"


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prefill_tokens: int
    decode_tokens: int
    # workload class (repro.schedule): interactive requests carry a TTFT
    # SLO; deferrable requests carry an absolute completion deadline and
    # may be parked by an admission policy until release_s
    klass: str = INTERACTIVE
    slo_s: float = math.inf           # TTFT SLO (interactive)
    deadline_s: float = math.inf      # absolute completion deadline
    release_s: float = -1.0           # admission release time (<0 = arrival)
    # runtime state
    decoded: int = 0
    prefilled: bool = False
    prefill_done: int = 0        # prompt tokens prefilled so far (chunking)
    t_first_token: float = -1.0
    t_done: float = -1.0

    @property
    def ready_s(self) -> float:
        """When the request becomes visible to routing: its admission
        release time if an admission policy parked it, else arrival."""
        return self.release_s if self.release_s >= 0 else self.arrival_s


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 1024
    qps: float = 6.45
    arrival: str = "poisson"          # poisson | uniform
    length_dist: str = "zipf"         # zipf | fixed
    zipf_theta: float = 0.6
    min_len: int = 128
    max_len: int = 4096
    pd_ratio: float = 20.0            # prefill:decode token ratio
    seed: int = 0
    # workload classes (repro.schedule): fraction of requests tagged
    # deferrable, their relative completion deadline, and the TTFT SLO
    # attached to the interactive class
    deferrable_frac: float = 0.0
    deferrable_deadline_s: float = 3600.0
    interactive_slo_s: float = 30.0
    # day-scale rate modulation (repro.workloads): a diurnal envelope
    # over the mean qps plus an MMPP-style burst overlay. The defaults
    # (envelope "none", gain 1.0) keep the legacy constant-rate stream
    # bit-for-bit, pinned by tests/test_workloads.py
    envelope: str = "none"            # none | sinusoidal | diurnal
    envelope_amplitude: float = 0.35
    envelope_period_h: float = 24.0
    envelope_phase_h: float = 0.0
    burst_gain: float = 1.0           # rate multiplier during bursts
    burst_mean_s: float = 0.0         # mean burst duration (0 = off)
    burst_idle_mean_s: float = 3600.0  # mean gap between bursts


def zipf_lengths(rng, n: int, theta: float, lo: int, hi: int) -> np.ndarray:
    support = np.arange(lo, hi + 1, dtype=np.float64)
    probs = support ** (-theta)
    probs /= probs.sum()
    return rng.choice(support, size=n, p=probs).astype(int)


def generate(cfg: WorkloadConfig) -> List[Request]:
    """Materialized request list; arrival placement, length draws and
    class tags live in ``repro.workloads.stream.generate_stream`` (the
    array-native form day-scale simulations consume directly)."""
    from repro.workloads.stream import generate_stream
    return generate_stream(cfg).to_requests()
