"""Workload generation: Poisson arrivals, Zipf request lengths, P:D split.

Matches the paper's Table 1 parameterization: request lengths drawn from
a Zipf distribution over [min_len, max_len] (theta=0.6 in the
integration case study), arrivals Poisson at a configured QPS, and a
prefill:decode token-ratio knob.

Workload classes (``repro.schedule``): a configurable fraction of
requests is tagged ``deferrable`` — batch-style work (evals, embedding
jobs, summarization queues) that tolerates delay up to a per-request
deadline. The rest stay ``interactive`` with a TTFT SLO. Class tags are
drawn *after* the arrival/length streams, so a workload with
``deferrable_frac=0`` is bit-identical to one generated before classes
existed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

INTERACTIVE = "interactive"
DEFERRABLE = "deferrable"


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prefill_tokens: int
    decode_tokens: int
    # workload class (repro.schedule): interactive requests carry a TTFT
    # SLO; deferrable requests carry an absolute completion deadline and
    # may be parked by an admission policy until release_s
    klass: str = INTERACTIVE
    slo_s: float = math.inf           # TTFT SLO (interactive)
    deadline_s: float = math.inf      # absolute completion deadline
    release_s: float = -1.0           # admission release time (<0 = arrival)
    # runtime state
    decoded: int = 0
    prefilled: bool = False
    prefill_done: int = 0        # prompt tokens prefilled so far (chunking)
    t_first_token: float = -1.0
    t_done: float = -1.0

    @property
    def ready_s(self) -> float:
        """When the request becomes visible to routing: its admission
        release time if an admission policy parked it, else arrival."""
        return self.release_s if self.release_s >= 0 else self.arrival_s


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 1024
    qps: float = 6.45
    arrival: str = "poisson"          # poisson | uniform
    length_dist: str = "zipf"         # zipf | fixed
    zipf_theta: float = 0.6
    min_len: int = 128
    max_len: int = 4096
    pd_ratio: float = 20.0            # prefill:decode token ratio
    seed: int = 0
    # workload classes (repro.schedule): fraction of requests tagged
    # deferrable, their relative completion deadline, and the TTFT SLO
    # attached to the interactive class
    deferrable_frac: float = 0.0
    deferrable_deadline_s: float = 3600.0
    interactive_slo_s: float = 30.0


def zipf_lengths(rng, n: int, theta: float, lo: int, hi: int) -> np.ndarray:
    support = np.arange(lo, hi + 1, dtype=np.float64)
    probs = support ** (-theta)
    probs /= probs.sum()
    return rng.choice(support, size=n, p=probs).astype(int)


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / max(cfg.qps, 1e-9), cfg.n_requests)
    else:
        gaps = np.full(cfg.n_requests, 1.0 / max(cfg.qps, 1e-9))
    arrivals = np.cumsum(gaps)
    if cfg.length_dist == "zipf":
        lengths = zipf_lengths(rng, cfg.n_requests, cfg.zipf_theta,
                               cfg.min_len, cfg.max_len)
    else:
        lengths = np.full(cfg.n_requests, cfg.max_len, int)
    # split each request's tokens by the P:D ratio
    pf = cfg.pd_ratio / (cfg.pd_ratio + 1.0)
    prefills = np.maximum(1, np.round(lengths * pf)).astype(int)
    decodes = np.maximum(1, lengths - prefills).astype(int)
    # class tags draw AFTER the arrival/length streams: frac=0 consumes
    # no randomness and reproduces the pre-class workload bit-for-bit
    if cfg.deferrable_frac > 0.0:
        deferrable = rng.random(cfg.n_requests) < cfg.deferrable_frac
    else:
        deferrable = np.zeros(cfg.n_requests, bool)
    out = []
    for i in range(cfg.n_requests):
        if deferrable[i]:
            out.append(Request(
                rid=i, arrival_s=float(arrivals[i]),
                prefill_tokens=int(prefills[i]),
                decode_tokens=int(decodes[i]), klass=DEFERRABLE,
                deadline_s=float(arrivals[i]) + cfg.deferrable_deadline_s))
        else:
            out.append(Request(
                rid=i, arrival_s=float(arrivals[i]),
                prefill_tokens=int(prefills[i]),
                decode_tokens=int(decodes[i]), klass=INTERACTIVE,
                slo_s=cfg.interactive_slo_s))
    return out
