"""Workload generation: Poisson arrivals, Zipf request lengths, P:D split.

Matches the paper's Table 1 parameterization: request lengths drawn from
a Zipf distribution over [min_len, max_len] (theta=0.6 in the
integration case study), arrivals Poisson at a configured QPS, and a
prefill:decode token-ratio knob.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prefill_tokens: int
    decode_tokens: int
    # runtime state
    decoded: int = 0
    prefilled: bool = False
    prefill_done: int = 0        # prompt tokens prefilled so far (chunking)
    t_first_token: float = -1.0
    t_done: float = -1.0


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 1024
    qps: float = 6.45
    arrival: str = "poisson"          # poisson | uniform
    length_dist: str = "zipf"         # zipf | fixed
    zipf_theta: float = 0.6
    min_len: int = 128
    max_len: int = 4096
    pd_ratio: float = 20.0            # prefill:decode token ratio
    seed: int = 0


def zipf_lengths(rng, n: int, theta: float, lo: int, hi: int) -> np.ndarray:
    support = np.arange(lo, hi + 1, dtype=np.float64)
    probs = support ** (-theta)
    probs /= probs.sum()
    return rng.choice(support, size=n, p=probs).astype(int)


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / max(cfg.qps, 1e-9), cfg.n_requests)
    else:
        gaps = np.full(cfg.n_requests, 1.0 / max(cfg.qps, 1e-9))
    arrivals = np.cumsum(gaps)
    if cfg.length_dist == "zipf":
        lengths = zipf_lengths(rng, cfg.n_requests, cfg.zipf_theta,
                               cfg.min_len, cfg.max_len)
    else:
        lengths = np.full(cfg.n_requests, cfg.max_len, int)
    # split each request's tokens by the P:D ratio
    pf = cfg.pd_ratio / (cfg.pd_ratio + 1.0)
    prefills = np.maximum(1, np.round(lengths * pf)).astype(int)
    decodes = np.maximum(1, lengths - prefills).astype(int)
    return [Request(rid=i, arrival_s=float(arrivals[i]),
                    prefill_tokens=int(prefills[i]),
                    decode_tokens=int(decodes[i]))
            for i in range(cfg.n_requests)]
