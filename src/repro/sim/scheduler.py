"""vLLM-style continuous-batching scheduler.

Each replica runs iterations ("batch stages"):
  - waiting prompts are admitted FCFS while the running set < batch_cap
    and the KV budget holds;
  - admitted prompts are prefilled (batched prefill iteration), possibly
    chunked (Sarathi-style) when ``chunk_prefill`` is set;
  - otherwise all running sequences decode one token per iteration.

This reproduces Vidur's replica_scheduler=vllm behavior at the fidelity
the energy model needs: batch composition + stage boundaries.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.sim.requests import Request


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    batch_cap: int = 128              # max running sequences
    max_tokens: int = 4096            # max model len (prompt + gen)
    kv_budget_tokens: int = 512 * 1024  # per-replica KV token capacity
    chunk_prefill: Optional[int] = None  # Sarathi chunk size, None = whole

    def __post_init__(self):
        if self.chunk_prefill is not None and self.chunk_prefill < 1:
            raise ValueError(
                f"chunk_prefill must be None or >= 1, "
                f"got {self.chunk_prefill}")


class ReplicaScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.kv_tokens = 0
        # prefill token counts of the batch returned by the last
        # next_batch() call, aligned with its prefills list (== full
        # prompt lengths when chunking is off), and the per-request
        # offsets of already-prefilled prompt tokens (nonzero only for
        # Sarathi chunk continuations — the exec model charges their
        # cross-chunk KV reads)
        self.last_prefill_tokens: List[int] = []
        self.last_prefill_offsets: List[int] = []
        self._chunk_by_rid: dict = {}

    def add(self, req: Request):
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _admit(self):
        while (self.waiting
               and len(self.running) < self.cfg.batch_cap
               and self.kv_tokens + self.waiting[0].prefill_tokens
               <= self.cfg.kv_budget_tokens):
            r = self.waiting.popleft()
            self.running.append(r)
            self.kv_tokens += r.prefill_tokens

    def next_batch(self) -> Tuple[List[Request], List[Request]]:
        """Returns (prefills, decodes) for the next iteration.

        The per-request prefill token counts of the returned batch are
        exposed as ``self.last_prefill_tokens`` (chunking makes them
        differ from the full prompt lengths).

        Without chunking: prefill-only iterations take priority, then
        decode-only iterations (the seed/vLLM behavior). With
        ``chunk_prefill=C`` (Sarathi-style): each iteration carries at
        most C prompt tokens of prefill work, coalesced with one decode
        token for every already-prefilled running sequence.
        """
        self._admit()
        if self.cfg.chunk_prefill is None:
            prefills = [r for r in self.running if not r.prefilled]
            if prefills:
                self.last_prefill_tokens = [r.prefill_tokens
                                            for r in prefills]
                self.last_prefill_offsets = [r.prefill_done
                                             for r in prefills]
                self._chunk_by_rid = {r.rid: r.prefill_tokens
                                      for r in prefills}
                return prefills, []
            self.last_prefill_tokens = []
            self.last_prefill_offsets = []
            self._chunk_by_rid = {}
            decodes = [r for r in self.running
                       if r.decoded < r.decode_tokens]
            return [], decodes

        budget = self.cfg.chunk_prefill
        prefills: List[Request] = []
        chunks: List[int] = []
        for r in self.running:
            if budget <= 0:
                break
            if not r.prefilled:
                take = min(budget, r.prefill_tokens - r.prefill_done)
                prefills.append(r)
                chunks.append(take)
                budget -= take
        decodes = [r for r in self.running
                   if r.prefilled and r.decoded < r.decode_tokens]
        self.last_prefill_tokens = chunks
        self.last_prefill_offsets = [r.prefill_done for r in prefills]
        self._chunk_by_rid = {r.rid: c for r, c in zip(prefills, chunks)}
        return prefills, decodes

    def complete_iteration(self, prefills: List[Request],
                           decodes: List[Request], now: float):
        # chunk sizes are attributed per request id; anything not in
        # the last next_batch() (direct API use, retries) advances by
        # its full remaining prompt
        chunk_by_rid = self._chunk_by_rid
        self._chunk_by_rid = {}
        for r in prefills:
            took = chunk_by_rid.get(r.rid,
                                    r.prefill_tokens - r.prefill_done)
            r.prefill_done += took
            if r.prefill_done >= r.prefill_tokens:
                r.prefilled = True
                if r.t_first_token < 0:
                    r.t_first_token = now
        done = []
        for r in decodes:
            r.decoded += 1
            self.kv_tokens += 1
            if r.decoded >= r.decode_tokens:
                r.t_done = now
                done.append(r)
        for r in done:
            self.running.remove(r)
            self.kv_tokens -= r.prefill_tokens + r.decoded
        return done


def __getattr__(name):
    # RoundRobinRouter moved to the routing layer (repro.fleet.routing);
    # resolved lazily here to keep the historical import path working
    # without a circular import at module load.
    if name == "RoundRobinRouter":
        from repro.fleet.routing import RoundRobinRouter
        return RoundRobinRouter
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
