"""vLLM-style continuous-batching scheduler + round-robin replica router.

Each replica runs iterations ("batch stages"):
  - waiting prompts are admitted FCFS while the running set < batch_cap
    and the KV budget holds;
  - admitted prompts are prefilled (batched prefill iteration), possibly
    chunked (Sarathi-style) when ``chunk_prefill`` is set;
  - otherwise all running sequences decode one token per iteration.

This reproduces Vidur's replica_scheduler=vllm behavior at the fidelity
the energy model needs: batch composition + stage boundaries.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.sim.requests import Request


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    batch_cap: int = 128              # max running sequences
    max_tokens: int = 4096            # max model len (prompt + gen)
    kv_budget_tokens: int = 512 * 1024  # per-replica KV token capacity
    chunk_prefill: Optional[int] = None  # Sarathi chunk size, None = whole


class ReplicaScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.kv_tokens = 0

    def add(self, req: Request):
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _admit(self):
        while (self.waiting
               and len(self.running) < self.cfg.batch_cap
               and self.kv_tokens + self.waiting[0].prefill_tokens
               <= self.cfg.kv_budget_tokens):
            r = self.waiting.popleft()
            self.running.append(r)
            self.kv_tokens += r.prefill_tokens

    def next_batch(self) -> Tuple[List[Request], List[Request]]:
        """Returns (prefills, decodes) for the next iteration."""
        self._admit()
        prefills = [r for r in self.running if not r.prefilled]
        if prefills:
            return prefills, []
        decodes = [r for r in self.running if r.decoded < r.decode_tokens]
        return [], decodes

    def complete_iteration(self, prefills: List[Request],
                           decodes: List[Request], now: float):
        for r in prefills:
            r.prefilled = True
            if r.t_first_token < 0:
                r.t_first_token = now
        done = []
        for r in decodes:
            r.decoded += 1
            self.kv_tokens += 1
            if r.decoded >= r.decode_tokens:
                r.t_done = now
                done.append(r)
        for r in done:
            self.running.remove(r)
            self.kv_tokens -= r.prefill_tokens + r.decoded
        return done


class RoundRobinRouter:
    def __init__(self, n_replicas: int, cfg: SchedulerConfig):
        self.replicas = [ReplicaScheduler(cfg) for _ in range(n_replicas)]
        self._next = 0

    def route(self, req: Request):
        self.replicas[self._next].add(req)
        self._next = (self._next + 1) % len(self.replicas)
