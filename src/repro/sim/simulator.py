"""Event-driven cluster simulator (the Vidur analogue).

Per replica: continuous-batching iterations timed by the analytical
roofline execution model; every batch stage is logged with its start,
duration, FLOPs split (MLP vs attention) and MFU — exactly the
granularity the paper's Eq. 2-3 energy accounting consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.power import DeviceProfile, PowerModel, DEVICES
from repro.sim.execmodel import ExecModelConfig, cached_execution_model
from repro.sim.requests import Request, WorkloadConfig, generate
from repro.sim.scheduler import SchedulerConfig
from repro.sim.trace import StageTrace

# the stage log became the array-native StageTrace (repro.sim.trace);
# the historical name keeps working for existing callers
StageLog = StageTrace


def kv_budget_tokens(model: ModelConfig, device: DeviceProfile, tp: int,
                     pp: int, mem_frac: float = 0.9,
                     weight_bytes: int = 2) -> int:
    """KV token capacity per replica given device memory: the paper's
    large-model cases (34B on one A100-80GB) are KV-constrained to tiny
    batches, which is what drives their low average power."""
    w_per_gpu = model.param_count() * weight_bytes / (tp * pp)
    room = device.hbm_bytes * mem_frac - w_per_gpu
    kv_per_gpu = model.kv_bytes_per_token() / (tp * pp)
    if room <= 0 or kv_per_gpu <= 0:
        return 0
    return int(room / kv_per_gpu)


def latency_stats(requests) -> Dict[str, float]:
    """TTFT / end-to-end percentiles over served requests (-1 when a
    percentile has no samples). Shared by single-site and fleet
    reports."""
    ttft = [r.t_first_token - r.arrival_s for r in requests
            if r.t_first_token >= 0]
    e2e = [r.t_done - r.arrival_s for r in requests if r.t_done >= 0]
    return {
        "ttft_p50_s": float(np.median(ttft)) if ttft else -1.0,
        "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft else -1.0,
        "e2e_p50_s": float(np.median(e2e)) if e2e else -1.0,
        "e2e_p99_s": float(np.percentile(e2e, 99)) if e2e else -1.0,
    }


@dataclasses.dataclass
class SimConfig:
    model: ModelConfig
    device: str = "a100"
    n_replicas: int = 1
    tp: int = 1
    pp: int = 1
    workload: WorkloadConfig = dataclasses.field(default_factory=WorkloadConfig)
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    execmodel: ExecModelConfig = dataclasses.field(default_factory=ExecModelConfig)
    auto_kv_budget: bool = True

    @property
    def n_devices(self) -> int:
        return self.n_replicas * self.tp * self.pp  # G = R * TP * PP (Eq. 2)


@dataclasses.dataclass
class SimResult:
    stages: StageTrace
    requests: List[Request]
    cfg: SimConfig

    # ---- derived metrics ----
    def throughput_qps(self) -> float:
        done = [r for r in self.requests if r.t_done >= 0]
        if not done:
            return 0.0
        return len(done) / max(self.stages.total_duration(), 1e-9)

    def latency_stats(self) -> Dict[str, float]:
        return latency_stats(self.requests)

    def avg_mfu(self) -> float:
        if len(self.stages.dur_s) == 0:
            return 0.0
        return float(np.sum(self.stages.mfu * self.stages.dur_s)
                     / max(self.stages.dur_s.sum(), 1e-12))


def run_simulation(cfg: SimConfig, max_sim_s: float = 10_000_000.0,
                   router=None, probe=None) -> SimResult:
    """Single-site simulation — the trivial fleet.

    The event loop lives in ``repro.fleet.simulation.drive``; this
    drives one ``LoopSite`` over it. ``router`` injects a pre-built
    replica router (anything exposing ``route(req) -> replica index``
    and a ``replicas`` list of ``ReplicaScheduler``); when injected,
    the caller owns scheduler config resolution (``auto_kv_budget`` is
    not applied). Default: round-robin over ``cfg.n_replicas`` fresh
    replicas, the historical behavior. ``probe`` (``repro.obs.Probe``)
    observes stage commits and routing; probe-off is bitwise identical.
    """
    from repro.fleet.simulation import LoopSite, drive

    requests = generate(cfg.workload)
    device = DEVICES[cfg.device]
    if router is None:
        from repro.fleet.routing import RoundRobinRouter
        sched_cfg = cfg.scheduler
        if cfg.auto_kv_budget:
            budget = kv_budget_tokens(cfg.model, device, cfg.tp, cfg.pp)
            if budget <= 0:
                raise ValueError(
                    f"{cfg.model.name} does not fit {cfg.device} at "
                    f"TP={cfg.tp} PP={cfg.pp}")
            import dataclasses as _dc
            sched_cfg = _dc.replace(sched_cfg, kv_budget_tokens=budget)
        router = RoundRobinRouter(cfg.n_replicas, sched_cfg)
    site = LoopSite(router, cached_execution_model(cfg.model, cfg.device,
                                                   cfg.tp, cfg.pp,
                                                   cfg.execmodel), cfg.pp)
    add = site.add
    if probe is not None:
        site.probe = probe

        def add(req):
            probe.on_route(req.ready_s, req.rid, 0)
            site.add(req)
    drive([site], add, requests, max_sim_s, probe=probe)
    if probe is not None:
        probe.on_requests(
            np.asarray([r.arrival_s for r in requests], np.float64),
            np.asarray([r.ready_s for r in requests], np.float64))
    return SimResult(stages=site.stage_log(), requests=requests, cfg=cfg)


def energy_report(res: SimResult, pue: float = 1.2):
    """Paper Eq. 2-3 over the simulation's stage trace."""
    from repro.core.energy import operational_energy_trace
    pm = PowerModel(res.cfg.device)
    return operational_energy_trace(res.stages, pm,
                                    n_devices=res.cfg.n_devices, pue=pue)
