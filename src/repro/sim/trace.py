"""Array-native stage traces.

``StageTrace`` is the structured log the event loop produces: one row
per (replica, pipeline-stage) iteration, stored as flat numpy arrays so
the energy (Eq. 2-3), carbon (Eq. 4) and co-sim (Eq. 5) accounting run
as single array passes — and so a whole trace can be re-costed through
``ExecutionModel.stage_cost_batch`` without replaying the loop.

``StageTraceBuilder`` accumulates rows into one preallocated, doubling
2-D buffer (no per-stage Python object lists); ``build()`` slices it
into the typed trace.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# column order of the builder buffer
_FIELDS = ("start_s", "dur_s", "flops_mlp", "flops_attn", "mfu",
           "n_prefill_tokens", "n_decode_tokens", "replica", "batch_size",
           "score_flops", "kv_rw_bytes")
# columns that are semantically integer counts/ids
_INT_FIELDS = frozenset({"n_prefill_tokens", "n_decode_tokens", "replica",
                         "batch_size"})


@dataclasses.dataclass
class StageTrace:
    """Batch-stage log of one deployment (or one fleet site).

    The first block of fields is the paper's Eq. 2-3 granularity
    (timing, FLOPs split, MFU); ``score_flops`` / ``kv_rw_bytes`` are
    the stage's batch-composition aggregates (``StageBatch``), kept so
    the roofline is replayable from the trace alone.
    """
    start_s: np.ndarray
    dur_s: np.ndarray
    flops_mlp: np.ndarray
    flops_attn: np.ndarray
    mfu: np.ndarray
    n_prefill_tokens: np.ndarray
    n_decode_tokens: np.ndarray
    replica: np.ndarray
    batch_size: np.ndarray
    score_flops: np.ndarray
    kv_rw_bytes: np.ndarray

    def __post_init__(self):
        n = len(self.start_s)
        for f in dataclasses.fields(self):
            if len(getattr(self, f.name)) != n:
                raise ValueError(
                    f"StageTrace columns must align: {f.name} has "
                    f"{len(getattr(self, f.name))} rows, start_s has {n}")

    def __len__(self) -> int:
        return len(self.start_s)

    def total_duration(self) -> float:
        if len(self.start_s) == 0:
            return 0.0
        return float((self.start_s + self.dur_s).max())

    def iteration_rows(self, pp: int) -> "StageTrace":
        """One row per scheduler iteration.

        The event loop logs ``pp`` rows per iteration (one per
        pipeline stage) sharing the same batch composition, so rows
        ``0, pp, 2*pp, ...`` carry the iteration-level columns. The
        sweep's trace-divergence analysis compares composition across
        device/TP/PP grid points through this view (timing columns
        still differ — only composition is parallelism-invariant).
        """
        if pp <= 1:
            return self
        if len(self) % pp:
            raise ValueError(
                f"trace length {len(self)} is not a multiple of pp={pp}")
        return StageTrace(**{f.name: getattr(self, f.name)[::pp]
                             for f in dataclasses.fields(StageTrace)})


class StageTraceBuilder:
    """Row accumulator over a preallocated (capacity, n_fields) buffer
    that doubles on overflow — the event loop appends scalars, the
    arrays come out columnar."""

    def __init__(self, capacity: int = 1024):
        self._buf = np.empty((max(capacity, 16), len(_FIELDS)), np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def append(self, start_s: float, dur_s: float, flops_mlp: float,
               flops_attn: float, mfu: float, n_prefill_tokens: float,
               n_decode_tokens: float, replica: float, batch_size: float,
               score_flops: float, kv_rw_bytes: float) -> None:
        if self._n == len(self._buf):
            grown = np.empty((2 * len(self._buf), len(_FIELDS)), np.float64)
            grown[:self._n] = self._buf
            self._buf = grown
        self._buf[self._n] = (start_s, dur_s, flops_mlp, flops_attn, mfu,
                              n_prefill_tokens, n_decode_tokens, replica,
                              batch_size, score_flops, kv_rw_bytes)
        self._n += 1

    def build(self) -> StageTrace:
        cols = {}
        for j, name in enumerate(_FIELDS):
            col = self._buf[:self._n, j].copy()
            cols[name] = col.astype(np.int64) if name in _INT_FIELDS else col
        return StageTrace(**cols)
