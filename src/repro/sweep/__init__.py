"""Scenario sweep engine: declarative grids over SimConfig, parallel
execution with on-disk result memoization, tidy CSV/JSON reporting, and
the paper's seven experiments as predefined sweeps (``repro.sweep.cli``).
"""
from repro.sweep.cache import ResultCache, default_cache_root
from repro.sweep.grid import (DEFAULT_GRID_CI, SCHEMA_VERSION, GridSpec,
                              Scenario, config_digest, derive_seed,
                              model_registry, with_overrides)
from repro.sweep.report import (flatten, format_rows, format_table, to_csv,
                                to_json, write_outputs)
from repro.sweep.remote import (RemoteCoordinator, RemoteOptions,
                                RemoteStats, pack_shards)
from repro.sweep.runner import (BACKENDS, EXECUTION_MODES, POSTPROCESSORS,
                                SweepRunner, SweepStats, execute_scenario,
                                run_scenarios)
from repro.sweep.scenarios import SWEEPS, SweepDef, run_sweep
from repro.sweep.vectorized import (estimate_group_cost,
                                    estimate_trace_cost,
                                    execute_scenario_group, group_by_trace)

__all__ = [
    "ResultCache", "default_cache_root",
    "DEFAULT_GRID_CI", "SCHEMA_VERSION", "GridSpec", "Scenario",
    "config_digest", "derive_seed", "model_registry", "with_overrides",
    "flatten", "format_rows", "format_table", "to_csv", "to_json",
    "write_outputs",
    "RemoteCoordinator", "RemoteOptions", "RemoteStats", "pack_shards",
    "BACKENDS", "EXECUTION_MODES", "POSTPROCESSORS", "SweepRunner",
    "SweepStats", "execute_scenario", "run_scenarios",
    "SWEEPS", "SweepDef", "run_sweep",
    "estimate_group_cost", "estimate_trace_cost",
    "execute_scenario_group", "group_by_trace",
]
