"""On-disk content-addressed cache of completed scenario records.

Keyed by ``Scenario.key`` (sha256 of the full config tree + runner
knobs + schema version, see ``grid.config_digest``), so a cache entry
is valid exactly as long as the scenario it describes is byte-identical.
Layout: ``<root>/<key[:2]>/<key>.json`` — one JSON record per scenario.
Writes are atomic (tmp file + rename) so parallel workers and
interrupted runs never leave a torn entry behind.

Reads and writes are additionally memoized in-process (bounded LRU):
repeated sweeps over overlapping grids in one process — the benchmark
harness, notebook loops, long-lived remote workers — skip the
open+parse per hit, and eviction drops the least-recently-touched
entry so hot keys stay resident past the cap. The on-disk entry stays
authoritative; the memo only ever holds records this process itself
read or wrote.
"""
from __future__ import annotations

import json
import os
import tempfile
from collections import OrderedDict
from pathlib import Path
from typing import Iterator, Optional

ENV_CACHE_DIR = "REPRO_SWEEP_CACHE"
DEFAULT_CACHE_DIR = Path("results") / "sweep_cache"


def default_cache_root() -> Path:
    return Path(os.environ.get(ENV_CACHE_DIR, DEFAULT_CACHE_DIR))


class ResultCache:
    _MEMO_CAP = 65536       # bound in-process memory, not correctness

    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root is not None else default_cache_root()
        self._memo: OrderedDict = OrderedDict()
        # cumulative effectiveness counters (process lifetime): hits
        # served from the in-process memo vs parsed off disk vs misses.
        # The sweep runner snapshots deltas per run for its summary.
        self.counters = {"memo": 0, "disk": 0, "miss": 0}

    def path_for(self, key: str) -> Path:
        return self.root / key[:2] / f"{key}.json"

    def get(self, key: str) -> Optional[dict]:
        memo = self._memo.get(key)
        if memo is not None:
            self._memo.move_to_end(key)
            self.counters["memo"] += 1
            return memo
        path = self.path_for(key)
        try:
            with open(path) as f:
                record = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            self.counters["miss"] += 1
            return None
        if record.get("key") != key:        # corrupt/foreign entry
            self.counters["miss"] += 1
            return None
        self._remember(key, record)
        self.counters["disk"] += 1
        return record

    def _remember(self, key: str, record: dict) -> None:
        if key in self._memo:
            self._memo.move_to_end(key)
        elif len(self._memo) >= self._MEMO_CAP:
            self._memo.popitem(last=False)   # evict least-recently-used
        self._memo[key] = record

    def put(self, key: str, record: dict) -> Path:
        path = self.path_for(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(record, f, default=str)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._remember(key, record)
        return path

    def iter_keys(self) -> Iterator[str]:
        if not self.root.exists():
            return
        for sub in sorted(self.root.iterdir()):
            if sub.is_dir():
                for entry in sorted(sub.glob("*.json")):
                    yield entry.stem

    def __len__(self) -> int:
        return sum(1 for _ in self.iter_keys())

    def clear(self) -> int:
        self._memo.clear()
        n = 0
        for key in list(self.iter_keys()):
            self.path_for(key).unlink(missing_ok=True)
            n += 1
        return n
