"""Unified benchmark CLI over the scenario sweep engine.

Examples:

    # every figure's pipeline at smoke scale (what CI runs)
    PYTHONPATH=src python -m repro.sweep.cli --smoke all

    # full Table 2 co-simulation, memoized — a repeat run is served
    # from the cache and executes zero scenarios
    PYTHONPATH=src python -m repro.sweep.cli table2

    # fig4 across 4 worker processes, custom output dir
    PYTHONPATH=src python -m repro.sweep.cli fig4 --workers 4 --out results/sweep
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.obs.log import configure as configure_logging
from repro.obs.log import get_logger
from repro.obs.spans import PROFILER
from repro.sweep.cache import ResultCache, default_cache_root
from repro.sweep.report import format_table, write_outputs
from repro.sweep.scenarios import SWEEPS, run_sweep

_log = get_logger("repro.sweep")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.sweep.cli",
        description="Run the paper's scenario sweeps through the "
                    "parallel, cache-memoized sweep engine.")
    p.add_argument("sweeps", nargs="*", metavar="SWEEP",
                   help=f"sweep names ({', '.join(SWEEPS)}) or 'all'")
    p.add_argument("--smoke", action="store_true",
                   help="tiny request counts + coarse grids (CI mode)")
    p.add_argument("--n-requests", type=int, default=None,
                   help="override per-scenario request count")
    p.add_argument("--workers", type=int, default=1,
                   help="scenario-level process parallelism (default 1)")
    p.add_argument("--mode", choices=("vectorized", "event_loop", "device"),
                   default="vectorized",
                   help="vectorized: one event-loop run per unique "
                        "config, shared-trace axes (pue/grid_ci/post.*) "
                        "evaluated as stacked array passes; event_loop: "
                        "every scenario through the loop (bit-identical "
                        "results either way); device: one batched jax "
                        "program over all trace groups at once, sharing "
                        "composition traces across device/tp/pp points "
                        "where divergence analysis proves it safe "
                        "(equivalent within a documented ulp-level "
                        "tolerance, see repro.sweep.device)")
    p.add_argument("--backend", choices=("local", "remote"),
                   default="local",
                   help="local: execute in this process (pool); "
                        "remote: publish trace-group shards to a "
                        "shared-filesystem work queue for detached "
                        "repro.sweep.worker processes (requires the "
                        "cache; see repro.sweep.remote)")
    p.add_argument("--remote-workers", type=int, default=2,
                   help="convenience worker processes the coordinator "
                        "spawns on this host (default 2; 0 = rely on "
                        "externally launched workers)")
    p.add_argument("--queue-dir", type=Path, default=None,
                   help="work-queue directory shared with workers "
                        "(default <cache>/.queue)")
    p.add_argument("--lease-s", type=float, default=30.0,
                   help="shard lease: a claim whose heartbeat is "
                        "staler than this is reclaimed and retried "
                        "(default 30)")
    p.add_argument("--remote-verify", type=int, default=0, metavar="N",
                   help="re-run N trace groups serially in-process and "
                        "assert the remote records are bit-identical")
    p.add_argument("--no-cache", action="store_true",
                   help="disable the on-disk result cache")
    p.add_argument("--cache-dir", type=Path, default=None,
                   help=f"cache root (default {default_cache_root()}, "
                        f"or $REPRO_SWEEP_CACHE)")
    p.add_argument("--clear-cache", action="store_true",
                   help="drop all cached scenario results, then proceed")
    p.add_argument("--out", type=Path, default=Path("results") / "sweep",
                   help="directory for per-sweep CSV/JSON tables")
    p.add_argument("--list", action="store_true", dest="list_sweeps",
                   help="list available sweeps and exit")
    p.add_argument("--quiet", action="store_true",
                   help="suppress per-scenario tables and progress logs")
    p.add_argument("-v", "--verbose", action="count", default=0,
                   help="raise progress-log verbosity (stderr)")
    p.add_argument("--profile", action="store_true",
                   help="profile the sweep pipeline's wall-clock "
                        "phases; per-phase totals print to stderr")
    p.add_argument("--trace-out", type=Path, default=None,
                   help="record a dual-clock Perfetto trace (sim-time "
                        "flight recorder + wall-clock spans) to this "
                        "Chrome trace-event JSON path; forces serial "
                        "execution and is rejected in device mode")
    p.add_argument("--obs-resolution", type=float, default=60.0,
                   help="flight-recorder timeline bin width in sim "
                        "seconds (default 60; observer-only)")
    p.add_argument("--audit", action="store_true",
                   help="attach the physics-invariant auditor "
                        "(repro.obs.audit) to every executed scenario: "
                        "conservation, Eq. 2-5 closure, KV/clock/power "
                        "invariants. Observer-only (results stay "
                        "bitwise identical); violations write a report "
                        "under results/obs/divergence/ and exit 1. "
                        "Forces serial execution; rejected in device "
                        "mode. Stackable with --trace-out")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    configure_logging(verbosity=(-1 if args.quiet else args.verbose))

    if args.list_sweeps:
        for name, sweep in SWEEPS.items():
            n = len(sweep.build(args.smoke, n_requests=args.n_requests))
            print(f"{name:8s} {n:3d} scenario(s)  {sweep.title}")
        return 0

    names = list(args.sweeps)
    if not names:
        print("no sweeps given (use names or 'all'); --list shows options",
              file=sys.stderr)
        return 2
    if names == ["all"]:
        names = list(SWEEPS)
    unknown = [n for n in names if n not in SWEEPS]
    if unknown:
        print(f"unknown sweep(s): {', '.join(unknown)}; "
              f"available: {', '.join(SWEEPS)}", file=sys.stderr)
        return 2

    cache = None if args.no_cache else ResultCache(args.cache_dir)
    if args.clear_cache and cache is not None:
        print(f"cleared {cache.clear()} cached scenario(s)")

    remote_opts = None
    if args.backend == "remote":
        if cache is None:
            print("--backend remote requires the result cache "
                  "(workers return records through it); drop --no-cache",
                  file=sys.stderr)
            return 2
        from repro.sweep.remote import RemoteOptions
        remote_opts = RemoteOptions(
            queue_dir=args.queue_dir,
            spawn_workers=max(0, args.remote_workers),
            lease_s=args.lease_s,
            verify_groups=max(0, args.remote_verify))

    probe = recorder = auditor = None
    if args.trace_out is not None:
        from repro.obs.recorder import FlightRecorder
        recorder = FlightRecorder(resolution_s=args.obs_resolution)
        probe = recorder
    if args.audit:
        from repro.obs.audit import AuditProbe
        auditor = AuditProbe()
        if recorder is not None:
            from repro.obs.probe import MultiProbe
            probe = MultiProbe([recorder, auditor])
        else:
            probe = auditor
    if args.profile or probe is not None:
        PROFILER.enable(reset=True)

    failed = []
    for name in names:
        t0 = time.perf_counter()
        print(f"== {name}: {SWEEPS[name].title}"
              + (" [smoke]" if args.smoke else ""))
        try:
            records, stats, derived = run_sweep(
                name, smoke=args.smoke, n_requests=args.n_requests,
                workers=args.workers, cache=cache, mode=args.mode,
                probe=probe, backend=args.backend, remote=remote_opts,
                progress=lambda msg: _log.info("%s", msg))
        except Exception as exc:           # keep sweeping, report at exit
            failed.append(name)
            print(f"   FAILED: {type(exc).__name__}: {exc}",
                  file=sys.stderr)
            continue
        paths = write_outputs(name, records, args.out, derived=derived)
        if not args.quiet:
            print(format_table(records))
        print(f"   {stats.summary()}")
        print(f"   derived: {derived}")
        if auditor is not None:
            print(f"   audit: {auditor.report().summary()}")
        print(f"   wrote {paths['csv']} {paths['json']} "
              f"({time.perf_counter() - t0:.2f}s)")

    if args.profile or probe is not None:
        PROFILER.disable()
    if args.trace_out is not None:
        from repro.obs.chrometrace import write_chrome_trace
        info = write_chrome_trace(args.trace_out, recorder, PROFILER)
        print(f"   wrote trace {info['path']} "
              f"({info['n_events']} events)")
    if args.profile:
        print("-- wall-clock phases --", file=sys.stderr)
        print(PROFILER.format_aggregate(), file=sys.stderr)

    if auditor is not None and not auditor.report().ok:
        from repro.obs.diff import DIVERGENCE_DIR
        report = auditor.report()
        DIVERGENCE_DIR.mkdir(parents=True, exist_ok=True)
        path = DIVERGENCE_DIR / "audit.md"
        path.write_text(report.to_markdown())
        print(f"audit FAILED: {report.summary()}\n"
              f"audit report: {path}", file=sys.stderr)
        return 1
    if failed:
        print(f"failed sweeps: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
