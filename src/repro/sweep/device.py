"""Device-batched whole-grid evaluation (sweep ``--mode device``).

One ``jax.jit`` + ``vmap`` program evaluates EVERY trace group's
post-simulation passes at once: the groups' ``StageTrace`` composition
columns are zero-padded and ragged-stacked into one ``(G, S)`` tensor
set, and the batched roofline (the same ``_roofline`` kernel
``stage_cost_batch`` runs), the Eq. 1-3 power/energy reductions and
the Eq. 4 emissions — including the per-group scenario fan-out over
the ``pue`` / ``grid_ci`` axes as a stacked ``(G, K)`` axis — compile
into a single device dispatch for the whole grid, instead of one numpy
pass per group (``repro.sweep.vectorized``).

Trace acquisition composes with ``repro.sweep.divergence``: groups
whose configs differ only in device/TP/PP and provably cannot diverge
in admission timing share one composition schedule (replayed per
config, bit-identically to the event loop) — the event loop runs only
for groups the conservative predicate rejects. Record assembly reuses
``runner.single_site_metrics``, so device-mode records carry exactly
the event-loop columns.

**Tolerance contract** (see README): numpy modes are bit-identical to
the event loop; device mode is NOT — the roofline and the Eq. 2-4
arithmetic are elementwise float64 (identical IEEE results under XLA),
but (a) the trace-level reductions (``sum(P_i*dt_i)``, ``sum(dt_i)``,
``sum(MFU_i*dt_i)``) reassociate — jnp's tree reduction vs numpy's
pairwise summation, ~1e-14 relative — and (b) the Eq. 1 power curve
is evaluated in float32 (mirroring ``core.power.power``) where XLA's
fused ``pow`` may differ from the eager op by a few f32 ulps, ~1e-7
relative on the power factor. ``DEVICE_MODE_RTOL`` bounds both with
margin; columns that never pass through the device program (latency
percentiles, throughput, MFU/batch averages, stage counts) come from
the host-side trace and stay bitwise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.carbon import reports_from_arrays
from repro.core.energy import reports_from_sums
from repro.core.power import DEVICES
from repro.fleet.config import FleetConfig
from repro.obs.spans import PROFILER
from repro.sim.execmodel import (PARAMS_FIELDS, _Params, _roofline,
                                 cached_execution_model)
from repro.sweep import divergence
from repro.sweep.grid import Scenario
from repro.sweep.vectorized import group_by_trace

#: documented ulp-level equivalence bound for device-mode records
#: against event-loop records (relative, per metric column) — the f32
#: Eq. 1 power evaluation dominates (~1e-7); 5e-6 leaves >10x margin
#: while still catching any real logic divergence. CI pins the perf
#: grid under this bound (benchmarks/perf_sweep.py --check-device).
DEVICE_MODE_RTOL = 5e-6


@dataclasses.dataclass
class DeviceStats:
    """How the device mode acquired and evaluated its traces."""
    trace_groups: int = 0
    event_loops: int = 0     # groups driven through the event loop
    replayed: int = 0        # groups served by divergence replay
    devices: int = 1         # accelerators the dispatch sharded over


def _next_pow2(n: int) -> int:
    """Padding bucket: shapes quantize to powers of two so jit
    recompiles O(log) times across grids, not per grid size."""
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def _group_kernel(comp_pre, comp_dec, comp_score, comp_kv,
                  params, powerp, ndev, phi, pues, cis):
    """Per-group pass (vmapped over G): roofline -> Eq. 1 power ->
    Eq. 2-3 reductions -> Eq. 4 terms over the scenario axis.

    Zero-padded rows have tokens == 0, which the roofline kernel
    already masks (all outputs zero), so only the power factor needs
    an explicit ``live`` mask (P(0) = p_idle, not 0)."""
    import jax.numpy as jnp

    p = _Params(*(params[i] for i in range(len(PARAMS_FIELDS))))
    t = _roofline(comp_pre, comp_dec, comp_score, comp_kv, p, jnp)
    dur_s, mfu = t[0], t[6]
    live = (comp_pre + comp_dec) > 0

    # Eq. 1 in float32, mirroring core.power.power() op for op; the
    # (p_max - p_idle) delta is precomputed host-side in f64 (powerp[4])
    # exactly as the eager path subtracts python floats
    mfu32 = jnp.clip(jnp.asarray(mfu, jnp.float32), 0.0, None)
    x = jnp.minimum(mfu32, powerp[2]) / powerp[2]
    pw = powerp[0] + powerp[4] * jnp.power(x, powerp[3])
    pw64 = jnp.where(live, pw.astype(jnp.float64), 0.0)

    e_sum = jnp.sum(pw64 * dur_s)                 # W*s
    m_sum = jnp.sum(mfu * dur_s)
    dur = jnp.sum(dur_s)
    peak = jnp.max(pw64)                          # 0 for empty groups
    gpu_h = dur / 3600.0 * ndev
    energy_wh = e_sum / 3600.0 * ndev * pues      # (K,) scenario axis
    op_g = energy_wh / 1000.0 * cis               # Eq. 4 operational
    emb_g = gpu_h * phi * 1000.0                  # Eq. 4 embodied
    return e_sum, m_sum, dur, peak, op_g, emb_g


_PROGRAM = None
_PMAP_PROGRAMS: Dict[int, object] = {}

# padded shapes this process has already dispatched: a new (G, S, K)
# bucket pays XLA compilation inside the call, a seen one replays the
# jit cache — the wall-clock profiler labels the two differently
_SEEN_SHAPES: set = set()

#: persistent-compilation-cache location; "off"/"0"/"none"/"" disables
#: (tests that pin compile-vs-execute span names set it off so a warm
#: on-disk cache can't blur the distinction)
ENV_JAX_CACHE_DIR = "REPRO_JAX_CACHE_DIR"
DEFAULT_JAX_CACHE_DIR = "results/jax_cache"

_PERSIST_CONFIGURED = False


def _maybe_persistent_cache() -> None:
    """Point jax at an on-disk compilation cache so the device
    program's XLA compile (``device_first_call_s``, ~0.3s/process) is
    paid once per shape bucket per machine instead of once per
    process — exactly the cost profile remote workers and process
    pools hit. Config keys are set best-effort: absent on older jax
    versions just means no persistence."""
    global _PERSIST_CONFIGURED
    if _PERSIST_CONFIGURED:
        return
    _PERSIST_CONFIGURED = True
    import os
    raw = os.environ.get(ENV_JAX_CACHE_DIR, DEFAULT_JAX_CACHE_DIR)
    if raw.strip().lower() in ("", "off", "0", "none"):
        return
    import jax
    try:
        os.makedirs(raw, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", raw)
        # the grid program compiles in ~0.3s — below the default 1s
        # persistence threshold — so lower both floors to "always"
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          -1)
    except (AttributeError, OSError):
        pass


def _program():
    global _PROGRAM
    if _PROGRAM is None:
        import jax
        _maybe_persistent_cache()
        _PROGRAM = jax.jit(jax.vmap(_group_kernel))
    return _PROGRAM


def _pmap_program(n_dev: int):
    """pmap(vmap(kernel)): the same per-group kernel, with the padded
    group axis split ``(G,) -> (D, G/D)`` so each local device
    evaluates its own slab — numerically the identical program per
    group, so the ``DEVICE_MODE_RTOL`` contract is unchanged."""
    prog = _PMAP_PROGRAMS.get(n_dev)
    if prog is None:
        import jax
        _maybe_persistent_cache()
        prog = jax.pmap(jax.vmap(_group_kernel))
        _PMAP_PROGRAMS[n_dev] = prog
    return prog


def _acquire_results(scenarios: Sequence[Scenario],
                     single: List[List[int]], stats: DeviceStats
                     ) -> Tuple[list, List[float]]:
    """One SimResult per single-site trace group: divergence-shared
    families replay one composition schedule per config; everything
    else runs the event loop."""
    from repro.sim import run_simulation

    fams: Dict[str, List[int]] = {}
    for gi, g in enumerate(single):
        blob = divergence.family_blob(scenarios[g[0]].cfg)
        fams.setdefault(blob, []).append(gi)

    results: list = [None] * len(single)
    sim_elapsed = [0.0] * len(single)
    for members in fams.values():
        cfgs = [scenarios[single[gi][0]].cfg for gi in members]
        shared = (len(members) > 1
                  and divergence.trace_shareable(cfgs)[0])
        for gi, cfg in zip(members, cfgs):
            t0 = time.perf_counter()
            if shared:
                results[gi] = divergence.replay_result(cfg)
                stats.replayed += 1
            else:
                results[gi] = run_simulation(cfg)
                stats.event_loops += 1
            sim_elapsed[gi] = time.perf_counter() - t0
    return results, sim_elapsed


def execute_device_grid(scenarios: Sequence[Scenario]
                        ) -> Tuple[List[dict], DeviceStats]:
    """Execute a whole cache-missed grid: fleet scenarios pass through
    their own rollup; every single-site trace group is padded into one
    batched tensor set and evaluated by a single device program."""
    import jax

    from repro.sweep.runner import (_execute_fleet_scenario,
                                    shared_result_metrics,
                                    single_site_metrics,
                                    single_site_record)

    groups = group_by_trace(scenarios)
    stats = DeviceStats(trace_groups=len(groups))
    records: List[Optional[dict]] = [None] * len(scenarios)

    single: List[List[int]] = []
    for g in groups:
        if isinstance(scenarios[g[0]].cfg, FleetConfig):
            # fleet rollups bake CI signals and PUE into per-site
            # co-sims — no stacked axis; identical to the other modes
            for i in g:
                records[i] = _execute_fleet_scenario(scenarios[i])
        else:
            single.append(g)
    if not single:
        return [r for r in records if r is not None], stats

    with PROFILER.span("device.acquire_traces"):
        results, sim_elapsed = _acquire_results(scenarios, single, stats)

    # ---- pad + ragged-stack into one (G, S) / (G, K) tensor set ----
    n_g = len(single)
    gp = _next_pow2(n_g)
    sp = _next_pow2(max(max(len(r.stages) for r in results), 1))
    kp = _next_pow2(max(max(len(g) for g in single), 1))
    comp = np.zeros((4, gp, sp))
    params = np.ones((gp, len(PARAMS_FIELDS)))
    powerp = np.zeros((gp, 5), np.float32)
    powerp[:, 2] = 0.5                   # padded groups: x = 0/0 guard
    powerp[:, 3] = 1.0
    ndev = np.ones(gp)
    phi = np.zeros(gp)
    pues = np.zeros((gp, kp))
    cis = np.zeros((gp, kp))
    for gi, (g, res) in enumerate(zip(single, results)):
        cfg = res.cfg
        tr = res.stages
        m = len(tr)
        comp[0, gi, :m] = tr.n_prefill_tokens
        comp[1, gi, :m] = tr.n_decode_tokens
        comp[2, gi, :m] = tr.score_flops
        comp[3, gi, :m] = tr.kv_rw_bytes
        em = cached_execution_model(cfg.model, cfg.device, cfg.tp,
                                    cfg.pp, cfg.execmodel)
        params[gi] = em.params_vector()
        dev = DEVICES[cfg.device]
        powerp[gi] = np.asarray(
            [dev.p_idle, dev.p_max_inst, dev.mfu_sat, dev.gamma,
             dev.p_max_inst - dev.p_idle], np.float32)
        ndev[gi] = float(cfg.n_devices)
        phi[gi] = dev.embodied_kg_per_hour
        for k, i in enumerate(g):
            pues[gi, k] = scenarios[i].pue
            cis[gi, k] = scenarios[i].grid_ci

    # ---- the single dispatch for the whole grid ----
    # enable_x64 is scoped: the program traces/executes in f64 without
    # flipping the process-global default (kernel/launcher tests in the
    # same process rely on f32 defaults). With >1 local accelerator the
    # padded group axis shards (D, G/D) across devices via pmap —
    # always exact: gp is a power of two, and so is D
    n_local = jax.local_device_count()
    d = 1
    while d * 2 <= min(n_local, gp):
        d *= 2
    args = (comp[0], comp[1], comp[2], comp[3],
            params, powerp, ndev, phi, pues, cis)
    shape_sig = (gp, sp, kp, d)
    dispatch_span = ("device.jit_compile_and_execute"
                     if shape_sig not in _SEEN_SHAPES
                     else "device.execute")
    with jax.experimental.enable_x64():
        with PROFILER.span(dispatch_span):
            if d > 1:
                sharded = tuple(
                    a.reshape((d, gp // d) + a.shape[1:]) for a in args)
                out = _pmap_program(d)(*sharded)
                e_sum, m_sum, dur, peak, op_g, emb_g = tuple(
                    np.asarray(o).reshape((gp,) + np.asarray(o).shape[2:])
                    for o in out)
            else:
                out = _program()(*args)
                e_sum, m_sum, dur, peak, op_g, emb_g = tuple(
                    np.asarray(o) for o in out)
    _SEEN_SHAPES.add(shape_sig)
    stats.devices = d

    # ---- record assembly through the shared single-site path ----
    for gi, (g, res) in enumerate(zip(single, results)):
        scs = [scenarios[i] for i in g]
        cfg = res.cfg
        shared_m = shared_result_metrics(res)
        reps = reports_from_sums(
            float(e_sum[gi]), float(m_sum[gi]), float(dur[gi]),
            float(peak[gi]), n_devices=cfg.n_devices,
            pues=[sc.pue for sc in scs])
        emb = float(emb_g[gi])
        ops = [float(o) for o in op_g[gi, :len(g)]]
        carbons = reports_from_arrays(
            ops, [emb] * len(g), [o + emb for o in ops],
            [sc.grid_ci for sc in scs])
        for i, sc, rep, carbon in zip(g, scs, reps, carbons):
            rec_t0 = time.perf_counter() - sim_elapsed[gi]
            metrics = single_site_metrics(res, sc, rep, carbon=carbon,
                                          shared=shared_m)
            records[i] = single_site_record(
                sc, metrics, rec_t0, mode="device",
                trace_scenarios=len(scs))
    return [r for r in records if r is not None], stats


def records_max_rel_err(recs_a: Sequence[dict], recs_b: Sequence[dict]
                        ) -> float:
    """Worst relative metric divergence between two aligned record
    sets (aligned by cache key) — what the CI perf job and the
    equivalence tests bound by ``DEVICE_MODE_RTOL``."""
    by_key = {r["key"]: r for r in recs_b}
    worst = 0.0
    for a in recs_a:
        b = by_key[a["key"]]
        for col, va in a["metrics"].items():
            vb = b["metrics"][col]
            if va == vb:
                continue
            rel = abs(va - vb) / max(abs(va), abs(vb))
            worst = max(worst, rel)
    return worst
