"""Trace-divergence analysis: when device/TP/PP axes share one trace.

Scenarios that differ only in ``device``/``tp``/``pp`` run the *same*
batch compositions whenever the hardware axes provably cannot change
admission timing — then the expensive part of the event loop (the
scheduling decisions) is config-invariant and each grid point's trace
is reconstructable by re-costing one shared composition, instead of
re-running the loop per point.

The predicate here is deliberately conservative (static, over the
config family + arrival stream only): it requires every request to be
**isolated** — consecutive ready-sorted arrival gaps at least an upper
bound on the previous request's full service time under *every* config
in the family, with every prompt inside every config's resolved KV
budget and no chunked prefill. Under isolation the loop serves one
request at a time, strictly serialized: request ``i`` goes to replica
``i % R`` (round-robin), its replica fast-forwards to the ready time,
and its schedule is exactly one whole-prompt prefill followed by
``decode_tokens`` single-token decode stages at contexts ``L..L+D-1``.
``replay_result`` reconstructs that schedule directly — aggregates via
the same float expressions as ``stage_cost_scalar``, costs via the
batched roofline (bit-identical to the scalar path by construction),
and clocks via the same left-fold accumulation ``drive`` performs — so
the replayed ``SimResult`` is **bit-equal** to what ``run_simulation``
would produce (pinned by the soundness property in
tests/test_device_mode.py).

Uniform (non-poisson) arrival streams at sub-service rates satisfy the
predicate by construction; poisson streams rarely do (some gap is
almost always tight), which is the right failure mode for a
conservative analysis: fall back to the event loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.sim.execmodel import StageBatch, cached_execution_model
from repro.sim.requests import Request, generate
from repro.sim.simulator import SimConfig, SimResult, kv_budget_tokens
from repro.sim.trace import StageTrace
from repro.core.power import DEVICES
from repro.sweep.grid import config_blob

#: drive()'s default horizon — a shared family must finish inside it
#: (the loop breaks mid-request past this point, which replay cannot
#: represent)
_MAX_SIM_S = 10_000_000.0


def family_blob(cfg) -> str:
    """Canonical config JSON with the hardware axes normalized out —
    configs sharing this blob differ (at most) in device/tp/pp and are
    candidates for one shared composition trace."""
    return config_blob(dataclasses.replace(cfg, device="*", tp=0, pp=0))


def _sorted_stream(cfg: SimConfig) -> Tuple[List[Request], np.ndarray]:
    """The workload draw in drive()'s admission order (stable sort by
    ready time), as (requests-in-rid-order, sorted row indices)."""
    requests = generate(cfg.workload)
    order = np.array(
        sorted(range(len(requests)), key=lambda i: requests[i].ready_s),
        np.int64)
    return requests, order


def _resolved_kv_budget(cfg: SimConfig) -> int:
    if cfg.auto_kv_budget:
        return kv_budget_tokens(cfg.model, DEVICES[cfg.device],
                                cfg.tp, cfg.pp)
    return cfg.scheduler.kv_budget_tokens


def _service_bound(cfg: SimConfig, L: np.ndarray, D: np.ndarray
                   ) -> np.ndarray:
    """Per-request upper bound on full service time under ``cfg``:
    ``t_prefill(L) + (D + 1) * t_decode(ctx = L + D)``. The roofline
    is monotone nondecreasing in context, so the decode term bounds
    every decode stage; the extra ``+1`` decode is slack dwarfing any
    accumulated summation ulps in the exact clock arithmetic."""
    em = cached_execution_model(cfg.model, cfg.device, cfg.tp, cfg.pp,
                                cfg.execmodel)
    n = len(L)
    kvpt = em.kv_bytes_per_token
    w = em.sliding_window
    avg_ctx = np.maximum(np.floor(L / 2.0), 1.0)
    pre = StageBatch(
        prefill_tokens=L, decode_count=np.zeros(n),
        score_flops=L * em._score_per_token(avg_ctx),
        kv_rw_bytes=L * kvpt)
    ub_ctx = L + D                      # one past the last decode context
    dec = StageBatch(
        prefill_tokens=np.zeros(n), decode_count=np.ones(n),
        score_flops=em._score_per_token(ub_ctx),
        kv_rw_bytes=np.minimum(ub_ctx, w) * kvpt + kvpt)
    t = em.stage_cost_batch(StageBatch.concat([pre, dec])).t_total
    return t[:n] + (D + 1.0) * t[n:]


def trace_shareable(cfgs: Sequence[SimConfig]) -> Tuple[bool, str]:
    """Conservative static predicate: may every config in the family
    share one composition trace? Returns (ok, reason)."""
    base = cfgs[0]
    if not isinstance(base, SimConfig):
        return False, "not a single-site config"
    for c in cfgs:
        if not isinstance(c, SimConfig):
            return False, "not a single-site config"
        if c.scheduler.chunk_prefill is not None:
            return False, "chunked prefill schedules depend on timing"
        if c.scheduler.batch_cap < 1:
            return False, "degenerate batch cap"
    if len({family_blob(c) for c in cfgs}) != 1:
        return False, "configs differ beyond device/tp/pp"

    requests, order = _sorted_stream(base)
    if not requests:
        return True, "empty workload"
    L = np.array([requests[i].prefill_tokens for i in order], np.float64)
    D = np.array([requests[i].decode_tokens for i in order], np.float64)
    ready = np.array([requests[i].ready_s for i in order], np.float64)
    if np.any(L < 1) or np.any(D < 1):
        return False, "degenerate request lengths"
    gaps = np.diff(ready)
    for c in cfgs:
        budget = _resolved_kv_budget(c)
        if budget <= 0 or float(L.max()) > budget:
            return False, (f"prompt exceeds KV budget on {c.device}"
                           f"/tp{c.tp}/pp{c.pp}")
        bound = _service_bound(c, L, D)
        if len(gaps) and bool(np.any(gaps < bound[:-1])):
            return False, (f"arrival gaps under service bound on "
                           f"{c.device}/tp{c.tp}/pp{c.pp}")
        if float(ready[-1] + bound[-1]) > _MAX_SIM_S:
            return False, "exceeds the event-loop horizon"
    return True, "isolated arrivals under every config"


def replay_result(cfg: SimConfig) -> SimResult:
    """Reconstruct ``run_simulation(cfg)`` bit-for-bit from the derived
    isolated schedule — valid ONLY when ``trace_shareable`` holds for a
    family containing ``cfg`` (the predicate proves the loop would make
    exactly these scheduling decisions)."""
    em = cached_execution_model(cfg.model, cfg.device, cfg.tp, cfg.pp,
                                cfg.execmodel)
    requests, order = _sorted_stream(cfg)
    n = len(order)
    pp = max(cfg.pp, 1)
    if n == 0:
        empty = {f.name: np.empty(0, np.int64 if f.name in
                                  ("n_prefill_tokens", "n_decode_tokens",
                                   "replica", "batch_size") else np.float64)
                 for f in dataclasses.fields(StageTrace)}
        return SimResult(stages=StageTrace(**empty), requests=requests,
                         cfg=cfg)

    Li = np.array([requests[i].prefill_tokens for i in order], np.int64)
    Di = np.array([requests[i].decode_tokens for i in order], np.int64)
    ready = np.array([requests[i].ready_s for i in order], np.float64)
    Lf = Li.astype(np.float64)

    # ---- iteration-level composition (1 prefill + D decodes/req) ----
    n_it = 1 + Di
    total_it = int(n_it.sum())
    seg0 = np.cumsum(n_it) - n_it                 # first iteration per req
    req_idx = np.repeat(np.arange(n), n_it)
    pos = np.arange(total_it) - seg0[req_idx]     # 0 = prefill, j = decode j
    is_pre = pos == 0
    ctx = Lf[req_idx] + (pos - 1)                 # decode ctx: L..L+D-1

    # aggregates via the same float expressions as stage_cost_scalar
    # (single-element sums are exact, so the vectorized forms match
    # the scalar path bitwise)
    kvpt = em.kv_bytes_per_token
    w = em.sliding_window
    avg_ctx = np.maximum(0.0 + np.floor(Lf / 2.0), 1.0)
    score_pre = Lf * em._score_per_token(avg_ctx)
    npt = np.where(is_pre, Lf[req_idx], 0.0)
    nd = np.where(is_pre, 0.0, 1.0)
    score = np.where(is_pre, score_pre[req_idx],
                     em._score_per_token(ctx))
    kv = np.where(is_pre, Lf[req_idx] * kvpt,
                  np.minimum(ctx, w) * kvpt + kvpt)
    costs = em.stage_cost_batch(
        StageBatch(prefill_tokens=npt, decode_count=nd,
                   score_flops=score, kv_rw_bytes=kv))
    durs = costs.t_total

    # ---- clocks: drive()'s left-fold accumulation per request ----
    starts = np.empty(total_it, np.float64)
    t_first = np.empty(n, np.float64)
    t_done = np.empty(n, np.float64)
    off = 0
    for i in range(n):
        m = int(n_it[i])
        c = np.cumsum(np.concatenate(([ready[i]], durs[off:off + m])))
        starts[off:off + m] = c[:-1]
        t_first[i] = c[1]                 # prefill completion
        t_done[i] = c[-1]
        off += m

    # ---- pipeline-stage row expansion (pp rows per iteration) ----
    rep_durs = np.repeat(durs, pp)
    ps_f = np.tile(np.arange(pp, dtype=np.float64), total_it)
    start_rows = np.repeat(starts, pp) + ps_f * rep_durs / float(pp)
    replica = (np.repeat((np.arange(n, dtype=np.int64) % cfg.n_replicas)
                         [req_idx] * pp, pp)
               + np.tile(np.arange(pp, dtype=np.int64), total_it))
    trace = StageTrace(
        start_s=start_rows, dur_s=rep_durs,
        flops_mlp=np.repeat(costs.flops_mlp, pp),
        flops_attn=np.repeat(costs.flops_attn, pp),
        mfu=np.repeat(costs.mfu, pp),
        n_prefill_tokens=np.repeat(npt, pp).astype(np.int64),
        n_decode_tokens=np.repeat(nd, pp).astype(np.int64),
        replica=replica,
        batch_size=np.ones(total_it * pp, np.int64),
        score_flops=np.repeat(score, pp),
        kv_rw_bytes=np.repeat(kv, pp))

    for i in range(n):
        r = requests[int(order[i])]
        r.prefilled = True
        r.prefill_done = int(Li[i])
        r.decoded = int(Di[i])
        r.t_first_token = float(t_first[i])
        r.t_done = float(t_done[i])
    return SimResult(stages=trace, requests=requests, cfg=cfg)
