"""Declarative scenario grids over ``SimConfig``.

A sweep is a base config plus named axes of dotted-path overrides
("workload.qps", "scheduler.batch_cap", "tp", "model", ...). Expanding
the grid yields ``Scenario`` objects: a fully-resolved ``SimConfig``,
the flat axis parameters for reporting, and a stable content hash that
keys the on-disk result cache (``repro.sweep.cache``).

Joint axes sweep several fields in lockstep with a ``+``-joined key:

    GridSpec(base=PAPER_DEFAULT,
             axes={"workload.qps": [1.0, 5.0, 10.0],
                   "tp+pp": [(1, 1), (2, 2)]})

expands to 6 scenarios (cardinality = product of axis lengths).

Axes (or ``fixed`` entries) whose path starts with ``post.`` override
the scenario's post-processor parameters instead of the config — e.g.
``"post.solar_capacity_w": [0.0, 600.0]`` sweeps the microgrid co-sim's
solar actor without touching ``SimConfig`` (the carbon-aware axes).

The paths ``pue`` and ``grid_ci`` address the *scenario-level* report
knobs (datacenter PUE, static grid carbon intensity) rather than the
config tree. Scenarios differing only in these axes (or in ``post.*``
parameters) share one simulation trace — the vectorized runner mode
(``repro.sweep.vectorized``) runs the event loop once per unique
config and evaluates such axes as stacked array passes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Dict, List, Mapping, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.sim.simulator import SimConfig

# Bump when simulator/runner semantics change in a way that invalidates
# previously cached scenario results.
# v2: shared fleet/single-site event loop — admission is gated on the
# next processing event instead of the min clock across all replicas
# (single-replica results are unchanged; multi-replica skew differs).
# v3: config schema extension for repro.schedule (workload classes on
# WorkloadConfig, ScheduleConfig + horizon_s on FleetConfig) changes
# every config's digest even though metrics under the defaults
# (immediate admission, no deferrable class) are numerically identical
# to v2 — pinned by tests/test_schedule.py.
# v4: array-native execution model — the roofline is evaluated by the
# batched kernel (repro.sim.execmodel.stage_cost_batch) whose folded
# constants reassociate a few float products (ulp-level timing shifts
# everywhere), and Sarathi chunked prefill now charges cross-chunk KV
# reads + context-offset score FLOPs (chunked scenarios change
# materially). Vectorized vs event-loop runner modes are bit-identical
# under v4 (tests/test_vectorized.py), so mode is NOT part of the key.
# v5: config schema extension for day-scale workloads (envelope/burst
# fields on WorkloadConfig, AutoscalerConfig on SiteConfig, DayConfig
# on FleetConfig) changes every digest; metrics under the defaults
# (no envelope, autoscaler disabled, day=None) are bit-identical to
# v4 — pinned by tests/test_day.py golden records.
# v6: the day planner's saturation guard gained a model-derived
# capacity floor (min of the autoscaler's tokens_per_s estimate and
# the roofline's replica_tokens_per_s), which can reclassify
# queue-saturated epochs from fluid to exact — day-grid records
# change; everything else is bit-identical to v5, pinned by the
# fig1/fleet/shift golden records in tests/test_day.py.
SCHEMA_VERSION = 6

# Default static grid carbon intensity for the report's carbon columns
# (gCO2eq/kWh; CAISO-ish annual average — the paper's co-sim case study
# uses a time-varying CAISO-North signal instead, via the cosim post).
DEFAULT_GRID_CI = 250.0

# axis paths addressing Scenario-level report knobs rather than the
# config tree (see GridSpec docstring)
_SCENARIO_KNOBS = ("pue", "grid_ci")


def _is_fleet(cfg) -> bool:
    from repro.fleet.config import FleetConfig
    return isinstance(cfg, FleetConfig)


def model_registry() -> Dict[str, ModelConfig]:
    """All paper models, addressable by name in grid axes."""
    from repro.configs import paper_models
    return {m.name: m for m in vars(paper_models).values()
            if isinstance(m, ModelConfig)}


def resolve_model(value) -> ModelConfig:
    if isinstance(value, ModelConfig):
        return value
    models = model_registry()
    if value not in models:
        raise KeyError(f"unknown model {value!r}; have {sorted(models)}")
    return models[value]


def with_overrides(cfg, overrides: Mapping[str, object]):
    """dataclasses.replace along dotted paths ("workload.qps" -> 6.45)."""
    by_head: Dict[str, Dict[str, object]] = {}
    flat: Dict[str, object] = {}
    for path, value in overrides.items():
        head, _, rest = path.partition(".")
        if rest:
            by_head.setdefault(head, {})[rest] = value
        else:
            if head == "model":
                value = resolve_model(value)
            flat[head] = value
    for head, sub in by_head.items():
        flat[head] = with_overrides(getattr(cfg, head), sub)
    return dataclasses.replace(cfg, **flat)


def _jsonable(value):
    if isinstance(value, ModelConfig):
        return value.name
    if isinstance(value, (tuple, list)):
        return [_jsonable(v) for v in value]
    return value


def _canonical_json(obj) -> str:
    return json.dumps(obj, sort_keys=True, default=str,
                      separators=(",", ":"))


def config_blob(cfg) -> str:
    """Canonical JSON of the config tree alone — the expensive part of
    a digest (``dataclasses.asdict`` over the full tree plus the JSON
    encode), shared between a scenario's ``key`` and ``trace_key``."""
    return _canonical_json(dataclasses.asdict(cfg))


def _digest_from_blobs(cfg_json: str, extra_json: str) -> str:
    # assembles the exact bytes json.dumps(payload, sort_keys=True)
    # would produce: the payload keys already sort cfg < extra < schema
    blob = (f'{{"cfg":{cfg_json},"extra":{extra_json},'
            f'"schema":{SCHEMA_VERSION}}}')
    return hashlib.sha256(blob.encode()).hexdigest()


def config_digest(cfg: SimConfig, extra: Optional[Mapping] = None) -> str:
    """Stable content hash of a scenario: canonical JSON of the full
    config tree (+ runner knobs) under the current schema version."""
    return _digest_from_blobs(config_blob(cfg),
                              _canonical_json(dict(extra or {})))


def derive_seed(params: Mapping[str, object]) -> int:
    """Deterministic per-scenario workload seed from the axis values —
    independent of execution order or process, so parallel and serial
    sweeps sample identical workloads."""
    blob = json.dumps({k: _jsonable(v) for k, v in params.items()},
                      sort_keys=True, default=str)
    return int.from_bytes(hashlib.sha256(blob.encode()).digest()[:4],
                          "big") % (2 ** 31)


@dataclasses.dataclass
class Scenario:
    """One fully-resolved point of a sweep.

    ``cfg`` is a ``SimConfig`` or a ``repro.fleet.FleetConfig`` — the
    runner dispatches on the type; both digest identically through
    ``config_digest``.
    """
    cfg: object
    params: Dict[str, object]
    tag: str = "scenario"
    pue: float = 1.2
    grid_ci: float = DEFAULT_GRID_CI
    post: Optional[str] = None            # runner post-processor name
    post_params: Dict[str, object] = dataclasses.field(default_factory=dict)
    # digests are lazily cached: the runner's dedup loop, the trace
    # grouping and record assembly all consult them, and one sha256
    # over the full config tree per consult would dominate the
    # per-scenario cost on large vectorized grids (scenarios are
    # treated as immutable once expanded)
    _key: Optional[str] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _trace_key: Optional[str] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _cfg_blob: Optional[str] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def cfg_blob(self) -> str:
        """Canonical config JSON, serialized once per scenario — both
        digests below reuse it (the asdict+encode pass dominates
        per-scenario runner overhead on large stacked grids)."""
        if self._cfg_blob is None:
            self._cfg_blob = config_blob(self.cfg)
        return self._cfg_blob

    @property
    def key(self) -> str:
        if self._key is None:
            self._key = _digest_from_blobs(self.cfg_blob, _canonical_json({
                "pue": self.pue, "grid_ci": self.grid_ci,
                "post": self.post, "post_params": self.post_params,
            }))
        return self._key

    @property
    def trace_key(self) -> str:
        """Digest of the config alone — everything the simulation
        trace depends on, nothing the report knobs touch (the
        vectorized runner's grouping key)."""
        if self._trace_key is None:
            self._trace_key = _digest_from_blobs(self.cfg_blob, "{}")
        return self._trace_key


@dataclasses.dataclass
class GridSpec:
    """Declarative parameter grid over a base SimConfig."""
    base: SimConfig
    axes: Mapping[str, Sequence] = dataclasses.field(default_factory=dict)
    fixed: Mapping[str, object] = dataclasses.field(default_factory=dict)
    tag: str = "sweep"
    pue: float = 1.2
    grid_ci: float = DEFAULT_GRID_CI
    post: Optional[str] = None
    post_params: Mapping[str, object] = dataclasses.field(default_factory=dict)
    seed_per_scenario: bool = False   # derive workload.seed from params

    @property
    def cardinality(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return n

    def expand(self) -> List[Scenario]:
        keys = list(self.axes.keys())
        value_lists = [self.axes[k] for k in keys]
        scenarios: List[Scenario] = []
        for combo in itertools.product(*value_lists):
            overrides: Dict[str, object] = dict(self.fixed)
            params: Dict[str, object] = {}
            report_only = set()    # param leaves that never touch cfg
            for key, value in zip(keys, combo):
                parts = key.split("+")
                values = value if len(parts) > 1 else (value,)
                if len(parts) != len(values):
                    raise ValueError(
                        f"joint axis {key!r} expects {len(parts)}-tuples, "
                        f"got {value!r}")
                for part, v in zip(parts, values):
                    overrides[part] = v
                    # report under the leaf name ("workload.qps" -> "qps")
                    leaf = part.split(".")[-1]
                    params[leaf] = _jsonable(v)
                    if part.startswith("post.") or part in _SCENARIO_KNOBS:
                        report_only.add(leaf)
            if self.seed_per_scenario and "workload.seed" not in overrides:
                # report-only axes (pue/grid_ci/post.*) never influence
                # the workload draw: scenarios differing only in them
                # must sample identical requests (trace sharing + an
                # unconfounded report axis)
                seed_params = {k: v for k, v in params.items()
                               if k not in report_only}
                overrides["workload.seed"] = derive_seed(seed_params)
            # "post.<key>" paths parameterize the post-processor,
            # "pue"/"grid_ci" the scenario-level report knobs, the
            # rest resolve into the config tree
            post_params = dict(self.post_params)
            scen_knobs = {"pue": self.pue, "grid_ci": self.grid_ci}
            cfg_overrides = {}
            for path, value in overrides.items():
                if path.startswith("post."):
                    post_params[path[len("post."):]] = value
                elif path in scen_knobs:
                    scen_knobs[path] = value
                    if hasattr(self.base, path):
                        # FleetConfig carries its own pue field, read
                        # by the fleet rollup — route the value there
                        # too so a fleet pue axis keeps sweeping it
                        cfg_overrides[path] = value
                    elif _is_fleet(self.base):
                        raise ValueError(
                            f"a {path!r} axis has no effect on fleet "
                            "scenarios (sites carry CI traces); sweep "
                            "site ci_trace instead")
                else:
                    cfg_overrides[path] = value
            cfg = with_overrides(self.base, cfg_overrides)
            label = ",".join(f"{k}={params[k]}" for k in params) or "base"
            scenarios.append(Scenario(
                cfg=cfg, params=params, tag=f"{self.tag}/{label}",
                pue=scen_knobs["pue"], grid_ci=scen_knobs["grid_ci"],
                post=self.post, post_params=post_params))
        return scenarios
