"""Cluster-scale sweep backend: a leased trace-group work queue over
the shared ``ResultCache`` (``SweepRunner(backend="remote")``).

The content-addressed cache layout already IS a shared result store —
writes are atomic (tmp + rename) and keys are config digests — so the
only thing a cluster needs on top of it is a work queue. This module
implements that queue as plain files on the same shared filesystem:

* the **coordinator** enumerates cache-missed scenarios, groups them by
  trace digest (``repro.sweep.vectorized.group_by_trace``), packs the
  groups into size-balanced *shards* (greedy LPT over estimated stage
  counts, ``pack_shards``) and publishes one pickled shard file per
  shard under ``<queue>/job-<id>/pending/``;
* **workers** (``python -m repro.sweep.worker``, same host or any host
  sharing the filesystem) claim shards by atomically renaming them into
  ``running/`` (exactly one rename wins), refresh the lease by touching
  the running file's mtime from a heartbeat thread, evaluate each
  shard's groups through the existing vectorized/device paths, write
  the records straight into the shared cache, and publish a JSON
  completion manifest (per-shard stats + ``SpanProfiler`` phase
  aggregate) under ``done/``;
* the coordinator tails ``done/``, **reclaims expired leases** (a
  crashed or wedged worker's shard is renamed back to ``pending/`` with
  its attempt count bumped — bounded by ``max_attempts``, after which
  the shard is quarantined under ``failed/``), merges the workers'
  phase aggregates and stats counters, and finally assembles the
  records by reading them back from the shared cache.

Correctness under crashes falls out of determinism + content
addressing: re-executing a shard produces bit-identical records under
the same keys, and cache writes are atomic — so a shard that is
executed twice (a slow worker racing its own lease expiry) converges
to exactly one record per scenario, never a torn or duplicated entry.
Records from remote workers are bit-identical to serial in-process
execution (workers run the same ``execute_scenario_group`` path);
``verify_groups`` makes the coordinator re-run a sample serially and
assert that equality per job.

Shard payloads are pickled (trusted shared filesystem, same codebase
on every host — the payload embeds ``SCHEMA_VERSION`` and workers skip
jobs whose schema does not match their own, so version skew degrades
to "no matching worker" instead of silent divergence).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import subprocess
import sys
import tempfile
import time
import uuid
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.log import get_logger
from repro.obs.spans import PROFILER
from repro.sweep.cache import ResultCache
from repro.sweep.grid import SCHEMA_VERSION, Scenario
from repro.sweep.vectorized import (estimate_group_cost, group_by_trace)

_log = get_logger("repro.sweep.remote")

#: queue sub-directories a shard file moves through (the directory IS
#: the shard's state; transitions are single atomic renames)
PENDING, RUNNING, DONE, FAILED = "pending", "running", "done", "failed"

#: crash-injection hook for the retry tests: a worker whose environment
#: sets this executes N groups of its first shard, then dies without
#: completing it (``os._exit``) — exercising lease expiry + reclaim
ENV_CRASH_AFTER_GROUPS = "REPRO_WORKER_CRASH_AFTER_GROUPS"


@dataclasses.dataclass
class RemoteOptions:
    """Coordinator knobs for the remote backend."""
    queue_dir: Optional[Path] = None    # default: <cache_root>/.queue
    spawn_workers: int = 0              # local convenience workers
    n_shards: Optional[int] = None      # default: shards_per_worker heur.
    shards_per_worker: int = 4          # over-decompose for work stealing
    lease_s: float = 30.0               # heartbeat staleness => reclaim
    poll_s: float = 0.05                # coordinator/worker poll period
    max_attempts: int = 3               # attempts before quarantine
    timeout_s: float = 3600.0           # whole-job wall-clock guard
    worker_mode: str = "inherit"        # spawned workers' --mode
    verify_groups: int = 0              # re-run N groups serially, assert
    # per-spawned-worker extra environment (test hook: crash injection)
    worker_env: Optional[List[Dict[str, str]]] = None


@dataclasses.dataclass
class RemoteStats:
    """What the coordinator observed for one job."""
    shards: int = 0
    trace_groups: int = 0
    lease_expired: int = 0
    retried: int = 0          # re-pended shards (expiry or worker error)
    quarantined: int = 0
    workers: int = 0          # distinct worker ids seen in manifests
    verified_groups: int = 0


# --------------------------------------------------------------------------
# shard packing: greedy LPT over estimated stage counts
# --------------------------------------------------------------------------

def pack_shards(costs: Sequence[float], n_shards: int) -> List[List[int]]:
    """Partition item indices into ``n_shards`` balanced bins by greedy
    LPT (longest processing time first): sort descending, always assign
    to the least-loaded bin. Guarantees makespan <= total/n + max(cost)
    and preserves the exact index multiset (hypothesis-pinned in
    tests/test_remote.py). Deterministic: ties break on index, so every
    coordinator packs identically. Empty bins are dropped."""
    n_shards = max(1, min(int(n_shards), len(costs))) if costs else 1
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], i))
    bins: List[List[int]] = [[] for _ in range(n_shards)]
    loads = [0.0] * n_shards
    for i in order:
        j = min(range(n_shards), key=lambda k: (loads[k], k))
        bins[j].append(i)
        loads[j] += costs[i]
    return [b for b in bins if b]


# --------------------------------------------------------------------------
# filesystem protocol: atomic writes, claims, leases
# --------------------------------------------------------------------------

def _atomic_write_bytes(path: Path, data: bytes) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: Path, obj) -> None:
    _atomic_write_bytes(Path(path),
                        json.dumps(obj, indent=1, default=str).encode())


def shard_file_name(shard: int, attempt: int, worker: str = "") -> str:
    suffix = f".{worker}" if worker else ""
    return f"shard-{shard:04d}.a{attempt}{suffix}.pkl"


def parse_shard_name(name: str) -> Tuple[int, int, Optional[str]]:
    """``shard-0007.a2[.worker].pkl`` -> (7, 2, worker|None)."""
    stem = name[:-len(".pkl")]
    head, attempt_part, *rest = stem.split(".", 2)
    shard = int(head.split("-", 1)[1])
    attempt = int(attempt_part[1:])
    return shard, attempt, (rest[0] if rest else None)


def publish_shard(job_dir: Path, shard: int, payload: dict) -> Path:
    path = job_dir / PENDING / shard_file_name(shard, 0)
    _atomic_write_bytes(path, pickle.dumps(
        payload, protocol=pickle.HIGHEST_PROTOCOL))
    return path


def claim_shard(job_dir: Path, name: str, worker_id: str
                ) -> Optional[Tuple[dict, Path]]:
    """Atomically claim one pending shard by renaming it into
    ``running/`` tagged with the worker id — exactly one concurrent
    claimer's rename succeeds; the rest see FileNotFoundError and move
    on. Returns ``(payload, running_path)`` or None if lost the race.
    The running file's mtime is the lease: the claim itself refreshes
    it, the worker's heartbeat keeps refreshing it."""
    shard, attempt, _ = parse_shard_name(name)
    src = job_dir / PENDING / name
    dst = job_dir / RUNNING / shard_file_name(shard, attempt, worker_id)
    try:
        os.rename(src, dst)
    except FileNotFoundError:
        return None
    os.utime(dst)
    try:
        payload = pickle.loads(dst.read_bytes())
    except Exception as exc:     # unreadable payload: quarantine it
        atomic_write_json(job_dir / FAILED / f"shard-{shard:04d}.json",
                          {"shard": shard, "attempts": attempt,
                           "error": f"unreadable payload: {exc!r}"})
        try:
            os.rename(dst, job_dir / FAILED / dst.name)
        except OSError:
            pass
        return None
    return payload, dst


def heartbeat(running_path: Path) -> bool:
    """Refresh a claimed shard's lease; False if it was reclaimed."""
    try:
        os.utime(running_path)
        return True
    except OSError:
        return False


def complete_shard(job_dir: Path, running_path: Path,
                   manifest: dict) -> None:
    """Publish the completion manifest, then release the lease. The
    manifest lands first so a crash between the two steps errs toward
    "done" (the records are already in the cache); a duplicate done
    manifest from a lease-raced re-execution simply overwrites with
    equivalent content (deterministic records)."""
    shard = parse_shard_name(running_path.name)[0]
    atomic_write_json(job_dir / DONE / f"shard-{shard:04d}.json", manifest)
    try:
        running_path.unlink()
    except FileNotFoundError:
        pass                     # reclaimed while we finished: harmless


def release_shard(job_dir: Path, running_path: Path, max_attempts: int,
                  error: str) -> str:
    """Return a claimed shard to ``pending/`` with its attempt count
    bumped, or quarantine it under ``failed/`` once attempts are
    exhausted. Returns "retried" | "quarantined" | "gone" (someone else
    already moved it)."""
    shard, attempt, _ = parse_shard_name(running_path.name)
    nxt = attempt + 1
    if nxt >= max_attempts:
        atomic_write_json(job_dir / FAILED / f"shard-{shard:04d}.json",
                          {"shard": shard, "attempts": nxt,
                           "error": error})
        try:
            os.rename(running_path,
                      job_dir / FAILED / shard_file_name(shard, nxt))
        except FileNotFoundError:
            return "gone"
        return "quarantined"
    try:
        os.rename(running_path,
                  job_dir / PENDING / shard_file_name(shard, nxt))
    except FileNotFoundError:
        return "gone"
    return "retried"


def reclaim_expired(job_dir: Path, lease_s: float, max_attempts: int
                    ) -> Tuple[int, int, int]:
    """Coordinator-side lease sweep over ``running/``: any claim whose
    mtime is staler than ``lease_s`` belongs to a crashed or wedged
    worker — re-pend it (or quarantine after ``max_attempts``).
    Returns (expired, retried, quarantined) counts."""
    expired = retried = quarantined = 0
    now = time.time()
    for path in sorted((job_dir / RUNNING).glob("shard-*.pkl")):
        try:
            age = now - path.stat().st_mtime
        except FileNotFoundError:
            continue             # completed or reclaimed under us
        if age <= lease_s:
            continue
        outcome = release_shard(job_dir, path, max_attempts,
                                f"lease expired after {age:.1f}s")
        if outcome == "gone":
            continue
        expired += 1
        if outcome == "retried":
            retried += 1
        else:
            quarantined += 1
    return expired, retried, quarantined


# --------------------------------------------------------------------------
# worker process management (local convenience spawns + benches/tests)
# --------------------------------------------------------------------------

def spawn_worker(queue_dir: Path, worker_id: Optional[str] = None,
                 mode: str = "inherit", poll_s: float = 0.05,
                 env: Optional[Dict[str, str]] = None,
                 log_path: Optional[Path] = None) -> subprocess.Popen:
    """Start a detached ``python -m repro.sweep.worker`` on this host.
    Cluster deployments start the same command on any host sharing the
    filesystem; this helper exists for the coordinator's
    ``spawn_workers`` convenience, the benches and the tests."""
    import repro
    pkg_root = str(Path(repro.__file__).resolve().parent.parent)
    full_env = dict(os.environ)
    full_env.update(env or {})
    full_env["PYTHONPATH"] = pkg_root + os.pathsep + \
        full_env.get("PYTHONPATH", "")
    full_env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "repro.sweep.worker", str(queue_dir),
           "--mode", mode, "--poll-s", str(poll_s)]
    if worker_id:
        cmd += ["--worker-id", worker_id]
    out = open(log_path, "ab") if log_path else subprocess.DEVNULL
    try:
        return subprocess.Popen(cmd, env=full_env, stdout=out,
                                stderr=subprocess.STDOUT)
    finally:
        if log_path:
            out.close()


def wait_for_workers(queue_dir: Path, n: int, timeout_s: float = 120.0
                     ) -> List[str]:
    """Block until ``n`` workers have registered under
    ``<queue>/workers/`` (each worker touches its alive file once its
    execution stack is warm) — the benches use this to time resident-
    cluster dispatch rather than python+jax cold starts."""
    deadline = time.monotonic() + timeout_s
    workers_dir = Path(queue_dir) / "workers"
    while True:
        alive = sorted(p.stem for p in workers_dir.glob("*.alive")) \
            if workers_dir.exists() else []
        if len(alive) >= n:
            return alive
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"{len(alive)}/{n} workers registered under "
                f"{workers_dir} within {timeout_s}s")
        time.sleep(0.05)


# --------------------------------------------------------------------------
# coordinator
# --------------------------------------------------------------------------

class RemoteCoordinator:
    """Publish a job's shards, tail completion, merge, fetch.

    ``execute(todo)`` returns ``(records, RemoteStats)`` with records
    aligned to ``todo`` — the drop-in remote counterpart of the local
    execution backends in ``SweepRunner``.
    """

    def __init__(self, cache: ResultCache, opts: Optional[RemoteOptions]
                 = None, mode: str = "vectorized", note=None):
        if cache is None:
            raise ValueError("the remote backend requires a shared "
                             "ResultCache (workers write records into it)")
        if mode not in ("vectorized", "device"):
            raise ValueError(
                f"remote backend ships whole trace groups; mode {mode!r} "
                "is not supported (use 'vectorized' or 'device')")
        self.cache = cache
        self.opts = opts or RemoteOptions()
        self.mode = mode
        self.note = note or (lambda msg: None)

    # ---- job setup ----

    def _queue_dir(self) -> Path:
        if self.opts.queue_dir is not None:
            return Path(self.opts.queue_dir)
        return self.cache.root / ".queue"

    def _publish(self, todo: Sequence[Scenario]) -> Tuple[Path, int, int]:
        groups = group_by_trace(todo)
        group_scs = [[todo[i] for i in g] for g in groups]
        costs = [estimate_group_cost(g) for g in group_scs]
        workers_hint = max(self.opts.spawn_workers, 2)
        n_shards = self.opts.n_shards or \
            self.opts.shards_per_worker * workers_hint
        shards = pack_shards(costs, n_shards)

        queue = self._queue_dir()
        job_id = f"{time.strftime('%Y%m%d-%H%M%S')}-{os.getpid()}-" \
                 f"{uuid.uuid4().hex[:6]}"
        job_dir = queue / f"job-{job_id}"
        for state in (PENDING, RUNNING, DONE, FAILED):
            (job_dir / state).mkdir(parents=True, exist_ok=True)
        atomic_write_json(job_dir / "job.json", {
            "job": job_id, "status": "open", "schema": SCHEMA_VERSION,
            "mode": self.mode, "n_shards": len(shards),
            "lease_s": self.opts.lease_s,
            "max_attempts": self.opts.max_attempts,
            "cache_root": str(Path(self.cache.root).resolve()),
            "created": time.time(),
        })
        with PROFILER.span("remote.publish"):
            for sid, gidxs in enumerate(shards):
                publish_shard(job_dir, sid, {
                    "job": job_id, "shard": sid,
                    "schema": SCHEMA_VERSION, "mode": self.mode,
                    "groups": [group_scs[g] for g in gidxs],
                })
        self.note(f"published {len(shards)} shard(s) covering "
                  f"{len(groups)} trace group(s) to {job_dir}")
        return job_dir, len(shards), len(groups)

    def _spawn(self, queue: Path, job_dir: Path
               ) -> List[subprocess.Popen]:
        procs = []
        envs = list(self.opts.worker_env or [])
        for i in range(self.opts.spawn_workers):
            extra = envs[i] if i < len(envs) else {}
            procs.append(spawn_worker(
                queue, worker_id=f"w{i}", mode=self.opts.worker_mode,
                poll_s=self.opts.poll_s, env=extra,
                log_path=job_dir / f"worker-w{i}.log"))
        return procs

    # ---- completion tail ----

    def _tail(self, job_dir: Path, n_shards: int, stats: RemoteStats
              ) -> Dict[int, dict]:
        deadline = time.monotonic() + self.opts.timeout_s
        manifests: Dict[int, dict] = {}
        failed: Dict[int, dict] = {}
        while True:
            for path in sorted((job_dir / DONE).glob("shard-*.json")):
                sid = int(path.stem.split("-", 1)[1])
                if sid not in manifests:
                    manifests[sid] = json.loads(path.read_text())
            exp, ret, quar = reclaim_expired(
                job_dir, self.opts.lease_s, self.opts.max_attempts)
            stats.lease_expired += exp
            stats.retried += ret
            stats.quarantined += quar
            for path in sorted((job_dir / FAILED).glob("shard-*.json")):
                sid = int(path.stem.split("-", 1)[1])
                failed.setdefault(sid, json.loads(path.read_text()))
            # a done shard's stale duplicates (re-pended by an expiry
            # the original worker outran) are dead work: drop them
            for state in (PENDING, RUNNING):
                for path in (job_dir / state).glob("shard-*.pkl"):
                    if parse_shard_name(path.name)[0] in manifests:
                        try:
                            path.unlink()
                        except OSError:
                            pass
            # "failed" only counts if no execution ever completed it
            dead = {sid: m for sid, m in failed.items()
                    if sid not in manifests}
            if len(manifests) + len(dead) >= n_shards:
                if dead:
                    raise RuntimeError(
                        f"{len(dead)} shard(s) quarantined after "
                        f"{self.opts.max_attempts} attempts: " + "; ".join(
                            f"shard {sid}: {m.get('error', '?')}"
                            for sid, m in sorted(dead.items())))
                return manifests
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"remote job incomplete after {self.opts.timeout_s}s: "
                    f"{len(manifests)}/{n_shards} shards done "
                    f"(queue {job_dir})")
            time.sleep(self.opts.poll_s)

    # ---- record fetch + verification ----

    def _fetch(self, todo: Sequence[Scenario]) -> List[dict]:
        records = []
        with PROFILER.span("remote.collect"):
            for sc in todo:
                rec = self.cache.get(sc.key)
                if rec is None:
                    raise RuntimeError(
                        f"shard manifests complete but record {sc.key} "
                        f"({sc.tag}) is missing from the shared cache")
                records.append({**rec, "meta": dict(rec.get("meta", {}))})
        return records

    def _verify(self, todo: Sequence[Scenario], records: List[dict],
                stats: RemoteStats) -> None:
        """Re-run a sample of trace groups serially in-process and
        assert the workers' records are bit-identical (vectorized mode
        only — device-mode records carry the documented rtol instead)."""
        if not self.opts.verify_groups or self.mode != "vectorized":
            return
        from repro.sweep.vectorized import execute_scenario_group
        by_key = {sc.key: rec for sc, rec in zip(todo, records)}
        groups = group_by_trace(todo)
        for g in groups[:self.opts.verify_groups]:
            serial = execute_scenario_group([todo[i] for i in g])
            for rec in serial:
                remote_rec = by_key[rec["key"]]
                if rec["metrics"] != remote_rec["metrics"]:
                    raise AssertionError(
                        "remote record diverges from serial execution "
                        f"for {rec['scenario']} (key {rec['key']})")
            stats.verified_groups += 1
        self.note(f"verified {stats.verified_groups} trace group(s) "
                  "bit-identical to serial execution")

    # ---- the whole job ----

    def execute(self, todo: Sequence[Scenario]
                ) -> Tuple[List[dict], RemoteStats]:
        stats = RemoteStats()
        queue = self._queue_dir()
        job_dir, stats.shards, stats.trace_groups = self._publish(todo)
        procs = self._spawn(queue, job_dir)
        status = "failed"
        try:
            with PROFILER.span("remote.tail"):
                manifests = self._tail(job_dir, stats.shards, stats)
            status = "done"
        finally:
            # flip the job closed first so watch-mode workers stop
            # rescanning it, then reap our own convenience spawns
            meta = json.loads((job_dir / "job.json").read_text())
            meta["status"] = status
            atomic_write_json(job_dir / "job.json", meta)
            for p in procs:
                p.terminate()
            for p in procs:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()

        # merge the workers' wall-clock phase aggregates (cross-process
        # merge: counts and totals only — see repro.obs.spans) and
        # persist the merged profile next to the job for CI artifacts
        merged: Dict[str, Dict[str, float]] = {}
        workers = set()
        for m in manifests.values():
            workers.add(m.get("worker", "?"))
            for name, a in (m.get("phases") or {}).items():
                agg = merged.setdefault(name, {"count": 0, "total_s": 0.0})
                agg["count"] += int(a["count"])
                agg["total_s"] += float(a["total_s"])
        stats.workers = len(workers)
        if PROFILER.enabled and merged:
            PROFILER.merge(merged)
        atomic_write_json(job_dir / "profile.json", merged)

        records = self._fetch(todo)
        self._verify(todo, records, stats)
        atomic_write_json(job_dir / "stats.json",
                          dataclasses.asdict(stats))
        self.note(f"remote job complete: {stats.shards} shard(s) on "
                  f"{stats.workers} worker(s), {stats.lease_expired} "
                  f"expired lease(s), {stats.retried} retried, "
                  f"{stats.quarantined} quarantined")
        return records, stats
