"""Tidy result tables from sweep records.

A record (see ``runner.execute_scenario``) carries ``params`` (axis
values) and ``metrics`` (energy/carbon/latency columns). Flattening
merges both into one row per scenario — the tidy-data shape the
paper's figures and any downstream pandas/plotting code expect.
"""
from __future__ import annotations

import csv
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence


def flatten(records: Sequence[dict]) -> List[Dict[str, object]]:
    """One flat row per scenario: params first, then metrics."""
    rows = []
    for record in records:
        row: Dict[str, object] = {"scenario": record.get("scenario", "")}
        row.update(record.get("params", {}))
        row.update(record.get("metrics", {}))
        meta = record.get("meta", {})
        row["cache_hit"] = bool(meta.get("cache_hit", False))
        rows.append(row)
    return rows


# Per-workload-class scheduling columns (repro.schedule.metrics +
# admission stats): pinned into one contiguous, stably-ordered group in
# CSV/table output so shifting experiments read as tidy data even when
# mixed with non-fleet rows (absent values render empty via restval).
SCHEDULE_COLUMNS = [
    "n_interactive", "n_deferrable", "deferred_fraction", "n_deferred",
    "mean_deferral_delay_s", "max_deferral_delay_s", "backlog_peak",
    "interactive_ttft_p50_s", "interactive_ttft_p99_s",
    "interactive_e2e_p50_s", "interactive_e2e_p99_s",
    "deferrable_e2e_p50_s", "deferrable_e2e_p99_s",
    "interactive_slo_violations", "deadline_violations",
]


def _columns(rows: Sequence[Dict[str, object]]) -> List[str]:
    cols: List[str] = []
    for row in rows:
        for key in row:
            if key not in cols:
                cols.append(key)
    # group the per-class scheduling columns contiguously (in their
    # canonical order) at the position of the first one encountered;
    # cache_hit stays last
    sched = [c for c in SCHEDULE_COLUMNS if c in cols]
    if sched:
        first = min(cols.index(c) for c in sched)
        rest = [c for c in cols if c not in sched]
        cols = rest[:first] + sched + rest[first:]
    if "cache_hit" in cols:
        cols.remove("cache_hit")
        cols.append("cache_hit")
    return cols


def to_csv(records: Sequence[dict], path: Path) -> Path:
    rows = flatten(records)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=_columns(rows),
                                restval="")
        writer.writeheader()
        writer.writerows(rows)
    return path


def to_json(records: Sequence[dict], path: Path,
            derived: Optional[str] = None) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"records": list(records)}
    if derived is not None:
        payload["derived"] = derived
    path.write_text(json.dumps(payload, indent=1, default=str))
    return path


def format_table(records: Sequence[dict],
                 columns: Optional[Sequence[str]] = None,
                 max_width: int = 14) -> str:
    """Plain-text table for CLI output (one row per scenario record)."""
    return format_rows(flatten(records), columns=columns,
                       max_width=max_width)


def format_rows(rows: Sequence[Dict[str, object]],
                columns: Optional[Sequence[str]] = None,
                max_width: int = 14) -> str:
    """Plain-text table over already-flat rows."""
    if not rows:
        return "(no scenarios)"
    cols = list(columns) if columns else _columns(rows)

    def fmt(v) -> str:
        if isinstance(v, float):
            s = f"{v:.4g}"
        else:
            s = str(v)
        return s[:max_width]

    table = [[fmt(row.get(c, "")) for c in cols] for row in rows]
    widths = [max(len(c), *(len(t[i]) for t in table))
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for t in table:
        lines.append("  ".join(v.ljust(w) for v, w in zip(t, widths)))
    return "\n".join(lines)


def write_outputs(name: str, records: Sequence[dict], outdir: Path,
                  derived: Optional[str] = None) -> Dict[str, Path]:
    """Write ``<outdir>/<name>.csv`` and ``.json``; returns the paths."""
    outdir = Path(outdir)
    return {
        "csv": to_csv(records, outdir / f"{name}.csv"),
        "json": to_json(records, outdir / f"{name}.json", derived=derived),
    }
