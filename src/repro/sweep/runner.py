"""Scenario execution: serial or multiprocessing, cache-memoized.

``execute_scenario`` turns one ``Scenario`` into a flat record of the
paper's energy/carbon summary columns (Eq. 2-4) plus latency and
throughput. ``SweepRunner`` runs a list of scenarios, skipping every
one whose content hash is already in the ``ResultCache`` and fanning
the rest out over a process pool. Scenario seeds live inside the
config (``workload.seed``), so results are bit-identical between
serial and parallel execution and across re-runs.

Post-processors extend a scenario with derived analyses that need the
full ``SimResult`` (e.g. the Table 2 microgrid co-simulation); they are
addressed by name so records stay JSON/cache-friendly.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sweep.cache import ResultCache
from repro.sweep.grid import SCHEMA_VERSION, Scenario


# --------------------------------------------------------------------------
# post-processors: name -> fn(SimResult, scenario) -> extra metric columns
# --------------------------------------------------------------------------

def _post_microgrid_cosim(res, scenario: Scenario) -> Dict[str, float]:
    """Table 2 pipeline: stage log -> 1-min power signal placed on a
    diurnal window -> solar+battery microgrid co-sim (paper Table 1b)."""
    from repro.core import MicrogridConfig, PowerModel, Signal, run_cosim
    from repro.core.cosim import stages_to_load_signal
    from repro.core.datasets import (carbon_intensity_signal,
                                     ci_trace_signal, solar_signal)
    from repro.core.microgrid import BatteryConfig

    p = {"hours": 30.0, "start_hour": 8.0, "resolution_s": 60.0,
         "solar_capacity_w": 600.0, "cloudiness": 0.12, "solar_seed": 3,
         "ci_seed": 4, "ci_trace": None, "battery_capacity_wh": 100.0,
         "soc_init": 0.5, "soc_min": 0.2, "soc_max": 0.8}
    p.update(scenario.post_params)

    cfg = scenario.cfg
    pm = PowerModel(cfg.device)
    load = stages_to_load_signal(res.stages.start_s, res.stages.dur_s,
                                 res.stages.mfu, pm,
                                 n_devices=cfg.n_devices, pue=scenario.pue,
                                 resolution_s=p["resolution_s"])
    n_bins = int(p["hours"] * 3600.0 / p["resolution_s"])
    idle_w = pm.dev.p_idle * cfg.n_devices * scenario.pue
    vals = np.full(n_bins, idle_w)
    start_bin = int(p["start_hour"] * 3600.0 / p["resolution_s"])
    n_active = min(len(load.values), n_bins - start_bin)
    vals[start_bin:start_bin + n_active] = load.values[:n_active]
    times = np.arange(n_bins) * p["resolution_s"]
    load_sig = Signal(times, vals, interp="previous")

    solar = solar_signal(p["hours"], capacity_w=p["solar_capacity_w"],
                         seed=p["solar_seed"], cloudiness=p["cloudiness"])
    if p["ci_trace"]:       # named region (core.datasets.CI_TRACES)
        ci = ci_trace_signal(p["ci_trace"], p["hours"])
    else:
        ci = carbon_intensity_signal(p["hours"], seed=p["ci_seed"])
    grid_cfg = MicrogridConfig(battery=BatteryConfig(
        capacity_wh=p["battery_capacity_wh"], soc_init=p["soc_init"],
        soc_min=p["soc_min"], soc_max=p["soc_max"]))
    out = run_cosim(load_sig, solar, ci, grid_cfg)
    return {f"cosim_{k}": float(v) for k, v in out.metrics.items()}


POSTPROCESSORS: Dict[str, Callable] = {
    "microgrid_cosim": _post_microgrid_cosim,
}


# --------------------------------------------------------------------------
# single-scenario execution
# --------------------------------------------------------------------------

def _execute_fleet_scenario(scenario: Scenario) -> dict:
    """Fleet scenarios: run the multi-site simulation and report its
    per-site + fleet-total energy/carbon columns."""
    from repro.fleet import run_fleet_simulation

    if scenario.post is not None:
        raise ValueError(
            "fleet scenarios run their own per-site microgrid co-sim; "
            f"post-processor {scenario.post!r} is not supported")
    t0 = time.perf_counter()
    res = run_fleet_simulation(scenario.cfg)
    cfg = scenario.cfg
    return {
        "scenario": scenario.tag,
        "key": scenario.key,
        "params": dict(scenario.params),
        "metrics": res.summary(),
        "meta": {"schema": SCHEMA_VERSION,
                 "elapsed_s": time.perf_counter() - t0,
                 "model": cfg.model.name,
                 "device": cfg.device,
                 "n_devices": cfg.n_devices,
                 "pue": cfg.pue,
                 "post": None,
                 "router": cfg.router,
                 "policy": cfg.schedule.policy,
                 "forecaster": cfg.schedule.forecaster},
    }


def execute_scenario(scenario: Scenario) -> dict:
    """Run one scenario to a flat, JSON-able record."""
    from repro.core.carbon import emissions
    from repro.core.power import DEVICES
    from repro.fleet.config import FleetConfig
    from repro.sim import energy_report, run_simulation

    if isinstance(scenario.cfg, FleetConfig):
        return _execute_fleet_scenario(scenario)

    t0 = time.perf_counter()
    res = run_simulation(scenario.cfg)
    rep = energy_report(res, pue=scenario.pue)
    device = DEVICES[scenario.cfg.device]
    carbon = emissions(rep.energy_wh, rep.gpu_hours, device,
                       ci=scenario.grid_ci)
    stages = res.stages
    metrics = {
        "energy_wh": rep.energy_wh,
        "energy_kwh": rep.energy_wh / 1000.0,
        "avg_power_w": rep.avg_power_w,
        "peak_power_w": rep.peak_power_w,
        "avg_mfu": res.avg_mfu(),
        "duration_s": rep.duration_s,
        "gpu_hours": rep.gpu_hours,
        "throughput_qps": res.throughput_qps(),
        "n_stages": len(stages.dur_s),
        "avg_batch": float(np.mean(stages.batch_size))
        if len(stages.batch_size) else 0.0,
        "carbon_operational_g": carbon.operational_g,
        "carbon_embodied_g": carbon.embodied_g,
        "carbon_total_g": carbon.total_g,
        "grid_ci_g_per_kwh": scenario.grid_ci,
        **res.latency_stats(),
    }
    if scenario.post is not None:
        if scenario.post not in POSTPROCESSORS:
            raise KeyError(f"unknown post-processor {scenario.post!r}; "
                           f"have {sorted(POSTPROCESSORS)}")
        metrics.update(POSTPROCESSORS[scenario.post](res, scenario))
    return {
        "scenario": scenario.tag,
        "key": scenario.key,
        "params": dict(scenario.params),
        "metrics": metrics,
        "meta": {"schema": SCHEMA_VERSION,
                 "elapsed_s": time.perf_counter() - t0,
                 "model": scenario.cfg.model.name,
                 "device": scenario.cfg.device,
                 "n_devices": scenario.cfg.n_devices,
                 "pue": scenario.pue,
                 "post": scenario.post},
    }


# --------------------------------------------------------------------------
# sweep runner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SweepStats:
    total: int = 0
    executed: int = 0
    cache_hits: int = 0
    elapsed_s: float = 0.0
    workers: int = 1

    def summary(self) -> str:
        return (f"{self.total} scenarios: {self.executed} executed, "
                f"{self.cache_hits} cache hits, "
                f"{self.elapsed_s:.2f}s wall, {self.workers} worker(s)")


class SweepRunner:
    """Execute scenarios with memoization and optional process fan-out.

    ``workers > 1`` uses a spawn-context process pool (fork is unsafe
    once jax has started its threadpools). ``cache=None`` disables
    memoization entirely.
    """

    def __init__(self, cache: Optional[ResultCache] = None,
                 workers: int = 1):
        self.cache = cache
        self.workers = max(1, int(workers))

    @staticmethod
    def _rebind(record: dict, sc: Scenario) -> dict:
        """Content-addressing means a cached/shared record may come
        from another scenario with an identical config — rebind the
        tag/params to the requesting scenario (metrics are
        config-determined, presentation is not)."""
        record = dict(record)
        record["scenario"] = sc.tag
        record["params"] = dict(sc.params)
        record["meta"] = {**record.get("meta", {}), "cache_hit": True}
        return record

    def run(self, scenarios: Sequence[Scenario],
            progress: Optional[Callable[[str], None]] = None
            ) -> Tuple[List[dict], SweepStats]:
        t0 = time.perf_counter()
        note = progress or (lambda msg: None)
        records: List[Optional[dict]] = [None] * len(scenarios)
        stats = SweepStats(total=len(scenarios), workers=self.workers)

        misses: List[int] = []          # first index per uncached key
        dup_of: Dict[str, List[int]] = {}   # key -> later same-key idxs
        for i, sc in enumerate(scenarios):
            hit = self.cache.get(sc.key) if self.cache is not None else None
            if hit is not None:
                records[i] = self._rebind(hit, sc)
                stats.cache_hits += 1
            elif sc.key in dup_of:      # same config earlier in this run
                dup_of[sc.key].append(i)
                stats.cache_hits += 1
            else:
                dup_of[sc.key] = []
                misses.append(i)
        if stats.cache_hits:
            note(f"cache: {stats.cache_hits}/{len(scenarios)} hits")

        if misses:
            todo = [scenarios[i] for i in misses]
            if self.workers > 1 and len(todo) > 1:
                ctx = multiprocessing.get_context("spawn")
                n = min(self.workers, len(todo))
                note(f"executing {len(todo)} scenarios on {n} processes")
                with ProcessPoolExecutor(max_workers=n,
                                         mp_context=ctx) as pool:
                    fresh = list(pool.map(execute_scenario, todo))
            else:
                note(f"executing {len(todo)} scenarios serially")
                fresh = [execute_scenario(sc) for sc in todo]
            for i, record in zip(misses, fresh):
                record["meta"]["cache_hit"] = False
                records[i] = record
                stats.executed += 1
                if self.cache is not None:
                    self.cache.put(record["key"], record)
                for j in dup_of[scenarios[i].key]:
                    records[j] = self._rebind(record, scenarios[j])

        stats.elapsed_s = time.perf_counter() - t0
        return [r for r in records if r is not None], stats


def run_scenarios(scenarios: Sequence[Scenario], workers: int = 1,
                  cache: Optional[ResultCache] = None,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> Tuple[List[dict], SweepStats]:
    """One-call convenience wrapper around ``SweepRunner``."""
    return SweepRunner(cache=cache, workers=workers).run(scenarios, progress)
