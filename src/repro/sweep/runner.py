"""Scenario execution: serial or multiprocessing, cache-memoized.

``execute_scenario`` turns one ``Scenario`` into a flat record of the
paper's energy/carbon summary columns (Eq. 2-4) plus latency and
throughput. ``SweepRunner`` runs a list of scenarios, skipping every
one whose content hash is already in the ``ResultCache`` and fanning
the rest out over a process pool. Scenario seeds live inside the
config (``workload.seed``), so results are bit-identical between
serial and parallel execution and across re-runs.

Execution modes: ``"vectorized"`` (default) groups grid points that
share a simulation trace — identical config, differing only in the
scenario-level PUE / grid-CI / post-processor axes — runs the event
loop once per group, and evaluates the shared-trace axes as stacked
array passes (``repro.sweep.vectorized``); bit-identical to
``"event_loop"``, which executes every scenario through the loop.
``"device"`` additionally pads every trace group into one batched
tensor set and evaluates the roofline/energy/carbon passes as a single
jax program over the whole grid, with divergence analysis sharing
composition traces across device/TP/PP points where provably safe
(``repro.sweep.device``); equivalent to the numpy modes within the
documented ``DEVICE_MODE_RTOL``.

Post-processors extend a scenario with derived analyses that need the
full ``SimResult`` (e.g. the Table 2 microgrid co-simulation); they are
addressed by name so records stay JSON/cache-friendly.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.carbon import emissions
from repro.core.power import DEVICES
from repro.fleet.config import FleetConfig
from repro.obs.spans import PROFILER
from repro.sweep.cache import ResultCache
from repro.sweep.grid import SCHEMA_VERSION, Scenario

EXECUTION_MODES = ("vectorized", "event_loop", "device")
#: where cache-missed scenarios execute: in this process (pool) or on
#: detached workers over a shared-filesystem work queue (sweep.remote)
BACKENDS = ("local", "remote")


# --------------------------------------------------------------------------
# post-processors: name -> fn(SimResult, scenario) -> extra metric columns
# --------------------------------------------------------------------------

def _post_microgrid_cosim(res, scenario: Scenario) -> Dict[str, float]:
    """Table 2 pipeline: stage log -> 1-min power signal placed on a
    diurnal window -> solar+battery microgrid co-sim (paper Table 1b)."""
    from repro.core import MicrogridConfig, PowerModel, Signal, run_cosim
    from repro.core.cosim import stages_to_load_signal
    from repro.core.datasets import (carbon_intensity_signal,
                                     ci_trace_signal, solar_signal)
    from repro.core.microgrid import BatteryConfig

    p = {"hours": 30.0, "start_hour": 8.0, "resolution_s": 60.0,
         "solar_capacity_w": 600.0, "cloudiness": 0.12, "solar_seed": 3,
         "ci_seed": 4, "ci_trace": None, "battery_capacity_wh": 100.0,
         "soc_init": 0.5, "soc_min": 0.2, "soc_max": 0.8}
    p.update(scenario.post_params)

    cfg = scenario.cfg
    pm = PowerModel(cfg.device)
    load = stages_to_load_signal(res.stages.start_s, res.stages.dur_s,
                                 res.stages.mfu, pm,
                                 n_devices=cfg.n_devices, pue=scenario.pue,
                                 resolution_s=p["resolution_s"])
    n_bins = int(p["hours"] * 3600.0 / p["resolution_s"])
    idle_w = pm.dev.p_idle * cfg.n_devices * scenario.pue
    vals = np.full(n_bins, idle_w)
    start_bin = int(p["start_hour"] * 3600.0 / p["resolution_s"])
    n_active = min(len(load.values), n_bins - start_bin)
    vals[start_bin:start_bin + n_active] = load.values[:n_active]
    times = np.arange(n_bins) * p["resolution_s"]
    load_sig = Signal(times, vals, interp="previous")

    solar = solar_signal(p["hours"], capacity_w=p["solar_capacity_w"],
                         seed=p["solar_seed"], cloudiness=p["cloudiness"])
    if p["ci_trace"]:       # named region (core.datasets.CI_TRACES)
        ci = ci_trace_signal(p["ci_trace"], p["hours"])
    else:
        ci = carbon_intensity_signal(p["hours"], seed=p["ci_seed"])
    grid_cfg = MicrogridConfig(battery=BatteryConfig(
        capacity_wh=p["battery_capacity_wh"], soc_init=p["soc_init"],
        soc_min=p["soc_min"], soc_max=p["soc_max"]))
    out = run_cosim(load_sig, solar, ci, grid_cfg)
    return {f"cosim_{k}": float(v) for k, v in out.metrics.items()}


POSTPROCESSORS: Dict[str, Callable] = {
    "microgrid_cosim": _post_microgrid_cosim,
}


# --------------------------------------------------------------------------
# single-scenario execution
# --------------------------------------------------------------------------

def _execute_fleet_scenario(scenario: Scenario, probe=None) -> dict:
    """Fleet scenarios: run the multi-site simulation and report its
    per-site + fleet-total energy/carbon columns. Configs carrying a
    ``DayConfig`` dispatch to the epoch-segmented day driver
    (``repro.fleet.day``) — fluid/request hybrid or exact per
    ``day.mode``."""
    from repro.fleet.day import run_fleet_day
    from repro.fleet.simulation import run_fleet_simulation

    if scenario.post is not None:
        raise ValueError(
            "fleet scenarios run their own per-site microgrid co-sim; "
            f"post-processor {scenario.post!r} is not supported")
    t0 = time.perf_counter()
    if probe is not None:
        probe.on_run_begin(scenario.tag)
    if scenario.cfg.day is not None:
        with PROFILER.span("sim.fleet_day"):
            res = run_fleet_day(scenario.cfg, probe=probe)
    else:
        with PROFILER.span("sim.fleet"):
            res = run_fleet_simulation(scenario.cfg, probe=probe)
    cfg = scenario.cfg
    meta = {"schema": SCHEMA_VERSION,
            "elapsed_s": time.perf_counter() - t0,
            "model": cfg.model.name,
            "device": cfg.device,
            "n_devices": cfg.n_devices,
            "pue": cfg.pue,
            "post": None,
            "router": cfg.router,
            "policy": cfg.schedule.policy,
            "forecaster": cfg.schedule.forecaster}
    if cfg.day is not None:
        meta["day_mode"] = cfg.day.mode
    return {
        "scenario": scenario.tag,
        "key": scenario.key,
        "params": dict(scenario.params),
        "metrics": res.summary(),
        "meta": meta,
    }


# result-only columns interleaved into the record head; the rest of
# shared_result_metrics() (latency percentiles) lands after carbon
_SHARED_HEAD = ("avg_mfu", "throughput_qps", "n_stages", "avg_batch")


def shared_result_metrics(res) -> Dict[str, float]:
    """The metric columns that depend only on the ``SimResult`` — in
    the vectorized mode a whole trace group computes these once."""
    stages = res.stages
    return {
        "avg_mfu": res.avg_mfu(),
        "throughput_qps": res.throughput_qps(),
        "n_stages": len(stages.dur_s),
        "avg_batch": float(np.mean(stages.batch_size))
        if len(stages.batch_size) else 0.0,
        **res.latency_stats(),
    }


def single_site_metrics(res, scenario: Scenario, rep, carbon=None,
                        shared=None) -> Dict[str, float]:
    """Assemble one scenario's metric columns from a (possibly shared)
    ``SimResult`` and its Eq. 2-3 energy report. Both execution modes
    go through this, so their records agree bit-for-bit. ``carbon``
    and ``shared`` accept precomputed pieces (the vectorized mode's
    stacked CI pass / per-group result metrics); None computes them
    here."""
    if carbon is None:
        carbon = emissions(rep.energy_wh, rep.gpu_hours,
                           DEVICES[scenario.cfg.device],
                           ci=scenario.grid_ci)
    if shared is None:
        shared = shared_result_metrics(res)
    metrics = {
        "energy_wh": rep.energy_wh,
        "energy_kwh": rep.energy_wh / 1000.0,
        "avg_power_w": rep.avg_power_w,
        "peak_power_w": rep.peak_power_w,
        "avg_mfu": shared["avg_mfu"],
        "duration_s": rep.duration_s,
        "gpu_hours": rep.gpu_hours,
        "throughput_qps": shared["throughput_qps"],
        "n_stages": shared["n_stages"],
        "avg_batch": shared["avg_batch"],
        "carbon_operational_g": carbon.operational_g,
        "carbon_embodied_g": carbon.embodied_g,
        "carbon_total_g": carbon.total_g,
        "grid_ci_g_per_kwh": scenario.grid_ci,
        **{k: v for k, v in shared.items() if k not in _SHARED_HEAD},
    }
    if scenario.post is not None:
        if scenario.post not in POSTPROCESSORS:
            raise KeyError(f"unknown post-processor {scenario.post!r}; "
                           f"have {sorted(POSTPROCESSORS)}")
        metrics.update(POSTPROCESSORS[scenario.post](res, scenario))
    return metrics


def single_site_record(scenario: Scenario, metrics: Dict[str, float],
                       t0: float, **meta) -> dict:
    return {
        "scenario": scenario.tag,
        "key": scenario.key,
        "params": dict(scenario.params),
        "metrics": metrics,
        "meta": {"schema": SCHEMA_VERSION,
                 "elapsed_s": time.perf_counter() - t0,
                 "model": scenario.cfg.model.name,
                 "device": scenario.cfg.device,
                 "n_devices": scenario.cfg.n_devices,
                 "pue": scenario.pue,
                 "post": scenario.post,
                 **meta},
    }


def execute_scenario(scenario: Scenario, probe=None) -> dict:
    """Run one scenario to a flat, JSON-able record (event-loop path).

    ``probe`` (``repro.obs.Probe``) observes the simulation and, for
    single-site scenarios, receives the Eq. 1-5 rollup inputs (this
    layer knows the scenario's PUE and grid CI); records stay bitwise
    identical either way."""
    from repro.sim import energy_report, run_simulation

    if isinstance(scenario.cfg, FleetConfig):
        return _execute_fleet_scenario(scenario, probe=probe)

    t0 = time.perf_counter()
    if probe is not None:
        probe.on_run_begin(scenario.tag)
    with PROFILER.span("sim.event_loop"):
        res = run_simulation(scenario.cfg, probe=probe)
    rep = energy_report(res, pue=scenario.pue)
    if probe is not None:
        probe.on_site_rollup(
            site=0, name=scenario.tag, trace=res.stages,
            device=scenario.cfg.device,
            row_devices=scenario.cfg.n_devices, pue=scenario.pue,
            ci=scenario.grid_ci,
            total_devices=scenario.cfg.n_devices,
            energy_wh=rep.energy_wh)
    return single_site_record(scenario, single_site_metrics(res, scenario, rep),
                              t0)


# --------------------------------------------------------------------------
# sweep runner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SweepStats:
    total: int = 0
    executed: int = 0
    cache_hits: int = 0
    elapsed_s: float = 0.0
    workers: int = 1
    mode: str = "vectorized"
    trace_groups: int = 0     # unique simulation traces actually driven
    event_loops: int = 0      # device mode: groups run through the loop
    replayed: int = 0         # device mode: groups shared via divergence
    # ResultCache effectiveness over this run (lookup-phase deltas);
    # cache_attached distinguishes a no-cache run from an all-miss one
    cache_attached: bool = False
    cache_memo: int = 0       # hits served from the in-process memo
    cache_disk: int = 0       # hits parsed off disk
    cache_miss: int = 0       # keys with no cached record
    peak_rss_mb: float = 0.0  # process tree high-water RSS (0 off-POSIX)
    # remote backend (sweep.remote): shard-queue observables
    backend: str = "local"
    shards: int = 0
    remote_workers: int = 0   # distinct workers seen in manifests
    lease_expired: int = 0
    retried: int = 0
    quarantined: int = 0

    def summary(self) -> str:
        groups = (f", {self.trace_groups} trace group(s)"
                  if self.mode in ("vectorized", "device") and self.executed
                  else "")
        shared = (f" ({self.event_loops} event loop(s), "
                  f"{self.replayed} replayed)"
                  if self.mode == "device" and self.executed else "")
        eff = (f", cache {self.cache_memo} memo / {self.cache_disk} disk"
               f" / {self.cache_miss} miss"
               if self.cache_attached else "")
        rss = (f", peak RSS {self.peak_rss_mb:.0f} MB"
               if self.peak_rss_mb else "")
        rem = (f", remote: shards={self.shards} "
               f"workers={self.remote_workers} "
               f"expired={self.lease_expired} retried={self.retried} "
               f"quarantined={self.quarantined}"
               if self.backend == "remote" and self.executed else "")
        return (f"{self.total} scenarios: {self.executed} executed, "
                f"{self.cache_hits} cache hits, "
                f"{self.elapsed_s:.2f}s wall, {self.workers} worker(s)"
                f"{groups}{shared}{eff}{rss}{rem}")


def _peak_rss_mb() -> float:
    """Process-tree high-water RSS in MB (``ru_maxrss`` is KB on
    Linux): the max of this process and its reaped children, so
    multiprocessing sweeps report the pool workers' footprint rather
    than just the coordinator's. 0.0 where ``resource`` is
    unavailable."""
    try:
        import resource
    except ImportError:
        return 0.0
    own = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    kids = resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss
    return max(own, kids) / 1024.0


class SweepRunner:
    """Execute scenarios with memoization and optional process fan-out.

    ``mode="vectorized"`` (default) groups uncached scenarios by their
    config digest and drives the event loop once per unique trace,
    fanning *groups* out over workers; ``mode="event_loop"`` executes
    every scenario independently (the historical behavior). Both modes
    produce bit-identical records (pinned by tests/test_vectorized.py).
    ``mode="device"`` evaluates all groups in one batched jax program
    (always in-process — the single dispatch IS the parallelism) and
    matches the numpy modes within ``device.DEVICE_MODE_RTOL`` (pinned
    by tests/test_device_mode.py).

    ``workers > 1`` uses a spawn-context process pool (fork is unsafe
    once jax has started its threadpools). ``cache=None`` disables
    memoization entirely.

    ``probe`` attaches a ``repro.obs.Probe`` to every *executed*
    scenario (cache hits never re-simulate, so they record nothing) —
    stack several with ``repro.obs.MultiProbe`` (e.g. a
    ``FlightRecorder`` plus an ``AuditProbe``). A probe forces serial
    in-process execution — probes are process-local state — and is
    rejected in device mode, whose batched program has no
    event-per-stage structure to observe.

    ``backend="remote"`` ships cache-missed trace groups to detached
    ``repro.sweep.worker`` processes through a shared-filesystem work
    queue (``repro.sweep.remote``): the workers write records straight
    into the shared cache and the coordinator reads them back, so a
    cache is mandatory and the records are bit-identical to local
    vectorized execution. ``remote`` takes a ``RemoteOptions``; probes
    are process-local and therefore rejected.
    """

    def __init__(self, cache: Optional[ResultCache] = None,
                 workers: int = 1, mode: str = "vectorized",
                 probe=None, backend: str = "local", remote=None):
        if mode not in EXECUTION_MODES:
            raise ValueError(f"unknown mode {mode!r}; have "
                             f"{EXECUTION_MODES}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; have "
                             f"{BACKENDS}")
        if probe is not None and mode == "device":
            raise ValueError(
                "probe recording is not supported in device mode (the "
                "batched grid program exposes no per-stage events); "
                "use mode='vectorized' or 'event_loop'")
        if backend == "remote":
            if cache is None:
                raise ValueError(
                    "backend='remote' requires a ResultCache — the "
                    "shared cache is how workers return records")
            if probe is not None:
                raise ValueError(
                    "probe recording is not supported on the remote "
                    "backend (probes are process-local state)")
            if mode == "event_loop":
                raise ValueError(
                    "the remote backend ships whole trace groups; use "
                    "mode='vectorized' (exact) or 'device'")
        self.cache = cache
        self.workers = max(1, int(workers))
        self.mode = mode
        self.probe = probe
        self.backend = backend
        self.remote = remote

    @staticmethod
    def _rebind(record: dict, sc: Scenario) -> dict:
        """Content-addressing means a cached/shared record may come
        from another scenario with an identical config — rebind the
        tag/params to the requesting scenario (metrics are
        config-determined, presentation is not)."""
        record = dict(record)
        record["scenario"] = sc.tag
        record["params"] = dict(sc.params)
        record["meta"] = {**record.get("meta", {}), "cache_hit": True}
        return record

    def run(self, scenarios: Sequence[Scenario],
            progress: Optional[Callable[[str], None]] = None
            ) -> Tuple[List[dict], SweepStats]:
        t0 = time.perf_counter()
        note = progress or (lambda msg: None)
        records: List[Optional[dict]] = [None] * len(scenarios)
        stats = SweepStats(total=len(scenarios), workers=self.workers,
                           mode=self.mode, backend=self.backend,
                           cache_attached=self.cache is not None)

        c0 = dict(self.cache.counters) if self.cache is not None else {}
        misses: List[int] = []          # first index per uncached key
        dup_of: Dict[str, List[int]] = {}   # key -> later same-key idxs
        with PROFILER.span("cache.lookup"):
            for i, sc in enumerate(scenarios):
                hit = (self.cache.get(sc.key)
                       if self.cache is not None else None)
                if hit is not None:
                    records[i] = self._rebind(hit, sc)
                    stats.cache_hits += 1
                elif sc.key in dup_of:  # same config earlier in this run
                    dup_of[sc.key].append(i)
                    stats.cache_hits += 1
                else:
                    dup_of[sc.key] = []
                    misses.append(i)
        if self.cache is not None:
            c1 = self.cache.counters
            stats.cache_memo = c1["memo"] - c0["memo"]
            stats.cache_disk = c1["disk"] - c0["disk"]
            stats.cache_miss = c1["miss"] - c0["miss"]
        if stats.cache_hits:
            note(f"cache: {stats.cache_hits}/{len(scenarios)} hits")

        if misses:
            todo = [scenarios[i] for i in misses]
            if self.backend == "remote":
                fresh = self._run_remote(todo, note, stats)
            elif self.mode == "vectorized":
                fresh, stats.trace_groups = self._run_vectorized(todo, note)
            elif self.mode == "device":
                fresh = self._run_device(todo, note, stats)
            else:
                fresh = self._run_event_loop(todo, note)
            with PROFILER.span("cache.store"):
                for i, record in zip(misses, fresh):
                    record["meta"]["cache_hit"] = False
                    records[i] = record
                    stats.executed += 1
                    # remote workers already persisted their records
                    # into the shared cache — re-putting them here
                    # would only re-serialize identical bytes
                    if self.cache is not None and self.backend != "remote":
                        self.cache.put(record["key"], record)
                    for j in dup_of[scenarios[i].key]:
                        records[j] = self._rebind(record, scenarios[j])

        stats.elapsed_s = time.perf_counter() - t0
        stats.peak_rss_mb = _peak_rss_mb()
        return [r for r in records if r is not None], stats

    # ---- execution backends over the cache-missed scenarios ----

    def _run_event_loop(self, todo: List[Scenario], note) -> List[dict]:
        if self.probe is None and self.workers > 1 and len(todo) > 1:
            ctx = multiprocessing.get_context("spawn")
            n = min(self.workers, len(todo))
            note(f"executing {len(todo)} scenarios on {n} processes")
            with PROFILER.span("pool.event_loop"), \
                    ProcessPoolExecutor(max_workers=n,
                                        mp_context=ctx) as pool:
                if PROFILER.enabled:
                    outs = list(pool.map(_execute_scenario_profiled, todo))
                    for _, agg in outs:
                        PROFILER.merge(agg)
                    return [rec for rec, _ in outs]
                return list(pool.map(execute_scenario, todo))
        note(f"executing {len(todo)} scenarios serially")
        return [execute_scenario(sc, probe=self.probe) for sc in todo]

    def _run_vectorized(self, todo: List[Scenario], note
                        ) -> Tuple[List[dict], int]:
        from repro.sweep.vectorized import (execute_scenario_group,
                                            execute_scenario_group_profiled,
                                            group_by_trace)
        with PROFILER.span("trace_grouping"):
            groups = group_by_trace(todo)
        group_scs = [[todo[j] for j in g] for g in groups]
        if self.probe is None and self.workers > 1 and len(group_scs) > 1:
            from repro.sweep.vectorized import estimate_group_cost
            ctx = multiprocessing.get_context("spawn")
            n = min(self.workers, len(group_scs))
            note(f"executing {len(todo)} scenarios as {len(groups)} "
                 f"trace group(s) on {n} processes")
            # submit heaviest groups first (LPT order, chunksize 1):
            # group_by_trace yields wildly unbalanced groups, and FIFO
            # submission can strand the biggest trace on the last
            # worker while the rest idle
            order = sorted(range(len(group_scs)),
                           key=lambda i: (-estimate_group_cost(
                               group_scs[i]), i))
            ordered = [group_scs[i] for i in order]
            with PROFILER.span("pool.vectorized"), \
                    ProcessPoolExecutor(max_workers=n,
                                        mp_context=ctx) as pool:
                if PROFILER.enabled:
                    outs = list(pool.map(execute_scenario_group_profiled,
                                         ordered, chunksize=1))
                    for _, agg in outs:
                        PROFILER.merge(agg)
                    ordered_recs = [recs for recs, _ in outs]
                else:
                    ordered_recs = list(pool.map(execute_scenario_group,
                                                 ordered, chunksize=1))
            per_group: List[Optional[List[dict]]] = [None] * len(group_scs)
            for pos, recs in zip(order, ordered_recs):
                per_group[pos] = recs
        else:
            note(f"executing {len(todo)} scenarios as {len(groups)} "
                 f"trace group(s) serially")
            per_group = [execute_scenario_group(g, probe=self.probe)
                         for g in group_scs]
        fresh: List[Optional[dict]] = [None] * len(todo)
        for idxs, recs in zip(groups, per_group):
            for j, rec in zip(idxs, recs):
                fresh[j] = rec
        return fresh, len(groups)

    def _run_remote(self, todo: List[Scenario], note,
                    stats: SweepStats) -> List[dict]:
        from repro.sweep.remote import RemoteCoordinator
        coord = RemoteCoordinator(self.cache, opts=self.remote,
                                  mode=self.mode, note=note)
        with PROFILER.span("remote.execute"):
            fresh, rstats = coord.execute(todo)
        stats.trace_groups = rstats.trace_groups
        stats.shards = rstats.shards
        stats.remote_workers = rstats.workers
        stats.lease_expired = rstats.lease_expired
        stats.retried = rstats.retried
        stats.quarantined = rstats.quarantined
        return fresh

    def _run_device(self, todo: List[Scenario], note,
                    stats: SweepStats) -> List[dict]:
        from repro.sweep.device import execute_device_grid
        note(f"executing {len(todo)} scenarios as one device-batched "
             "grid program")
        with PROFILER.span("device.grid"):
            fresh, dstats = execute_device_grid(todo)
        stats.trace_groups = dstats.trace_groups
        stats.event_loops = dstats.event_loops
        stats.replayed = dstats.replayed
        return fresh


def _execute_scenario_profiled(sc: Scenario) -> Tuple[dict, dict]:
    """Pool target for profiled event-loop fan-out: runs one scenario
    under the worker-local ``PROFILER`` and ships the per-phase
    aggregate back for the parent's ``merge()``."""
    PROFILER.enable(reset=True)
    try:
        rec = execute_scenario(sc)
    finally:
        PROFILER.disable()
    return rec, PROFILER.aggregate()


def run_scenarios(scenarios: Sequence[Scenario], workers: int = 1,
                  cache: Optional[ResultCache] = None,
                  progress: Optional[Callable[[str], None]] = None,
                  mode: str = "vectorized", probe=None,
                  backend: str = "local", remote=None
                  ) -> Tuple[List[dict], SweepStats]:
    """One-call convenience wrapper around ``SweepRunner``."""
    return SweepRunner(cache=cache, workers=workers, mode=mode,
                       probe=probe, backend=backend,
                       remote=remote).run(scenarios, progress)
