"""The paper's seven experiments as declarative sweep definitions.

Each ``SweepDef`` is a thin grid declaration (base config + axes) plus
a ``derive`` function that checks the paper's headline claims against
the sweep records. ``--smoke`` variants shrink request counts and grid
resolution so every figure's full pipeline runs in seconds — that is
what CI exercises on every push.

The benchmark scripts under ``benchmarks/`` are wrappers over this
registry; ``python -m repro.sweep.cli`` drives it directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.sim import INTEGRATION_DEFAULT, PAPER_DEFAULT
from repro.sweep.grid import GridSpec, Scenario
from repro.sweep.report import flatten


@dataclasses.dataclass
class SweepDef:
    name: str
    title: str
    build: Callable[..., List[Scenario]]   # build(smoke, n_requests=None)
    derive: Callable[[List[dict]], str]    # records -> paper-claim summary
    rows: Optional[Callable[[List[dict]], list]] = None  # default: flatten

    def make_rows(self, records: List[dict]) -> list:
        return (self.rows or flatten)(records)


def _rows_by(records: List[dict], key: str) -> List[dict]:
    return sorted(flatten(records), key=lambda r: r[key])


# ---------------------------------------------------------------- fig1 ----

def _fig1_build(smoke: bool, n_requests: Optional[int] = None):
    qps = [1.0, 6.45, 10.0] if smoke else [0.5, 1.0, 2.0, 3.0, 5.0, 6.45,
                                           7.9, 10.0, 12.6]
    n = n_requests or (48 if smoke else 512)
    return GridSpec(base=PAPER_DEFAULT, tag="fig1",
                    axes={"workload.qps": qps},
                    fixed={"workload.n_requests": n}).expand()


def _fig1_derive(records: List[dict]) -> str:
    rows = _rows_by(records, "qps")
    sat = [r["avg_mfu"] for r in rows if 5.0 <= r["qps"] <= 7.9]
    return (f"mfu@5-7.9qps={min(sat):.3f}-{max(sat):.3f}"
            f";paper=saturates~0.45")


# ---------------------------------------------------------------- fig2 ----

_FIG2_MODELS = [("phi2-2.7b", 1, 1), ("llama3-8b", 1, 1),
                ("codellama-34b", 1, 1), ("llama3-70b", 2, 2),
                ("qwen-72b", 2, 2)]
_FIG2_SMALL = {"phi2-2.7b", "llama3-8b", "codellama-34b"}


def _fig2_build(smoke: bool, n_requests: Optional[int] = None):
    models = _FIG2_MODELS[:2] if smoke else _FIG2_MODELS
    counts = (48, 96) if smoke else (256, 1024, 4096)
    if n_requests:
        # distinct counts, never exceeding the requested cap, so the
        # energy-vs-count fit stays well-posed
        counts = sorted({max(1, n_requests // f) for f in (4, 2, 1)})
    return GridSpec(base=PAPER_DEFAULT, tag="fig2",
                    axes={"model+tp+pp": models,
                          "workload.n_requests": list(counts)}).expand()


def _fig2_extrapolations(records: List[dict]) -> Dict[str, dict]:
    """Linear energy-in-request-count fit, extrapolated to 2^16."""
    by_model: Dict[str, List[dict]] = {}
    for r in flatten(records):
        by_model.setdefault(r["model"], []).append(r)
    extr = {}
    for model, rs in by_model.items():
        rs = sorted(rs, key=lambda r: r["n_requests"])
        counts = [r["n_requests"] for r in rs]
        energies = [r["energy_wh"] for r in rs]
        if len(set(counts)) >= 2:
            slope = float(np.polyfit(counts, energies, 1)[0])
        else:
            slope = energies[-1] / max(counts[-1], 1)
        extr[model] = {"model": model, "n_requests": 65536,
                       "energy_wh": slope * 65536, "extrapolated": True,
                       "avg_power_w": float(np.mean(
                           [r["avg_power_w"] for r in rs]))}
    return extr


def _fig2_rows(records: List[dict]) -> list:
    return flatten(records) + list(_fig2_extrapolations(records).values())


def _fig2_derive(records: List[dict]) -> str:
    rows = flatten(records)
    small = [r for r in rows if r["model"] in _FIG2_SMALL]
    big = [r for r in rows if r["model"] not in _FIG2_SMALL]
    extr = _fig2_extrapolations(records)
    parts = []
    if small:
        parts.append(f"P_small={min(x['avg_power_w'] for x in small):.0f}-"
                     f"{max(x['avg_power_w'] for x in small):.0f}W"
                     f"(paper:135-155)")
    if big:
        parts.append(f"P_big={min(x['avg_power_w'] for x in big):.0f}-"
                     f"{max(x['avg_power_w'] for x in big):.0f}W"
                     f"(paper:125-127)")
    if "codellama-34b" in extr:
        parts.append(f"E64k_34b={extr['codellama-34b']['energy_wh']/1e3:.1f}"
                     f"kWh(paper~16)")
    if "llama3-70b" in extr:
        parts.append(f"E64k_70b={extr['llama3-70b']['energy_wh']/1e3:.1f}"
                     f"kWh(paper>80)")
    return ";".join(parts)


# ---------------------------------------------------------------- fig3 ----

def _fig3_build(smoke: bool, n_requests: Optional[int] = None):
    lengths = [128, 1024] if smoke else [128, 512, 1024, 4096]
    pds = [20.0, 0.1] if smoke else [50.0, 10.0, 2.0, 1.0, 0.5, 0.1, 0.02]
    n = n_requests or (32 if smoke else 256)
    return GridSpec(
        base=PAPER_DEFAULT, tag="fig3",
        axes={"workload.min_len+workload.max_len": [(L, L) for L in lengths],
              "workload.pd_ratio": pds},
        fixed={"workload.n_requests": n}).expand()


def _fig3_derive(records: List[dict]) -> str:
    rows = flatten(records)
    lengths = sorted({r["min_len"] for r in rows})
    e_by_len = {L: sum(r["energy_wh"] for r in rows if r["min_len"] == L)
                for L in lengths}
    mono = all(e_by_len[lengths[i]] < e_by_len[lengths[i + 1]]
               for i in range(len(lengths) - 1))
    longest = [r for r in rows if r["min_len"] == lengths[-1]]
    # pd_ratio axis runs prefill-heavy -> decode-heavy
    decode_heavier = longest[-1]["energy_wh"] > longest[0]["energy_wh"]
    return (f"energy_monotonic_in_length={mono}(paper:yes);"
            f"decode_heavy_costs_more_at_{lengths[-1]}="
            f"{decode_heavier}(paper:yes)")


# ---------------------------------------------------------------- fig4 ----

def _fig4_build(smoke: bool, n_requests: Optional[int] = None):
    caps = [1, 8, 32] if smoke else [1, 2, 4, 8, 16, 32, 64, 128]
    n = n_requests or (48 if smoke else 256)
    return GridSpec(base=PAPER_DEFAULT, tag="fig4",
                    axes={"scheduler.batch_cap": caps},
                    fixed={"workload.qps": 50.0,
                           "workload.n_requests": n}).expand()


def _fig4_derive(records: List[dict]) -> str:
    rows = _rows_by(records, "batch_cap")
    sub = all(r["avg_batch"] <= r["batch_cap"] for r in rows)
    power_up = rows[-1]["avg_power_w"] > rows[0]["avg_power_w"]
    energy_down = rows[-1]["energy_wh"] < rows[0]["energy_wh"]
    mid = min(rows, key=lambda r: abs(r["batch_cap"] - 16))
    gain_lo = rows[0]["energy_wh"] / mid["energy_wh"]
    gain_hi = mid["energy_wh"] / rows[-1]["energy_wh"]
    return (f"batch_sublinear={sub};power_rises={power_up}(paper:yes);"
            f"energy_drops={energy_down}(paper:yes);"
            f"gain{rows[0]['batch_cap']}->{mid['batch_cap']}={gain_lo:.1f}x;"
            f"gain{mid['batch_cap']}->{rows[-1]['batch_cap']}={gain_hi:.2f}x"
            f"(paper:diminishing past 16)")


# ---------------------------------------------------------------- fig5 ----

def _fig5_build(smoke: bool, n_requests: Optional[int] = None):
    qps = [1.0, 5.0, 10.0] if smoke else [0.5, 1.0, 2.0, 3.2, 5.0, 7.9,
                                          10.0, 12.6]
    n = n_requests or (64 if smoke else 2048)
    return GridSpec(base=PAPER_DEFAULT, tag="fig5",
                    axes={"workload.qps": qps,
                          "workload.n_requests": [n]}).expand()


def _fig5_derive(records: List[dict]) -> str:
    rows = _rows_by(records, "qps")
    n = rows[0]["n_requests"]
    p_sat = [r["avg_power_w"] for r in rows if r["qps"] >= 5.0]
    e_hi = [r["energy_wh"] for r in rows if r["qps"] >= 7.9] or \
           [rows[-1]["energy_wh"]]
    scale = n / 16384
    return (f"P_sat={min(p_sat):.0f}-{max(p_sat):.0f}W(paper:~360);"
            f"E_converged={min(e_hi):.1f}Wh"
            f"(paper~{500 * scale:.0f}Wh at this workload scale)")


# ---------------------------------------------------------------- exp5 ----

def _exp5_build(smoke: bool, n_requests: Optional[int] = None):
    grid = [(1, 1), (2, 1), (1, 2)] if smoke else \
        [(1, 1), (1, 2), (1, 4), (2, 1), (2, 2), (2, 4), (4, 1), (4, 2),
         (4, 4)]
    n = n_requests or (32 if smoke else 256)
    return GridSpec(base=PAPER_DEFAULT, tag="exp5",
                    axes={"tp+pp": grid},
                    fixed={"model": "codellama-34b",
                           "workload.qps": 3.0,
                           "workload.n_requests": n}).expand()


def _exp5_derive(records: List[dict]) -> str:
    rows = flatten(records)
    best = min(rows, key=lambda r: r["energy_wh"])
    pmax = max(rows, key=lambda r: r["avg_power_w"])
    return (f"P_range={min(r['avg_power_w'] for r in rows):.0f}-"
            f"{max(r['avg_power_w'] for r in rows):.0f}W"
            f"(paper:213-355);peak_at=TP{pmax['tp']}PP{pmax['pp']}"
            f"(paper:TP2PP1);best=TP{best['tp']}PP{best['pp']}"
            f"(paper:TP2PP1 or TP1PP2)")


# --------------------------------------------------------------- table2 ---

def _table2_build(smoke: bool, n_requests: Optional[int] = None):
    """Paper deviation (documented in EXPERIMENTS.md §Repro): the stated
    20 QPS on one A100 exceeds the device's peak FLOP/s by ~1.6x for
    this workload; we reproduce the co-sim at 85% of OUR max QPS (5.5),
    preserving the 5.5 h saturated-burst shape and total energy of the
    paper's Table 2."""
    n = n_requests or (1500 if smoke else 110_000)
    return GridSpec(
        base=INTEGRATION_DEFAULT, tag="table2",
        axes={"workload.n_requests": [n]},
        fixed={"workload.qps": 5.5},
        post="microgrid_cosim",
        post_params={"hours": 30.0}).expand()


def _table2_derive(records: List[dict]) -> str:
    m = records[0]["metrics"]
    return (f"renewable_share={m['cosim_renewable_share_pct']:.1f}%"
            f"(paper:70.3);offset={m['cosim_carbon_offset_pct']:.1f}%"
            f"(paper:69.2);E={m['cosim_total_energy_kwh']:.2f}kWh"
            f"(paper:5.90);"
            f"net={m['cosim_net_emissions_kg'] * 1000:.0f}g(paper:759)")


def _table2_rows(records: List[dict]) -> list:
    return {k[len("cosim_"):]: v
            for k, v in records[0]["metrics"].items()
            if k.startswith("cosim_")}


# --------------------------------------------------------------- carbon ---

def _carbon_build(smoke: bool, n_requests: Optional[int] = None):
    """ROADMAP "carbon-aware sweep scenarios": grid CI trace, solar
    capacity and battery sizing as post-processor axes over the
    single-site microgrid co-sim (same Eq. 5 -> co-sim pipeline as
    table2, swept instead of fixed at the paper's Table 1b point)."""
    n = n_requests or (400 if smoke else 20_000)
    traces = ["hydro", "caiso"] if smoke else ["hydro", "wind", "caiso",
                                               "coal"]
    solar = [0.0, 600.0] if smoke else [0.0, 300.0, 600.0, 1200.0]
    batt = [100.0] if smoke else [0.0, 100.0, 400.0]
    return GridSpec(
        base=PAPER_DEFAULT, tag="carbon",
        axes={"post.ci_trace": traces,
              "post.solar_capacity_w": solar,
              "post.battery_capacity_wh": batt},
        fixed={"workload.n_requests": n, "workload.qps": 5.0},
        post="microgrid_cosim",
        # full diurnal window: the load lands at start_hour=8 inside
        # the solar day, so the solar/battery axes actually bite
        post_params={"hours": 24.0}).expand()


def _carbon_derive(records: List[dict]) -> str:
    rows = flatten(records)
    by_trace: Dict[str, List[float]] = {}
    for r in rows:
        by_trace.setdefault(r["ci_trace"], []).append(
            r["cosim_net_emissions_kg"])
    order = sorted(by_trace, key=lambda t: float(np.mean(by_trace[t])))
    solar_off = [r["cosim_net_emissions_kg"] for r in rows
                 if r["solar_capacity_w"] == 0.0]
    solar_on = [r["cosim_net_emissions_kg"] for r in rows
                if r["solar_capacity_w"] > 0.0]
    helps = float(np.mean(solar_on)) < float(np.mean(solar_off))
    return (f"ci_ranking={'<'.join(order)};"
            f"solar_cuts_net_emissions={helps}(expected:True)")


# ---------------------------------------------------------------- fleet ---

_FLEET_DIVERGENT = "hydro+coal"     # the two-region divergent-CI pair


def _fleet_build(smoke: bool, n_requests: Optional[int] = None):
    """Multi-site fleet: site device mix x router policy x two-region
    CI trace pair, each scenario a full in-loop-routed fleet
    simulation (repro.fleet)."""
    from repro.configs.paper_models import LLAMA3_8B
    from repro.fleet.config import FleetConfig, SiteConfig
    from repro.sim.requests import WorkloadConfig
    from repro.sim.scheduler import SchedulerConfig

    n = n_requests or (64 if smoke else 2048)
    routers = (["round_robin", "carbon_greedy"] if smoke
               else ["round_robin", "least_loaded", "carbon_greedy"])
    ci_pairs = ([("hydro", "coal"), ("caiso", "caiso-east")] if smoke
                else [("hydro", "coal"), ("caiso", "caiso-east"),
                      ("wind", "coal")])
    mixes = [("a100", "a100")] if smoke else [("a100", "a100"),
                                              ("a100", "h100")]
    wl = WorkloadConfig(n_requests=n, qps=6.45, min_len=128,
                        max_len=1024 if smoke else 4096, seed=0)
    scenarios = []
    for mix in mixes:
        for pair in ci_pairs:
            for router in routers:
                sites = tuple(
                    SiteConfig(name=f"s{i}-{trace}", device=dev,
                               ci_trace=trace,
                               scheduler=SchedulerConfig(batch_cap=64))
                    for i, (dev, trace) in enumerate(zip(mix, pair)))
                cfg = FleetConfig(model=LLAMA3_8B, sites=sites,
                                  workload=wl, router=router)
                params = {"devices": "+".join(mix),
                          "ci": "+".join(pair), "router": router}
                label = ",".join(f"{k}={v}" for k, v in params.items())
                scenarios.append(Scenario(cfg=cfg, params=params,
                                          tag=f"fleet/{label}",
                                          pue=cfg.pue))
    return scenarios


def _fleet_derive(records: List[dict]) -> str:
    """Headline check: on the divergent two-region pair the
    carbon-greedy geo-router must emit less than round-robin."""
    rows = [r for r in flatten(records) if r["ci"] == _FLEET_DIVERGENT
            and r["devices"] == "a100+a100"]
    by_router = {r["router"]: r for r in rows}
    rr = by_router.get("round_robin")
    cg = by_router.get("carbon_greedy")
    if not (rr and cg):
        return "divergent-pair rows missing"
    save = 100.0 * (1.0 - cg["carbon_operational_g"]
                    / max(rr["carbon_operational_g"], 1e-12))
    return (f"carbon_greedy_vs_round_robin_on_{_FLEET_DIVERGENT}="
            f"-{save:.1f}%_emissions(expected:negative);"
            f"rr={rr['carbon_operational_g']:.2f}g,"
            f"cg={cg['carbon_operational_g']:.2f}g")


# ---------------------------------------------------------------- shift ---

_SHIFT_DIVERGENT = "hydro-evening+coal-evening"
#: deliberately spans the evening CI ramp: arrivals start at 17:00
#: grid-local (the "-evening" traces), so deferral windows reach the
#: post-peak overnight decline within a few hours of sim time
_SHIFT_SPAN_S = {"smoke": 4 * 3600.0, "full": 8 * 3600.0}


def _shift_build(smoke: bool, n_requests: Optional[int] = None):
    """Temporal carbon-aware scheduling (repro.schedule): admission
    policy x CI forecaster x deadline x trace-set x solar axes over
    request-level fleet simulations. Every scenario pins the same
    co-sim horizon, so idle carbon is identical across the policy axis
    and differences isolate what the admission gate moved."""
    from repro.configs.paper_models import LLAMA3_8B
    from repro.fleet.config import FleetConfig, SiteConfig
    from repro.schedule.config import ScheduleConfig
    from repro.sim.requests import WorkloadConfig
    from repro.sim.scheduler import SchedulerConfig

    span = _SHIFT_SPAN_S["smoke" if smoke else "full"]
    n = n_requests or (96 if smoke else 1024)
    policies = ["immediate", "threshold_defer", "forecast_window"]
    forecasters = (["oracle", "persistence"] if smoke
                   else ["oracle", "persistence", "diurnal"])
    deadlines = [7200.0] if smoke else [3600.0, 14400.0]
    # (ci label, site traces, spatial router): carbon_slo on the
    # divergent pair is the temporal x spatial composition and the
    # acceptance pin (its site assignment is invariant to release
    # order, so the policy axis isolates the temporal gate); the same
    # pair under spatially-blind round_robin is the baseline (release
    # order reshuffles its assignments — reported, not pinned); the
    # single-site rows isolate temporal shifting, with the real
    # ElectricityMaps trace exercising the file-backed loader end to end
    site_sets = [(_SHIFT_DIVERGENT, ("hydro-evening", "coal-evening"),
                  "carbon_slo"),
                 (_SHIFT_DIVERGENT, ("hydro-evening", "coal-evening"),
                  "round_robin"),
                 ("caiso-evening", ("caiso-evening",), "round_robin")]
    if not smoke:
        site_sets += [("caiso-em", ("caiso-em",), "round_robin")]
    solars = [(0.0, 0.0)] if smoke else [(0.0, 0.0), (600.0, 100.0)]
    horizon_s = span + max(deadlines) + 3600.0

    scenarios = []
    for ci_label, traces, router in site_sets:
        for policy in policies:
            # immediate admission never consults the forecaster: one
            # row per forecast axis would execute bit-identical sims
            # under distinct cache keys
            for fc in (["oracle"] if policy == "immediate"
                       else forecasters):
                for deadline in deadlines:
                    for solar_w, batt_wh in solars:
                        wl = WorkloadConfig(
                            n_requests=n, qps=n / span, min_len=128,
                            max_len=1024 if smoke else 4096, seed=0,
                            deferrable_frac=0.5,
                            deferrable_deadline_s=deadline,
                            interactive_slo_s=30.0)
                        sites = tuple(
                            SiteConfig(name=f"s{i}-{t}", ci_trace=t,
                                       solar_capacity_w=(solar_w if i == 0
                                                         else 0.0),
                                       battery_capacity_wh=(batt_wh
                                                            if i == 0
                                                            else 0.0),
                                       scheduler=SchedulerConfig(
                                           batch_cap=64))
                            for i, t in enumerate(traces))
                        sched = ScheduleConfig(
                            policy=policy, forecaster=fc,
                            ci_stat=("min" if router == "carbon_slo"
                                     else "mean"))
                        cfg = FleetConfig(model=LLAMA3_8B, sites=sites,
                                          workload=wl, router=router,
                                          schedule=sched,
                                          horizon_s=horizon_s)
                        params = {"policy": policy, "forecaster": fc,
                                  "deadline_s": deadline, "ci": ci_label,
                                  "router": router, "solar_w": solar_w}
                        label = ",".join(f"{k}={v}"
                                         for k, v in params.items())
                        scenarios.append(Scenario(
                            cfg=cfg, params=params, tag=f"shift/{label}",
                            pue=cfg.pue))
    return scenarios


def _shift_derive(records: List[dict]) -> str:
    """Headline: on the divergent evening pair under SLO-bounded
    carbon routing with oracle forecasts, deferral must cut the
    request-attributable operational emissions vs immediate admission
    while interactive p99 TTFT stays within the 30 s SLO."""
    rows = [r for r in flatten(records)
            if r["ci"] == _SHIFT_DIVERGENT and r["router"] == "carbon_slo"
            and r["forecaster"] == "oracle" and r["solar_w"] == 0.0]
    if not rows:
        return "divergent-pair oracle rows missing"
    deadline = max(r["deadline_s"] for r in rows)
    by_policy = {r["policy"]: r for r in rows
                 if r["deadline_s"] == deadline}
    imm = by_policy.get("immediate")
    td = by_policy.get("threshold_defer")
    fw = by_policy.get("forecast_window")
    if not (imm and td and fw):
        return "policy rows missing"

    def save(r, col="carbon_active_g"):
        return 100.0 * (1.0 - r[col] / max(imm[col], 1e-12))

    return (f"active_carbon_cut_on_{_SHIFT_DIVERGENT}: "
            f"threshold_defer=-{save(td):.2f}%(expected:<0),"
            f"forecast_window=-{save(fw):.2f}%(expected:<0);"
            f"cosim_net: defer<=immediate="
            f"{td['carbon_operational_g'] <= imm['carbon_operational_g']};"
            f"deferred_frac={td['deferred_fraction']:.2f};"
            f"interactive_p99: imm={imm['interactive_ttft_p99_s']:.3f}s "
            f"defer={td['interactive_ttft_p99_s']:.3f}s "
            f"(SLO 30s, expected:unchanged+within)")


# ----------------------------------------------------------------- day ----

#: tolerance for fluid-epoch and whole-day metric agreement between the
#: hybrid and event_loop day modes (relative) — the acceptance bound
#: the day-smoke CI job asserts
DAY_FLUID_RTOL = 0.01

#: per-epoch columns compared across day modes. Tail quantiles are
#: deliberately absent: a ~100-request pilot's p99 is order-statistic-
#: limited (the ttft tail sits on discrete queueing modes, so the 99th
#: percentile of a small sample jumps between modes), so the p99
#: agreement bound is asserted on planned-exact epochs (bit-for-bit,
#: below) and on the day-level weighted percentile (_DAY_TOTAL_COLS),
#: where the aggregated sample mass smooths the mode boundary.
_DAY_COMPARE_COLS = ("energy_wh", "carbon_g", "n")
_DAY_EXACT_COLS = _DAY_COMPARE_COLS + ("ttft_p99_s",)
_DAY_TOTAL_COLS = ("energy_wh", "carbon_operational_g", "ttft_p99_s",
                   "e2e_p99_s", "n_requests")


def _day_build(smoke: bool, n_requests: Optional[int] = None):
    """Day-scale fluid/request hybrid (repro.fleet.day): a diurnal +
    bursty arrival stream over a two-site fleet with carbon-aware
    deferral, run under both day modes — ``hybrid`` (fluid epochs with
    exact transients) and ``event_loop`` (every epoch exact) — with
    and without the replica autoscaler. The smoke grid is what the
    day-smoke CI job compares: planned-exact epochs bit-for-bit,
    fluid epochs within ``DAY_FLUID_RTOL``."""
    from repro.configs.paper_models import LLAMA3_8B
    from repro.fleet.autoscale import AutoscalerConfig
    from repro.fleet.config import FleetConfig, SiteConfig
    from repro.schedule.config import ScheduleConfig
    from repro.sim.hybrid import DayConfig
    from repro.sim.requests import WorkloadConfig
    from repro.sim.scheduler import SchedulerConfig

    span = 3600.0 if smoke else 24 * 3600.0
    n = n_requests or (9000 if smoke else 400_000)
    epoch_s = 300.0 if smoke else 900.0
    # full-scale event_loop would step every request (minutes of wall
    # clock); the full sweep keeps the hybrid rows only — the smoke
    # grid carries the cross-mode agreement pin
    modes = ["hybrid", "event_loop"] if smoke else ["hybrid"]
    # fixed request length: the fluid pilot's p99 must estimate the
    # exact epoch's p99 within DAY_FLUID_RTOL, which needs a latency
    # distribution whose tail is set by queueing, not by length-draw
    # sampling noise in a ~100-request pilot
    wl = WorkloadConfig(
        n_requests=n, qps=n / span, min_len=192, max_len=192, seed=0,
        envelope="sinusoidal", envelope_amplitude=0.3,
        envelope_period_h=span / 3600.0,
        burst_gain=2.5, burst_mean_s=span / 15.0,
        burst_idle_mean_s=span / 2.5,
        deferrable_frac=0.3, deferrable_deadline_s=span,
        interactive_slo_s=30.0)
    scenarios = []
    for autoscale in (0, 1):
        # tokens_per_s is the planner's capacity estimate, pitched so
        # the diurnal swing crosses the scale-up threshold (util ~0.5
        # at the trough, ~0.9 at the peak, >1 inside bursts)
        asc = AutoscalerConfig(
            enabled=bool(autoscale), min_replicas=1, max_replicas=3,
            target_util=0.6, scale_up_latency_s=epoch_s / 5.0,
            warm_spares=1, tokens_per_s=160.0 * n / 4000.0 / (span / 3600.0),
            ci_scale_down_g=0.0)
        sites = tuple(
            SiteConfig(name=f"s{i}-{trace}", ci_trace=trace,
                       autoscaler=asc,
                       scheduler=SchedulerConfig(batch_cap=64))
            for i, trace in enumerate(("caiso-night", "coal-night")))
        for mode in modes:
            cfg = FleetConfig(
                model=LLAMA3_8B, sites=sites, workload=wl,
                router="round_robin",
                schedule=ScheduleConfig(policy="forecast_window",
                                        forecaster="oracle",
                                        policy_params={"margin": 0.01}),
                # util_threshold below the default 0.85: the fluid
                # pilot's p99 only estimates the exact epoch's within
                # DAY_FLUID_RTOL when the tail is service-time- rather
                # than queueing-dominated, so epochs the capacity
                # estimate puts past ~60% utilization run exact
                day=DayConfig(mode=mode, epoch_s=epoch_s,
                              pilot_requests=128 if smoke else 256,
                              warmup_requests=32 if smoke else 64,
                              util_threshold=0.6))
            params = {"mode": mode, "autoscale": autoscale}
            label = ",".join(f"{k}={v}" for k, v in params.items())
            scenarios.append(Scenario(cfg=cfg, params=params,
                                      tag=f"day/{label}", pue=cfg.pue))
    return scenarios


def day_agreement(records: List[dict]) -> Dict[str, float]:
    """Hybrid-vs-event_loop agreement stats over paired day records.

    Pairs records on the non-mode params and compares per-epoch fleet
    columns: epochs both modes planned fully exact must match
    bit-for-bit (``exact_max_rel`` stays 0.0), fluid epochs and whole-
    day totals within ``DAY_FLUID_RTOL``. Also checks the two modes
    planned identical epochs (``plans_match``) and reports the hybrid
    speedup. This is what tests/test_day.py and the day-smoke CI job
    assert on."""
    by_pair: Dict[tuple, Dict[str, dict]] = {}
    for r in records:
        key = tuple(sorted((k, v) for k, v in r["params"].items()
                           if k != "mode"))
        by_pair.setdefault(key, {})[r["params"]["mode"]] = r
    out = {"n_pairs": 0.0, "plans_match": 1.0, "exact_max_rel": 0.0,
           "fluid_max_rel": 0.0, "total_max_rel": 0.0,
           "n_exact_epochs": 0.0, "n_fluid_epochs": 0.0,
           "speedup": 0.0, "sim_fraction": 1.0}
    speedups = []
    for pair in by_pair.values():
        h, x = pair.get("hybrid"), pair.get("event_loop")
        if not (h and x):
            continue
        hm, xm = h["metrics"], x["metrics"]
        out["n_pairs"] += 1
        if hm["n_epochs"] != xm["n_epochs"]:
            out["plans_match"] = 0.0
            continue
        for e in range(int(hm["n_epochs"])):
            tag = f"e{e:03d}"
            if hm[f"{tag}_exact"] != xm[f"{tag}_exact"]:
                out["plans_match"] = 0.0
            fully_exact = hm[f"{tag}_exact"] == 1.0
            cols = _DAY_EXACT_COLS if fully_exact else _DAY_COMPARE_COLS
            for col in cols:
                a, b = hm[f"{tag}_{col}"], xm[f"{tag}_{col}"]
                rel = abs(a - b) / max(abs(a), abs(b), 1e-12)
                bucket = ("exact_max_rel" if fully_exact
                          else "fluid_max_rel")
                out[bucket] = max(out[bucket], rel)
            if fully_exact:
                out["n_exact_epochs"] += 1
            else:
                out["n_fluid_epochs"] += 1
        for col in _DAY_TOTAL_COLS:
            rel = (abs(hm[col] - xm[col])
                   / max(abs(hm[col]), abs(xm[col]), 1e-12))
            out["total_max_rel"] = max(out["total_max_rel"], rel)
        speedups.append(x["meta"]["elapsed_s"]
                        / max(h["meta"]["elapsed_s"], 1e-9))
        out["sim_fraction"] = min(out["sim_fraction"],
                                  hm["sim_fraction"])
    if speedups:
        out["speedup"] = float(np.mean(speedups))
    return out


def _day_derive(records: List[dict]) -> str:
    agree = day_agreement(records)
    if not agree["n_pairs"]:
        h = [r["metrics"] for r in records
             if r["params"]["mode"] == "hybrid"]
        if not h:
            return "no day records"
        return (f"hybrid_only:n={sum(m['n_requests'] for m in h):.0f};"
                f"sim_fraction={min(m['sim_fraction'] for m in h):.3f};"
                f"exact_epochs={sum(m['n_exact_epochs'] for m in h):.0f}"
                f"/{sum(m['n_epochs'] for m in h):.0f}")
    return (f"pairs={agree['n_pairs']:.0f};"
            f"plans_match={bool(agree['plans_match'])}(expected:True);"
            f"exact_bitwise={agree['exact_max_rel'] == 0.0}"
            f"(expected:True);"
            f"fluid_max_rel={agree['fluid_max_rel']:.2e}"
            f"(tol:{DAY_FLUID_RTOL});"
            f"total_max_rel={agree['total_max_rel']:.2e};"
            f"sim_fraction={agree['sim_fraction']:.3f};"
            f"hybrid_speedup={agree['speedup']:.1f}x")


# ---------------------------------------------------------------- perf ----

def _perf_build(smoke: bool, n_requests: Optional[int] = None):
    """Perf-trajectory grid (``benchmarks/perf_sweep.py``), two planes:

    * plane A — a few QPS points x a dense (PUE x grid-CI) report
      plane: scenario-level axes share traces, so the vectorized
      runner drives one event loop per QPS point and stacks the rest
      (the historical ~1k-scenario grid);
    * plane B — a hardware family (device x TP x PP) over one sparse
      uniform-arrival stream: every point is its own trace group for
      the numpy modes, but the arrivals are provably isolated under
      every config, so device-mode divergence analysis shares one
      composition schedule and replays it per point instead of
      re-running the event loop 8x (``repro.sweep.divergence``).

    The event-loop runner simulates everything; the contrasts are what
    ``BENCH_sweep.json`` tracks."""
    qps = [2.0, 4.0, 6.45, 8.0]
    pues = [round(1.0 + 0.05 * i, 2) for i in range(16)]
    cis = [round(25.0 + 45.0 * i, 1) for i in range(16)]
    n = n_requests or (16 if smoke else 64)
    plane_a = GridSpec(
        base=PAPER_DEFAULT, tag="perf",
        axes={"workload.qps": qps, "pue": pues, "grid_ci": cis},
        fixed={"workload.n_requests": n, "workload.min_len": 64,
               "workload.max_len": 256}).expand()
    hw = [(dev, tp, pp) for dev in ("a100", "h100")
          for tp, pp in ((1, 1), (2, 1), (1, 2), (2, 2))]
    plane_b = GridSpec(
        base=PAPER_DEFAULT, tag="perf",
        axes={"device+tp+pp": hw,
              "pue": [1.1, 1.3], "grid_ci": [100.0, 400.0]},
        fixed={"workload.n_requests": 4 * n, "workload.qps": 0.5,
               "workload.arrival": "uniform", "workload.min_len": 64,
               "workload.max_len": 256}).expand()
    return plane_a + plane_b


def _perf_derive(records: List[dict]) -> str:
    rows = flatten(records)
    traces = len({(r.get("qps"), r.get("device"), r.get("tp"),
                   r.get("pp")) for r in rows})
    return (f"scenarios={len(rows)};unique_traces={traces};"
            f"shared_axis_points={len(rows) // max(traces, 1)}")


# ------------------------------------------------------------- registry ---

SWEEPS: Dict[str, SweepDef] = {
    "fig1": SweepDef("fig1", "QPS saturation (Llama-3-8B MFU plateau)",
                     _fig1_build, _fig1_derive),
    "fig2": SweepDef("fig2", "Request count vs power/energy across models",
                     _fig2_build, _fig2_derive, rows=_fig2_rows),
    "fig3": SweepDef("fig3", "Prefill:decode ratio x request length",
                     _fig3_build, _fig3_derive),
    "fig4": SweepDef("fig4", "Batch cap vs power and energy",
                     _fig4_build, _fig4_derive),
    "fig5": SweepDef("fig5", "QPS vs power and energy (fixed workload)",
                     _fig5_build, _fig5_derive),
    "exp5": SweepDef("exp5", "TP x PP parallelism (CodeLlama-34B)",
                     _exp5_build, _exp5_derive),
    "table2": SweepDef("table2", "Vidur-Vessim microgrid co-simulation",
                       _table2_build, _table2_derive, rows=_table2_rows),
    "carbon": SweepDef("carbon", "CI trace x solar x battery co-sim axes",
                       _carbon_build, _carbon_derive),
    "fleet": SweepDef("fleet",
                      "Multi-site fleet: device mix x router x CI pair",
                      _fleet_build, _fleet_derive),
    "shift": SweepDef("shift",
                      "Temporal shifting: policy x forecaster x deadline "
                      "x CI trace x solar",
                      _shift_build, _shift_derive),
    "day": SweepDef("day",
                    "Day-scale hybrid: diurnal+burst stream, fluid vs "
                    "exact day modes, autoscaler on/off",
                    _day_build, _day_derive),
    "perf": SweepDef("perf",
                     "Perf smoke grid: QPS x PUE x grid-CI (1k scenarios, "
                     "4 traces)",
                     _perf_build, _perf_derive),
}


def run_sweep(name: str, smoke: bool = False,
              n_requests: Optional[int] = None, workers: int = 1,
              cache=None, progress=None, mode: str = "vectorized",
              probe=None, backend: str = "local", remote=None):
    """Expand + execute one named sweep.

    Returns ``(records, stats, derived)``. ``cache`` follows
    ``runner.SweepRunner`` semantics (None disables memoization);
    ``mode`` selects the execution mode (both numpy modes are
    bit-identical); ``probe`` attaches a ``repro.obs.Probe`` to
    executed scenarios (forces serial execution, see ``SweepRunner``);
    ``backend="remote"`` fans trace groups out to detached workers
    over a shared-filesystem queue (``repro.sweep.remote``).
    """
    from repro.sweep.runner import SweepRunner
    if name not in SWEEPS:
        raise KeyError(f"unknown sweep {name!r}; have {sorted(SWEEPS)}")
    sweep = SWEEPS[name]
    scenarios = sweep.build(smoke, n_requests=n_requests)
    records, stats = SweepRunner(cache=cache, workers=workers,
                                 mode=mode, probe=probe, backend=backend,
                                 remote=remote).run(scenarios, progress)
    return records, stats, sweep.derive(records)
