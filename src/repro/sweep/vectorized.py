"""Trace-grouped (vectorized) scenario execution.

The expensive part of a scenario is driving the continuous-batching
event loop; everything after it — Eq. 2-3 energy under a PUE, Eq. 4
carbon under a static grid CI, the microgrid post-processors — is a
pure array pass over the logged ``StageTrace``. Grid points whose
*config* is identical (they differ only in the scenario-level ``pue``
/ ``grid_ci`` axes or in ``post.*`` parameters) therefore share one
trace: this module groups them by config digest, runs the simulation
once per group, and evaluates the shared-trace axes stacked —
``stacked_energy_reports`` computes per-stage power once and scales it
across the whole PUE axis, ``emissions_batch`` sweeps the CI axis.

Axes that reach into the config tree (workload, scheduler, device,
TP/PP, exec-model calibration) genuinely diverge the trace — device
and parallelism change stage durations, durations change admission
timing, timing changes batch composition — so each unique config
falls back to one event-loop run. Their *per-stage* roofline still
evaluates through the batched kernel inside the loop.

Fleet scenarios (``FleetConfig``) run their own multi-site rollup and
pass through unchanged.

Both paths assemble records through ``runner.single_site_metrics``,
so vectorized and event-loop records are bit-identical (pinned by
tests/test_vectorized.py).

``repro.sweep.device`` builds on the same grouping: instead of one
numpy pass per group, it pads every group's trace into one batched
tensor set and evaluates the whole grid in a single jax program, with
divergence analysis (``repro.sweep.divergence``) sharing composition
traces across device/TP/PP points where provably safe.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

from repro.core.carbon import emissions_batch
from repro.core.power import DEVICES, PowerModel
from repro.fleet.config import FleetConfig
from repro.sweep.grid import Scenario


def estimate_trace_cost(sc: Scenario) -> float:
    """Estimated event-loop stage count for one scenario's trace —
    the scheduling weight for balanced shard/worker packing, not a
    wall-clock prediction. Each request contributes one prefill stage
    plus its decode steps (~avg_len / (1 + pd_ratio) under the
    prefill:decode token-ratio convention); fleet scenarios scale by
    site count (each site drives its own loop over its share)."""
    cfg = sc.cfg
    wl = cfg.workload
    avg_len = 0.5 * (wl.min_len + wl.max_len)
    decode_per_req = avg_len / (1.0 + max(wl.pd_ratio, 1e-9))
    stages = wl.n_requests * (1.0 + decode_per_req)
    if isinstance(cfg, FleetConfig):
        stages *= max(1, len(cfg.sites))
    return max(stages, 1.0)


def estimate_group_cost(scenarios: Sequence[Scenario]) -> float:
    """A trace group's estimated cost: one shared event loop plus a
    small per-scenario stacked-pass/record term. All members share one
    config digest, so the trace estimate comes from the first."""
    return estimate_trace_cost(scenarios[0]) + 0.1 * len(scenarios)


def group_by_trace(scenarios: Sequence[Scenario]) -> List[List[int]]:
    """Order-preserving partition of scenario indices into groups that
    share one simulation trace, keyed by ``Scenario.trace_key`` (the
    config digest alone — everything the event loop's trace depends
    on, nothing the report knobs touch)."""
    groups: Dict[str, List[int]] = {}
    for i, sc in enumerate(scenarios):
        groups.setdefault(sc.trace_key, []).append(i)
    return list(groups.values())


def execute_scenario_group(scenarios: List[Scenario],
                           probe=None) -> List[dict]:
    """Execute scenarios that share one config: one event-loop run,
    then stacked metric evaluation per scenario. ``probe``
    (``repro.obs.Probe``) observes the shared simulation and gets the
    rollup under the *first* scenario's PUE/CI (the group shares one
    trace; report knobs differ per scenario)."""
    from repro.core.energy import stacked_energy_reports
    from repro.obs.spans import PROFILER
    from repro.sim import run_simulation
    from repro.sweep.runner import (_execute_fleet_scenario,
                                    shared_result_metrics,
                                    single_site_metrics,
                                    single_site_record)

    if isinstance(scenarios[0].cfg, FleetConfig):
        # the fleet rollup bakes CI signals and PUE into its per-site
        # co-sims — no shared-trace axis to stack; keep the fleet path
        return [_execute_fleet_scenario(sc, probe=probe)
                for sc in scenarios]

    t0 = time.perf_counter()
    cfg = scenarios[0].cfg
    if probe is not None:
        probe.on_run_begin(scenarios[0].tag)
    with PROFILER.span("sim.event_loop"):
        res = run_simulation(cfg, probe=probe)
    pm = PowerModel(cfg.device)
    shared = shared_result_metrics(res)
    sim_elapsed = time.perf_counter() - t0
    with PROFILER.span("stacked_passes"):
        # one array pass over the shared trace covers the whole PUE axis
        reps = stacked_energy_reports(res.stages.mfu, res.stages.dur_s, pm,
                                      n_devices=cfg.n_devices,
                                      pues=[sc.pue for sc in scenarios])
        # ... and one stacked Eq. 4 pass covers the grid-CI axis
        carbons = emissions_batch([r.energy_wh for r in reps],
                                  [r.gpu_hours for r in reps],
                                  DEVICES[cfg.device],
                                  [sc.grid_ci for sc in scenarios])
    if probe is not None:
        # rollup fires after the stacked passes so the driver can hand
        # the probe the group's Eq. 2-3 total (observer-only ordering:
        # records are identical either way)
        probe.on_site_rollup(
            site=0, name=scenarios[0].tag, trace=res.stages,
            device=cfg.device, row_devices=cfg.n_devices,
            pue=scenarios[0].pue, ci=scenarios[0].grid_ci,
            total_devices=cfg.n_devices, energy_wh=reps[0].energy_wh)

    records = []
    with PROFILER.span("record_assembly"):
        for sc, rep, carbon in zip(scenarios, reps, carbons):
            # elapsed_s = the (shared) sim + this record's own
            # evaluation — the scenario's standalone cost, not a
            # cumulative group sum
            rec_t0 = time.perf_counter() - sim_elapsed
            metrics = single_site_metrics(res, sc, rep, carbon=carbon,
                                          shared=shared)
            records.append(single_site_record(
                sc, metrics, rec_t0, mode="vectorized",
                trace_scenarios=len(scenarios)))
    return records


def execute_scenario_group_profiled(scenarios: List[Scenario]
                                    ) -> tuple:
    """Pool target for profiled fan-out: run the group under the
    worker-local ``PROFILER`` and return ``(records, aggregate)`` so
    the parent can ``merge()`` the per-phase totals (span events
    themselves stay worker-local — cross-process clocks don't share an
    origin)."""
    from repro.obs.spans import PROFILER
    PROFILER.enable(reset=True)
    try:
        records = execute_scenario_group(scenarios)
    finally:
        PROFILER.disable()
    return records, PROFILER.aggregate()
