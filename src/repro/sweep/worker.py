"""Remote sweep worker: ``python -m repro.sweep.worker <queue_dir>``.

One worker process per invocation. It scans the queue directory for
open jobs (published by ``repro.sweep.remote.RemoteCoordinator``),
claims pending shards by atomic rename, evaluates each shard's trace
groups through the existing execution paths (``vectorized`` — exact,
bit-identical to serial — or ``device`` — batched jax program within
``DEVICE_MODE_RTOL``), and writes the records straight into the shared
``ResultCache`` named by the job. A daemon heartbeat thread refreshes
the claimed shard's lease (mtime) so the coordinator can tell a slow
worker from a dead one.

Run it on any host that shares the queue/cache filesystem; nothing
else is coordinated. ``--once`` drains the current backlog and exits
(CI); without it the worker keeps polling until ``<queue>/stop``
exists, ``--idle-timeout-s`` elapses without work, or it is signalled.

Crash safety: a worker that dies mid-shard simply stops heartbeating;
the coordinator re-pends the shard after ``lease_s`` and another
worker re-executes it. Records it already wrote are bit-identical to
the re-execution's (deterministic sims, content-addressed keys, atomic
cache writes), so partial progress is never torn or duplicated —
``REPRO_WORKER_CRASH_AFTER_GROUPS`` injects exactly that failure for
the retry tests.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
import uuid
from pathlib import Path
from typing import Optional

from repro.obs.spans import PROFILER
from repro.sweep import remote
from repro.sweep.cache import ResultCache
from repro.sweep.grid import SCHEMA_VERSION


def choose_mode(worker_mode: str, payload: dict) -> str:
    """Resolve the shard's execution mode. ``inherit`` (default) uses
    whatever the coordinator ran with — the safe choice, preserving the
    backend's bit-identity contract when the sweep is vectorized.
    ``auto`` picks device for single-site shards (fastest, rtol
    contract) and vectorized otherwise; an explicit mode wins."""
    if worker_mode == "inherit":
        return payload.get("mode", "vectorized")
    if worker_mode == "auto":
        from repro.fleet.config import FleetConfig
        for group in payload["groups"]:
            if isinstance(group[0].cfg, FleetConfig):
                return "vectorized"
        return "device"
    return worker_mode


def execute_shard(payload: dict, cache: ResultCache, mode: str,
                  crash_after: Optional[int] = None) -> int:
    """Evaluate one shard's trace groups and persist every record into
    the shared cache. Returns the record count. ``crash_after`` kills
    the process (``os._exit``) after that many completed groups — the
    injected-crash hook exercising lease-expiry retry."""
    from repro.sweep.vectorized import execute_scenario_group

    n_records = 0
    done_groups = 0
    if mode == "device":
        from repro.sweep.device import execute_device_grid
        flat = [sc for group in payload["groups"] for sc in group]
        with PROFILER.span("worker.device_grid"):
            records, _ = execute_device_grid(flat)
        with PROFILER.span("cache.store"):
            for rec in records:
                rec["meta"]["cache_hit"] = False
                cache.put(rec["key"], rec)
                n_records += 1
        return n_records

    for group in payload["groups"]:
        records = execute_scenario_group(group)
        with PROFILER.span("cache.store"):
            for rec in records:
                rec["meta"]["cache_hit"] = False
                cache.put(rec["key"], rec)
                n_records += 1
        done_groups += 1
        if crash_after is not None and done_groups >= crash_after:
            # simulated hard crash: no release, no manifest, no atexit
            os._exit(17)
    return n_records


def _start_heartbeat(running_path: Path, lease_s: float
                     ) -> threading.Event:
    """Refresh the shard lease from a daemon thread every lease_s/4;
    returns the stop event. OSErrors are swallowed — a reclaimed file
    just means the heartbeat is moot."""
    stop = threading.Event()

    def _beat():
        while not stop.wait(max(0.05, lease_s / 4.0)):
            remote.heartbeat(running_path)

    threading.Thread(target=_beat, daemon=True).start()
    return stop


def _open_jobs(queue_dir: Path):
    """Yield (job_dir, job_meta) for jobs still accepting work, oldest
    first. Schema-mismatched jobs are skipped (version skew between a
    worker's checkout and the coordinator's must never produce records
    under the wrong digest)."""
    for job_dir in sorted(queue_dir.glob("job-*")):
        try:
            meta = json.loads((job_dir / "job.json").read_text())
        except (OSError, json.JSONDecodeError):
            continue
        if meta.get("status") != "open":
            continue
        if meta.get("schema") != SCHEMA_VERSION:
            continue
        yield job_dir, meta


def _work_one_shard(job_dir: Path, meta: dict, worker_id: str,
                    worker_mode: str,
                    crash_after: Optional[int]) -> bool:
    """Try to claim and complete one shard of this job. Returns True if
    a shard was executed (or claimed-and-failed), False if nothing was
    claimable."""
    pending = sorted(p.name for p in
                     (job_dir / remote.PENDING).glob("shard-*.pkl"))
    if not pending:
        return False
    # start each worker at a different offset so concurrent claimers
    # mostly don't race for the same file
    offset = hash(worker_id) % len(pending)
    for name in pending[offset:] + pending[:offset]:
        claimed = remote.claim_shard(job_dir, name, worker_id)
        if claimed is None:
            continue
        payload, running_path = claimed
        lease_s = float(meta.get("lease_s", 30.0))
        beat_stop = _start_heartbeat(running_path, lease_s)
        t0 = time.perf_counter()
        PROFILER.enable(reset=True)
        try:
            cache = ResultCache(Path(meta["cache_root"]))
            mode = choose_mode(worker_mode, payload)
            n_records = execute_shard(payload, cache, mode,
                                      crash_after=crash_after)
        except BaseException as exc:
            PROFILER.disable()
            beat_stop.set()
            outcome = remote.release_shard(
                job_dir, running_path,
                int(meta.get("max_attempts", 3)), repr(exc))
            print(f"[worker {worker_id}] shard {payload['shard']} "
                  f"failed ({outcome}): {exc!r}", flush=True)
            return True
        PROFILER.disable()
        beat_stop.set()
        remote.complete_shard(job_dir, running_path, {
            "shard": payload["shard"],
            "worker": worker_id,
            "mode": mode,
            "n_groups": len(payload["groups"]),
            "n_records": n_records,
            "elapsed_s": round(time.perf_counter() - t0, 4),
            "phases": {k: {"count": int(a["count"]),
                           "total_s": a["total_s"]}
                       for k, a in PROFILER.aggregate().items()},
        })
        return True
    return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep.worker",
        description="claim and execute sweep shards from a shared "
                    "work queue (see repro.sweep.remote)")
    ap.add_argument("queue", type=Path,
                    help="queue directory shared with the coordinator")
    ap.add_argument("--mode", default="inherit",
                    choices=("inherit", "auto", "vectorized", "device"),
                    help="per-shard execution mode (default: whatever "
                         "the coordinator ran with)")
    ap.add_argument("--poll-s", type=float, default=0.05,
                    help="idle poll period (default 0.05s)")
    ap.add_argument("--idle-timeout-s", type=float, default=None,
                    help="exit after this long without claimable work")
    ap.add_argument("--once", action="store_true",
                    help="drain the current backlog, then exit")
    ap.add_argument("--worker-id", default=None,
                    help="stable identity in claims/manifests "
                         "(default: host-pid-rand)")
    ap.add_argument("--crash-after-groups", type=int, default=None,
                    help=argparse.SUPPRESS)   # test hook
    args = ap.parse_args(argv)

    worker_id = args.worker_id or \
        f"{os.uname().nodename}-{os.getpid()}-{uuid.uuid4().hex[:4]}"
    crash_after = args.crash_after_groups
    if crash_after is None and os.environ.get(remote.ENV_CRASH_AFTER_GROUPS):
        crash_after = int(os.environ[remote.ENV_CRASH_AFTER_GROUPS])

    # warm the execution stack BEFORE registering as alive, so
    # wait_for_workers() measures resident-cluster dispatch, not
    # python+jax import cost
    import repro.sim                                    # noqa: F401
    from repro.sweep.vectorized import execute_scenario_group  # noqa: F401

    queue: Path = args.queue
    workers_dir = queue / "workers"
    workers_dir.mkdir(parents=True, exist_ok=True)
    alive = workers_dir / f"{worker_id}.alive"
    alive.write_text(json.dumps({"pid": os.getpid(),
                                 "started": time.time()}))
    print(f"[worker {worker_id}] watching {queue}", flush=True)

    last_work = time.monotonic()
    try:
        while True:
            if (queue / "stop").exists():
                print(f"[worker {worker_id}] stop file — exiting",
                      flush=True)
                return 0
            worked = False
            for job_dir, meta in _open_jobs(queue):
                while _work_one_shard(job_dir, meta, worker_id,
                                      args.mode, crash_after):
                    worked = True
                    last_work = time.monotonic()
            if worked:
                continue
            if args.once:
                return 0
            if args.idle_timeout_s is not None and \
                    time.monotonic() - last_work > args.idle_timeout_s:
                print(f"[worker {worker_id}] idle "
                      f"{args.idle_timeout_s}s — exiting", flush=True)
                return 0
            time.sleep(args.poll_s)
    except KeyboardInterrupt:
        return 0
    finally:
        try:
            alive.unlink()
        except OSError:
            pass


if __name__ == "__main__":
    import sys
    sys.exit(main())
