"""Checkpointing: msgpack + per-leaf numpy, async writes, atomic commit.

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.msgpack     # treedef, shapes, dtypes, step metadata
        leaf_00000.npy ...   # one file per leaf (host-gathered)
        COMMIT               # written last: restart-safe atomicity marker

Fault tolerance: ``latest_step`` only considers committed checkpoints, so
a crash mid-write is invisible on restart. ``CheckpointManager.save_async``
snapshots device arrays to host then writes on a worker thread, keeping
the training loop running.
"""
from __future__ import annotations

import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(path: str, tree, step: int, extra: Optional[Dict] = None):
    p = Path(path) / f"step_{step:08d}"
    tmp = p.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(l.shape) for l in leaves],
        "dtypes": [str(l.dtype) for l in leaves],
        "extra": extra or {},
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    for i, leaf in enumerate(leaves):
        np.save(tmp / f"leaf_{i:05d}.npy", leaf)
    (tmp / "COMMIT").write_text("ok")
    if p.exists():
        shutil.rmtree(p)
    tmp.rename(p)
    return str(p)


def latest_step(path: str) -> Optional[int]:
    p = Path(path)
    if not p.exists():
        return None
    steps = []
    for d in p.glob("step_*"):
        if (d / "COMMIT").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(path: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` (shapes validated)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
    p = Path(path) / f"step_{step:08d}"
    manifest = msgpack.unpackb((p / "manifest.msgpack").read_bytes())
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, model has {len(leaves)}"
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(p / f"leaf_{i:05d}.npy")
        assert list(arr.shape) == list(ref.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}"
        out.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class CheckpointManager:
    """Async checkpointing with retention."""

    def __init__(self, path: str, keep: int = 3):
        self.path = Path(path)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, tree, step: int, extra: Optional[Dict] = None):
        self.wait()
        # snapshot to host synchronously (cheap vs. device compute)
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save_checkpoint(str(self.path), host, step, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(d for d in self.path.glob("step_*")
                       if (d / "COMMIT").exists())
        for d in steps[:-self.keep]:
            shutil.rmtree(d, ignore_errors=True)
