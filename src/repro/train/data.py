"""Synthetic token data pipeline: seeded, shardable, restart-deterministic.

Produces packed LM batches (tokens, labels) from a Zipf unigram
distribution with document boundaries — enough structure for loss curves
to be meaningful (the model can learn the unigram + local bigram
statistics) while requiring no external data.

The iterator is stateless-resumable: batch i is a pure function of
(seed, i), so restart-from-checkpoint replays identically; each data
shard draws a disjoint stream (seed folded with shard index).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    mean_doc_len: int = 512
    bos_id: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size)
        probs = 1.0 / ranks ** cfg.zipf_a
        self._probs = probs / probs.sum()

    def _doc(self, rng, n: int) -> np.ndarray:
        """A 'document': unigram draws with a persistent bigram shift."""
        base = rng.choice(np.arange(1, self.cfg.vocab_size), size=n,
                          p=self._probs)
        shift = rng.integers(1, 17)
        # every other token correlates with its predecessor (learnable)
        base[1::2] = (base[0::2][: len(base[1::2])] + shift) % (
            self.cfg.vocab_size - 1) + 1
        return base

    def batch(self, index: int, shard: int = 0, n_shards: int = 1) -> Dict:
        c = self.cfg
        rows = c.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, shard, index]))
        toks = np.empty((rows, c.seq_len + 1), np.int32)
        for r in range(rows):
            buf = []
            while sum(len(b) for b in buf) < c.seq_len + 1:
                n = max(8, int(rng.exponential(c.mean_doc_len)))
                buf.append(np.concatenate([[c.bos_id], self._doc(rng, n)]))
            row = np.concatenate(buf)[: c.seq_len + 1]
            toks[r] = row
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
