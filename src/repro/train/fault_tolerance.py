"""Fault-tolerant training runner: checkpoint/restart, failure detection,
straggler mitigation hooks, elastic re-meshing.

On a real multi-pod deployment, failures surface as (a) process exits
(handled by restart-from-latest-commit), (b) NaN/Inf loss spikes (handled
by step rejection + LR cooldown), and (c) stragglers (handled by step-time
watchdog -> reshard decision). All three paths are testable on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager, latest_step, restore_checkpoint


@dataclasses.dataclass
class FaultToleranceConfig:
    ckpt_dir: str = "ckpts"
    ckpt_every: int = 50
    keep: int = 3
    max_nan_retries: int = 3
    straggler_factor: float = 2.5    # step slower than median x factor
    straggler_window: int = 20


class StepWatchdog:
    """Detects straggling steps against a rolling median."""

    def __init__(self, factor: float, window: int):
        self.factor = factor
        self.window = window
        self.times = []
        self.straggler_events = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 5 and dt > self.factor * float(np.median(hist)):
            self.straggler_events += 1
            return True
        return False


class FaultTolerantRunner:
    """Wraps a jit'd train_step with checkpoint/restart + NaN rejection.

    The step function must be (params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def __init__(self, step_fn: Callable, cfg: FaultToleranceConfig):
        self.step_fn = step_fn
        self.cfg = cfg
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.watchdog = StepWatchdog(cfg.straggler_factor,
                                     cfg.straggler_window)
        self.nan_rejections = 0

    def try_restore(self, params, opt_state):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        (params, opt_state), manifest = restore_checkpoint(
            self.cfg.ckpt_dir, (params, opt_state))
        return params, opt_state, int(manifest["step"])

    def run(self, params, opt_state, batches, n_steps: int,
            start_step: int = 0, log_every: int = 10,
            log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
        losses = []
        step_times = []
        step = start_step
        while step < n_steps:
            batch = batches(step)
            t0 = time.time()
            new_params, new_opt, metrics = self.step_fn(params, opt_state,
                                                        batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if not np.isfinite(loss):
                self.nan_rejections += 1
                log_fn(f"[ft] step {step}: non-finite loss, rejecting update "
                       f"({self.nan_rejections}/{self.cfg.max_nan_retries})")
                if self.nan_rejections > self.cfg.max_nan_retries:
                    raise FloatingPointError(
                        f"loss diverged at step {step}")
                step += 1
                continue
            params, opt_state = new_params, new_opt
            if self.watchdog.observe(dt):
                log_fn(f"[ft] step {step}: straggler ({dt:.2f}s vs median "
                       f"{np.median(self.watchdog.times[-20:]):.2f}s)")
            losses.append(loss)
            step_times.append(dt)
            if step % self.cfg.ckpt_every == 0 and step > start_step:
                self.manager.save_async((params, opt_state), step,
                                        extra={"loss": loss})
            if step % log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"({dt*1e3:.0f} ms/step)")
            step += 1
        self.manager.save_async((params, opt_state), step)
        self.manager.wait()
        return {"params": params, "opt_state": opt_state,
                "losses": losses, "step_times": step_times,
                "straggler_events": self.watchdog.straggler_events,
                "final_step": step}
