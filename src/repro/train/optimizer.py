"""Sharded AdamW with linear-warmup cosine decay.

Optimizer moments inherit the parameter PartitionSpecs (2D FSDPxTP for
training), so state memory scales down with the full mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["mu"])[0]
    flat_v = jax.tree_util.tree_flatten(state["nu"])[0]
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
