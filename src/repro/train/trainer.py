"""Train-step factory: value_and_grad + sharded AdamW, with remat and
optional microbatch gradient accumulation."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    grad_accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1 the global batch is split into microbatches scanned
    sequentially — peak activation memory drops by the accumulation factor.
    """

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, l

            def split(x):
                # strided split: microbatch m takes rows {m, ga+m, 2ga+m, ...}
                # so each microbatch stays sharded across the full data axis
                B = x.shape[0]
                return x.reshape(B // grad_accum, grad_accum,
                                 *x.shape[1:]).swapaxes(0, 1)

            mbs = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            grads, losses = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = losses.mean()
            metrics = {"ce": loss, "aux": jnp.zeros(())}

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step
