"""Day-scale workload generation: diurnal rate envelopes, MMPP burst
overlays, and array-native arrival streams (see ``repro.workloads.
stream`` / ``repro.workloads.envelope``)."""
from repro.workloads.envelope import (ENVELOPES, BurstOverlay,
                                      burst_overlay, cumulative_rate,
                                      envelope_shape, rate_on_grid)
from repro.workloads.stream import ArrivalStream, generate_stream

__all__ = [
    "ENVELOPES", "BurstOverlay", "burst_overlay", "cumulative_rate",
    "envelope_shape", "rate_on_grid", "ArrivalStream", "generate_stream",
]
