"""Rate envelopes and burst overlays for day-scale workloads.

The instantaneous arrival rate of a day-in-the-life workload is

    lambda(t) = qps * envelope(t) * burst(t)

where ``envelope`` is a smooth diurnal modulation (mean ~1 over a
period, so ``qps`` stays the day-average request rate) and ``burst`` is
an MMPP-style two-state overlay (a background/burst Markov-modulated
Poisson process): the rate multiplies by ``burst_gain`` during bursts,
with exponentially distributed burst/idle durations drawn from their
own seeded generator so the overlay never disturbs the length draws.

Everything here is deterministic per seed and evaluated as array
passes on a dense time grid; ``repro.workloads.stream`` inverts the
cumulative rate to place arrivals.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ENVELOPES = ("none", "sinusoidal", "diurnal")

# grid step (s) for cumulative-rate integration / inversion — fine
# enough to resolve minute-scale bursts, coarse enough that a week-long
# horizon stays a ~20k-point array
GRID_STEP_S = 30.0


def envelope_shape(name: str, t_s: np.ndarray, amplitude: float,
                   period_h: float, phase_h: float) -> np.ndarray:
    """Multiplicative diurnal modulation around 1.0 (clipped >= 0.05).

    ``sinusoidal``: 1 + A sin(2 pi (t + phase) / period).
    ``diurnal``: a two-peak weekday template (morning ramp, midday
    plateau, evening peak, overnight trough) — the canonical serving
    load-generator shape: a steady-state request loop whose Poisson
    arrival rate is modulated by an hour-of-day traffic profile.
    """
    t_s = np.asarray(t_s, np.float64)
    if name == "none":
        return np.ones_like(t_s)
    hod = (t_s / 3600.0 + phase_h) % period_h
    if name == "sinusoidal":
        shape = 1.0 + amplitude * np.sin(2.0 * np.pi * hod / period_h)
    elif name == "diurnal":
        # two-Gaussian peak template on a 24h-equivalent clock: morning
        # rise toward a midday plateau, a sharper evening peak, and an
        # early-morning trough; scaled so amplitude sets the swing
        h = hod * (24.0 / period_h)

        def peak(center, width):
            d = np.minimum(np.abs(h - center), 24.0 - np.abs(h - center))
            return np.exp(-0.5 * (d / width) ** 2)

        template = 0.75 * peak(11.0, 3.0) + peak(20.0, 2.5) - peak(4.0, 3.0)
        shape = 1.0 + amplitude * template
    else:
        raise ValueError(f"unknown envelope {name!r}; have {ENVELOPES}")
    return np.maximum(shape, 0.05)


@dataclasses.dataclass
class BurstOverlay:
    """Step function of the MMPP burst state: ``switch_s[i]`` is the
    time the multiplier changes to ``gain_at[i]`` (state 0 = 1.0)."""
    switch_s: np.ndarray
    gain_at: np.ndarray

    def at(self, t_s: np.ndarray) -> np.ndarray:
        t_s = np.asarray(t_s, np.float64)
        if len(self.switch_s) == 0:
            return np.ones_like(t_s)
        idx = np.searchsorted(self.switch_s, t_s, side="right") - 1
        out = np.ones_like(t_s)
        mask = idx >= 0
        out[mask] = self.gain_at[idx[mask]]
        return out

    def burst_windows(self):
        """(start, end) pairs of the burst-state intervals."""
        wins = []
        for i, g in enumerate(self.gain_at):
            if g != 1.0:
                end = (self.switch_s[i + 1]
                       if i + 1 < len(self.switch_s) else np.inf)
                wins.append((float(self.switch_s[i]), float(end)))
        return wins


def burst_overlay(seed: int, horizon_s: float, gain: float,
                  mean_on_s: float, mean_off_s: float) -> BurstOverlay:
    """Alternating exponential off/on (background/burst) state process.

    ``gain <= 1`` or ``mean_on_s <= 0`` disables the overlay (constant
    1.0). The state stream draws from its own generator keyed off the
    workload seed, so enabling bursts never shifts the length draws.
    """
    if gain <= 1.0 or mean_on_s <= 0.0:
        return BurstOverlay(np.empty(0), np.empty(0))
    rng = np.random.default_rng([seed, 0xB1157])
    switches, gains = [], []
    t = float(rng.exponential(mean_off_s))     # start in background state
    while t < horizon_s:
        on = float(rng.exponential(mean_on_s))
        switches.extend((t, t + on))
        gains.extend((gain, 1.0))
        t += on + float(rng.exponential(mean_off_s))
    return BurstOverlay(np.asarray(switches), np.asarray(gains))


def rate_on_grid(qps: float, envelope: str, amplitude: float,
                 period_h: float, phase_h: float, burst: BurstOverlay,
                 horizon_s: float, step_s: float = GRID_STEP_S):
    """(t_grid, lambda(t_grid)) over [0, horizon_s]."""
    n = max(2, int(np.ceil(horizon_s / step_s)) + 1)
    t = np.arange(n, dtype=np.float64) * step_s
    lam = (max(qps, 1e-9)
           * envelope_shape(envelope, t, amplitude, period_h, phase_h)
           * burst.at(t))
    return t, lam


def cumulative_rate(t: np.ndarray, lam: np.ndarray) -> np.ndarray:
    """Trapezoid cumulative integral Lambda(t) with Lambda(0) = 0."""
    out = np.empty_like(t)
    out[0] = 0.0
    np.cumsum(0.5 * (lam[1:] + lam[:-1]) * np.diff(t), out=out[1:])
    return out
