"""Array-native arrival streams for day-scale workloads.

``ArrivalStream`` is the columnar counterpart of ``List[Request]``: one
numpy row per request (arrival, token split, class, release). Day-scale
simulations (millions of requests) plan epochs, route, and defer as
array passes over the stream, and only *materialize* ``Request``
objects for the slices the exact event loop actually steps.

Arrival placement under a time-varying rate uses the standard
inhomogeneous-Poisson inversion: draw unit-rate exponential gaps, take
their cumulative sum ``u``, and map through the inverse cumulative rate
``Lambda^-1`` (dense-grid trapezoid integral + linear interpolation).
With the ``none`` envelope the legacy constant-rate draw is kept
bit-for-bit, and because the unit-rate path consumes the generator
identically, request *lengths* are per-seed identical across envelopes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.sim.requests import (DEFERRABLE, INTERACTIVE, Request,
                                WorkloadConfig, zipf_lengths)
from repro.workloads.envelope import (BurstOverlay, burst_overlay,
                                      cumulative_rate, rate_on_grid)


@dataclasses.dataclass
class ArrivalStream:
    """Columnar workload: row i is one request. ``ready_s`` starts as
    a copy of ``arrival_s``; epoch-granular admission (``repro.
    schedule.epochs``) shifts deferrable rows forward in place."""
    cfg: WorkloadConfig
    rid: np.ndarray              # original request ids (int64)
    arrival_s: np.ndarray
    prefill_tokens: np.ndarray
    decode_tokens: np.ndarray
    deferrable: np.ndarray       # bool
    ready_s: np.ndarray
    burst: Optional[BurstOverlay] = None

    def __len__(self) -> int:
        return len(self.arrival_s)

    @property
    def tokens(self) -> np.ndarray:
        return self.prefill_tokens + self.decode_tokens

    def sorted_by_ready(self) -> "ArrivalStream":
        """Stable reorder by ready time (deferral shifts rows forward,
        breaking arrival order); epoch slicing needs sorted ready_s."""
        order = np.argsort(self.ready_s, kind="stable")
        return self.take(order)

    def take(self, idx: np.ndarray) -> "ArrivalStream":
        return ArrivalStream(
            cfg=self.cfg, rid=self.rid[idx],
            arrival_s=self.arrival_s[idx],
            prefill_tokens=self.prefill_tokens[idx],
            decode_tokens=self.decode_tokens[idx],
            deferrable=self.deferrable[idx],
            ready_s=self.ready_s[idx], burst=self.burst)

    def window(self, t0: float, t1: float) -> "tuple[int, int]":
        """[i0, i1) row range with t0 <= ready < t1 (requires rows
        sorted by ready_s)."""
        return (int(np.searchsorted(self.ready_s, t0, side="left")),
                int(np.searchsorted(self.ready_s, t1, side="left")))

    def counts(self, bounds: np.ndarray) -> np.ndarray:
        """Per-interval request counts for sorted epoch ``bounds``
        (len(bounds)-1 intervals; requires rows sorted by ready_s)."""
        edges = np.searchsorted(self.ready_s, bounds, side="left")
        return np.diff(edges)

    def to_requests(self, lo: int = 0, hi: Optional[int] = None
                    ) -> List[Request]:
        """Materialize rows [lo, hi) as event-loop ``Request`` objects
        (identical to what ``repro.sim.requests.generate`` builds)."""
        hi = len(self) if hi is None else hi
        cfg = self.cfg
        out = []
        for i in range(lo, hi):
            arr = float(self.arrival_s[i])
            rdy = float(self.ready_s[i])
            if self.deferrable[i]:
                req = Request(
                    rid=int(self.rid[i]), arrival_s=arr,
                    prefill_tokens=int(self.prefill_tokens[i]),
                    decode_tokens=int(self.decode_tokens[i]),
                    klass=DEFERRABLE,
                    deadline_s=arr + cfg.deferrable_deadline_s)
            else:
                req = Request(
                    rid=int(self.rid[i]), arrival_s=arr,
                    prefill_tokens=int(self.prefill_tokens[i]),
                    decode_tokens=int(self.decode_tokens[i]),
                    klass=INTERACTIVE, slo_s=cfg.interactive_slo_s)
            if rdy > arr:
                req.release_s = rdy
            out.append(req)
        return out


def _invert_arrivals(cfg: WorkloadConfig, u: np.ndarray,
                     burst_seed_horizon: float) -> "tuple[np.ndarray, BurstOverlay]":
    """Map unit-rate cumulative exponentials through Lambda^-1 on a
    dense grid, doubling the grid horizon until Lambda covers u[-1].
    The burst overlay is prefix-stable in its horizon (sequential
    draws from a fresh generator), so extending the grid never moves
    already-placed switches."""
    qps = max(cfg.qps, 1e-9)
    horizon = max(float(u[-1]) / qps * 1.5, burst_seed_horizon, 600.0)
    while True:
        burst = burst_overlay(cfg.seed, horizon, cfg.burst_gain,
                              cfg.burst_mean_s, cfg.burst_idle_mean_s)
        t, lam = rate_on_grid(qps, cfg.envelope, cfg.envelope_amplitude,
                              cfg.envelope_period_h, cfg.envelope_phase_h,
                              burst, horizon)
        lam_cum = cumulative_rate(t, lam)
        if lam_cum[-1] >= u[-1]:
            return np.interp(u, lam_cum, t), burst
        horizon *= 2.0


def generate_stream(cfg: WorkloadConfig) -> ArrivalStream:
    """Deterministic per-seed arrival stream for any envelope.

    Draw order mirrors the legacy ``generate``: arrival gaps first,
    then lengths, then class tags — so lengths and classes are
    per-seed identical whichever envelope modulates the arrivals, and
    ``envelope="none"`` reproduces the legacy stream bit-for-bit.
    """
    n = cfg.n_requests
    rng = np.random.default_rng(cfg.seed)
    burst = None
    if cfg.envelope == "none" and cfg.burst_gain <= 1.0:
        # legacy constant-rate path, bit-identical to pre-envelope code
        if cfg.arrival == "poisson":
            gaps = rng.exponential(1.0 / max(cfg.qps, 1e-9), n)
        else:
            gaps = np.full(n, 1.0 / max(cfg.qps, 1e-9))
        arrivals = np.cumsum(gaps)
    else:
        # unit-rate draws consume the generator exactly like the
        # legacy scale-parameterized draw (numpy scales post-hoc), so
        # the zipf/class draws below see the same stream state
        if cfg.arrival == "poisson":
            u = np.cumsum(rng.exponential(1.0, n))
        else:
            u = np.arange(1, n + 1, dtype=np.float64)
        arrivals, burst = _invert_arrivals(cfg, u, 0.0)

    if cfg.length_dist == "zipf":
        lengths = zipf_lengths(rng, n, cfg.zipf_theta, cfg.min_len,
                               cfg.max_len)
    else:
        lengths = np.full(n, cfg.max_len, int)
    pf = cfg.pd_ratio / (cfg.pd_ratio + 1.0)
    prefills = np.maximum(1, np.round(lengths * pf)).astype(int)
    decodes = np.maximum(1, lengths - prefills).astype(int)
    if cfg.deferrable_frac > 0.0:
        deferrable = rng.random(n) < cfg.deferrable_frac
    else:
        deferrable = np.zeros(n, bool)

    return ArrivalStream(
        cfg=cfg, rid=np.arange(n, dtype=np.int64),
        arrival_s=arrivals.astype(np.float64),
        prefill_tokens=prefills.astype(np.int64),
        decode_tokens=decodes.astype(np.int64),
        deferrable=deferrable, ready_s=arrivals.astype(np.float64).copy(),
        burst=burst)
