"""Graceful degradation when ``hypothesis`` is not installed.

Property-based tests import ``given``/``settings``/``st`` from this
module instead of from hypothesis directly. With hypothesis available
these are the real objects; without it, ``@given(...)`` turns the test
into a pytest skip — the rest of the module's (example-based) tests
still collect and run, so the suite degrades instead of erroring at
collection (the seed repo's failure mode).
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call; the values are never
        drawn because the test body is skipped."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
