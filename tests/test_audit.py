"""Physics-invariant auditor pins (``repro.obs.audit``).

Three contract families:

(a) **audit neutrality** — attaching an ``AuditProbe`` (alone or
    stacked with a ``FlightRecorder`` through ``MultiProbe``) leaves
    sweep records and day summaries bitwise identical to probe-off
    runs, and every tier-1 grid audits *clean* with the expected
    contracts actually exercised (``checks`` distinguishes "clean"
    from "never checked");
(b) **injected violations** — the auditor is a pure observer, so each
    invariant is broken by feeding it a synthetic hook stream; every
    breach must be caught with correct first-violation localization
    (contract, run tag, site, stage, sim-time);
(c) **reporting mechanics** — ``strict=True`` raises ``AuditError``,
    ``max_per_contract`` caps storage and counts the overflow, and the
    markdown rendering carries the violation table.
"""
import numpy as np
import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.core.power import PowerModel
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.day import run_fleet_day
from repro.obs.audit import (CONTRACTS, EQ45_CLOSURE_RTOL, AuditError,
                             AuditProbe)
from repro.obs.probe import MultiProbe
from repro.obs.recorder import FlightRecorder
from repro.sim.hybrid import DayConfig
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig
from repro.sweep import SWEEPS, SweepRunner


def _assert_records_bit_identical(off, on):
    assert len(off) == len(on)
    for a, b in zip(off, on):
        assert a["scenario"] == b["scenario"]
        assert a["params"] == b["params"]
        assert a["key"] == b["key"]
        assert a["metrics"] == b["metrics"], a["scenario"]


# ---------------------------------------------------------------------------
# (a) neutrality + clean tier-1 grids
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sweep,n_req", [("fig1", 16), ("fleet", 10),
                                         ("shift", 10)])
def test_audit_attached_records_bit_identical_and_clean(sweep, n_req):
    scenarios = SWEEPS[sweep].build(True, n_requests=n_req)
    auditor = AuditProbe()
    off, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    on, _ = SweepRunner(cache=None, mode="event_loop",
                        probe=auditor).run(scenarios)
    _assert_records_bit_identical(off, on)
    report = auditor.report()
    assert report.ok, report.summary()
    assert report.runs == len(scenarios)
    # clean because checked, not because skipped
    core = {"clock-monotonic", "kv-budget", "batch-cap",
            "request-conservation", "request-lifecycle",
            "token-conservation", "admission-legality",
            "mfu-range", "power-range", "eq23-closure"}
    assert core <= set(report.checks), report.checks
    assert set(report.checks) <= set(CONTRACTS)
    if sweep == "shift":
        # shift horizons span multiple load bins, arming Eq. 4-5
        assert report.checks.get("eq45-closure", 0) > 0


def day_cfg(n=1200, span=900.0):
    wl = WorkloadConfig(
        n_requests=n, qps=n / span, min_len=192, max_len=192, seed=0,
        envelope="sinusoidal", envelope_amplitude=0.3,
        envelope_period_h=span / 3600.0, burst_gain=2.5,
        burst_mean_s=span / 15.0, burst_idle_mean_s=span / 2.5)
    return FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="s0", ci_trace="caiso-night",
                          scheduler=SchedulerConfig(batch_cap=64)),),
        workload=wl, router="round_robin",
        day=DayConfig(mode="hybrid", epoch_s=300.0, pilot_requests=128,
                      warmup_requests=32, util_threshold=0.6))


def test_audit_attached_day_summary_bit_identical_and_clean():
    cfg = day_cfg()
    auditor = AuditProbe()
    off = run_fleet_day(cfg).summary()
    on = run_fleet_day(cfg, probe=auditor).summary()
    assert off == on
    report = auditor.report()
    assert report.ok, report.summary()
    # epoch boundaries rewound replica clocks without tripping the
    # monotonic floor, and the day driver's rollup armed the closures
    assert report.checks.get("clock-monotonic", 0) > 0
    assert report.checks.get("eq45-closure", 0) > 0


def test_multiprobe_stacks_recorder_and_auditor():
    scenarios = SWEEPS["fig1"].build(True, n_requests=12)
    rec = FlightRecorder(resolution_s=30.0)
    auditor = AuditProbe()
    off, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    on, _ = SweepRunner(cache=None, mode="event_loop",
                        probe=MultiProbe([rec, auditor])).run(scenarios)
    _assert_records_bit_identical(off, on)
    assert rec.n_stage_events > 0          # recorder saw the run
    assert auditor.report().ok             # auditor audited it
    assert auditor.report().n_checks > 0


def test_sweep_cli_audit_flag_clean_run(tmp_path, capsys):
    from repro.sweep.cli import main
    rc = main(["fig1", "--smoke", "--n-requests", "8", "--no-cache",
               "--audit", "--quiet", "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "audit: clean" in out


# ---------------------------------------------------------------------------
# (b) injected violations: synthetic hook streams, exact localization
# ---------------------------------------------------------------------------

def _sched(kv=0, budget=4096, cap=64, running=0):
    in_flight = tuple(range(running))

    class _Cfg:
        kv_budget_tokens = budget
        batch_cap = cap

    class _S:
        cfg = _Cfg()
        kv_tokens = kv
        waiting = ()
        running = in_flight
    return _S()


class _Req:
    def __init__(self, rid, arrival=0.0, ready=0.0, first=0.1, done=0.2,
                 prefill=8, decode=8, prefill_done=None, decoded=None):
        self.rid = rid
        self.arrival_s = arrival
        self.ready_s = ready
        self.release_s = ready
        self.t_first_token = first
        self.t_done = done
        self.prefill_tokens = prefill
        self.decode_tokens = decode
        self.prefill_done = prefill if prefill_done is None else prefill_done
        self.decoded = decode if decoded is None else decoded


class _Trace:
    def __init__(self, mfu, dur_s, start_s=None, batch_size=None,
                 n_prefill_tokens=None, n_decode_tokens=None,
                 replica=None):
        self.mfu = np.asarray(mfu, np.float64)
        self.dur_s = np.asarray(dur_s, np.float64)
        # optional structural columns: the rollup's vectorized checks
        # skip whatever a trace doesn't carry
        self.start_s = (None if start_s is None
                        else np.asarray(start_s, np.float64))
        self.batch_size = (None if batch_size is None
                           else np.asarray(batch_size, np.float64))
        self.n_prefill_tokens = (
            None if n_prefill_tokens is None
            else np.asarray(n_prefill_tokens, np.float64))
        self.n_decode_tokens = (
            None if n_decode_tokens is None
            else np.asarray(n_decode_tokens, np.float64))
        self.replica = (None if replica is None
                        else np.asarray(replica, np.float64))

    def __len__(self):
        return len(self.mfu)


class _Load:
    def __init__(self, times, values):
        self.times = np.asarray(times, np.float64)
        self.values = np.asarray(values, np.float64)


def _stage(probe, t_s, site=0, replica=0, sched=None, prefill=32,
           decode=4, batch=4):
    probe.on_stage(t_s, 0.05, site, replica, sched or _sched(),
                   prefill, decode, batch)


def test_shuffled_stage_order_trips_clock_monotonic():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 1.0)
    _stage(p, 0.5)          # same (site, replica): clock went backwards
    v = p.report().first
    assert v is not None and v.contract == "clock-monotonic"
    # streamed floor violations localize by sim-time (stage index is
    # a trace-rollup concept; -1 marks not-stage-scoped)
    assert (v.run, v.site, v.stage, v.t_s) == ("synthetic", 0, -1, 0.5)
    assert "replica 0" in v.detail


def test_decoupled_replica_clocks_are_legal():
    # replica 1 lagging replica 0 is NOT a violation (per-replica floors)
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 1.0, replica=0)
    _stage(p, 0.5, replica=1)
    assert p.report().ok


def test_epoch_eval_resets_monotonic_floor():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 100.0, site=0)
    p.on_epoch_eval(0, None)
    _stage(p, 10.0, site=0)   # epoch rewound the clock: legal
    assert p.report().ok


def test_kv_budget_breach_localized():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 0.0)                                       # clean
    _stage(p, 1.0, sched=_sched(kv=5000, budget=4096))   # breach
    v = p.report().first
    assert v.contract == "kv-budget" and v.stage == -1 and v.t_s == 1.0
    assert "4096" in v.expected and v.actual == "5000"


def test_kv_budget_allows_decode_growth():
    # the budget gates admission (prompt tokens); decode then grows
    # occupancy one token per running request — legal past the budget
    sched = _sched(kv=4100, budget=4096)
    sched.running = (_Req(0, decoded=3), _Req(1, decoded=2))
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 0.0, sched=sched)            # 4100 <= 4096 + 5
    assert p.report().ok
    sched.running = (_Req(0, decoded=3),)  # 4100 > 4096 + 3
    _stage(p, 1.0, sched=sched)
    assert p.report().first.contract == "kv-budget"
    assert "decode-grown" in p.report().first.expected


def test_batch_cap_breach():
    # batch sizes are audited vectorized from the committed trace at
    # rollup; on_stage only registers the site's cap
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 0.0, sched=_sched(cap=8), batch=4)
    p.on_site_rollup(0, "synthetic",
                     _Trace([0.3, 0.4], [0.05, 0.05],
                            start_s=[0.0, 0.1], batch_size=[4, 9]),
                     "a100", 1)
    v = p.report().first
    assert v.contract == "batch-cap" and v.stage == 1
    assert "batch=9" in v.actual and "<= 8" in v.expected


def test_dropped_request_caught_at_finalize():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    for rid in range(4):                       # 4 routed ...
        p.on_route(0.1 * rid, rid, site=0)
    p.on_requests(np.zeros(5), np.zeros(5))    # ... of 5 generated
    v = p.report().first
    assert v.contract == "request-conservation"
    assert (v.site, v.stage, v.t_s) == (-1, -1, -1.0)
    assert v.expected == "5 requests routed" and v.actual == "4 routed"


def test_duplicate_route_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_route(0.0, 7, site=0)
    p.on_route(0.1, 7, site=1)
    v = p.report().first
    assert v.contract == "request-conservation" and v.site == 1
    assert "rid 7" in v.expected and v.actual == "routed again"


def test_route_order_regression_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_route(1.0, 0, site=0)
    p.on_route(0.5, 1, site=0)
    v = p.report().first
    assert v.contract == "clock-monotonic"
    assert "ready order" in v.detail


def test_completions_exceeding_admissions_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_route(0.0, 0, site=0)
    _stage(p, 0.1)
    p.on_complete(0.2, 0, 0, [_Req(0), _Req(1)])   # 2 done, 1 admitted
    v = p.report().first
    assert v.contract == "request-conservation"
    assert v.expected == "completed <= 1 admitted"
    assert v.actual == "2 completed"


def test_request_lifecycle_partial_decode_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 0.0)
    p.on_complete(0.2, 0, 0, [_Req(0, decode=8, decoded=5)])
    v = p.report().first
    assert v.contract == "request-lifecycle" and v.t_s == 0.2
    assert "decoded 5/8" in v.actual


def test_token_conservation_caught():
    # completion events stream in; the comparison against the trace's
    # staged-token cumsum runs vectorized at rollup
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 0.0, prefill=8, decode=2)
    p.on_complete(0.1, 0, 0, [_Req(0, prefill=8, decode=8)])
    p.on_site_rollup(0, "synthetic",
                     _Trace([0.3], [0.05], start_s=[0.0],
                            n_prefill_tokens=[8], n_decode_tokens=[2]),
                     "a100", 1)             # staged: 8p / 2d
    v = p.report().first
    assert v.contract == "token-conservation" and v.t_s == 0.1
    assert "8p/2d" in v.expected and "8p/8d" in v.actual


def test_autoscale_illegal_transitions_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_scale(0.0, 0, 2, 1, "up_warm")
    p.on_scale(1.0, 0, 4, 0, "up_cold")     # active stepped by two
    v = p.report().first
    assert v.contract == "autoscale-legality"
    assert v.actual == "up_cold: n_active 2 -> 4"

    p2 = AuditProbe()
    p2.on_run_begin("synthetic")
    p2.on_scale(0.0, 0, 1, 0, "teleport")   # unknown kind
    assert p2.report().first.actual == "kind='teleport'"


def test_admission_before_arrival_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_requests(np.array([1.0, 2.0]), np.array([1.0, 1.5]))
    v = p.report().first
    assert v.contract == "admission-legality"
    assert "request index 1" in v.detail


def test_mfu_out_of_range_caught():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_site_rollup(0, "s0", _Trace([0.3, 1.5, 0.2], [1.0, 1.0, 1.0]),
                     "a100", 8)
    v = p.report().first
    assert v.contract == "mfu-range" and v.stage == 1
    assert "1.5" in v.actual


def test_eq23_closure_clean_then_scaled_energy_caught():
    mfu = [0.2, 0.5, 0.4]
    dur = [1.0, 2.0, 0.5]
    p_w = np.asarray(PowerModel("a100").power(np.asarray(mfu)),
                     np.float64)
    wh = float(np.sum(p_w * np.asarray(dur) * 8 * 1.2 / 3600.0))

    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_site_rollup(0, "s0", _Trace(mfu, dur), "a100", 8, pue=1.2,
                     energy_wh=wh)
    assert p.report().ok        # exact per-stage sum closes Eq. 2-3

    p.on_site_rollup(0, "s0", _Trace(mfu, dur), "a100", 8, pue=1.2,
                     energy_wh=wh * 1.01)    # scaled power column
    v = p.report().first
    assert v.contract == "eq23-closure" and v.site == 0
    assert "Wh" in v.expected


def test_eq45_closure_clean_then_perturbed_cosim_caught():
    times = np.arange(0.0, 600.0, 60.0)
    vals = np.full(len(times), 1000.0)      # flat 1 kW load
    e_kwh = float(vals.sum()) * 60.0 / 3600.0 / 1000.0
    kg = float(np.sum(vals * 400.0)) * 60.0 / 3600.0 / 1e6
    cosim = {"total_energy_kwh": e_kwh,
             "total_emissions_nosolar_kg": kg}

    p = AuditProbe()
    p.on_run_begin("synthetic")
    p.on_site_rollup(0, "s0", _Trace([], []), "a100", 8, ci=400.0,
                     cosim=dict(cosim), load=_Load(times, vals))
    assert p.report().ok
    assert p.report().checks.get("eq45-closure", 0) == 2

    bad = dict(cosim)
    bad["total_energy_kwh"] = e_kwh * (1.0 + 10 * EQ45_CLOSURE_RTOL)
    p.on_site_rollup(0, "s0", _Trace([], []), "a100", 8, ci=400.0,
                     cosim=bad, load=_Load(times, vals))
    v = p.report().first
    assert v.contract == "eq45-closure" and "kWh" in v.expected


# ---------------------------------------------------------------------------
# (c) reporting mechanics
# ---------------------------------------------------------------------------

def test_strict_mode_raises_at_first_breach():
    p = AuditProbe(strict=True)
    p.on_run_begin("synthetic")
    _stage(p, 1.0)
    with pytest.raises(AuditError) as ei:
        _stage(p, 0.5)
    assert ei.value.violation.contract == "clock-monotonic"


def test_max_per_contract_caps_storage_and_counts_dropped():
    p = AuditProbe(max_per_contract=2)
    p.on_run_begin("synthetic")
    for k in range(5):
        _stage(p, 0.1 * k, sched=_sched(kv=9999, budget=4096))
    report = p.report()
    assert len(report.violations) == 2 and report.dropped == 3
    assert "+3 beyond cap" in report.summary()
    assert report.by_contract() == {"kv-budget": 2}


def test_report_serialization_and_markdown():
    p = AuditProbe()
    p.on_run_begin("synthetic")
    _stage(p, 1.0)
    _stage(p, 0.5)
    report = p.report()
    d = report.to_dict()
    assert d["ok"] is False and d["runs"] == 1
    assert d["by_contract"] == {"clock-monotonic": 1}
    assert d["violations"][0]["contract"] == "clock-monotonic"
    md = report.to_markdown()
    assert "# Audit report" in md and "clock-monotonic" in md
    assert "## Violations" in md
