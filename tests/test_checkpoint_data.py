"""Checkpointing (atomicity, async, retention) + data pipeline tests."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.data import DataConfig, SyntheticLM


@pytest.fixture
def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "inner": {"b": jnp.ones((5,)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=3)
    restored, manifest = restore_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_invisible(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=1)
    p = save_checkpoint(str(tmp_path), tree, step=2)
    os.remove(os.path.join(p, "COMMIT"))  # simulate crash mid-write
    assert latest_step(str(tmp_path)) == 1


def test_async_manager_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(tree, s)
    mgr.wait()
    mgr._gc()
    steps = sorted(int(d.name.split("_")[1])
                   for d in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_shape_mismatch_rejected(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=1)
    bad = {"w": jnp.zeros((2, 2)),
           "inner": {"b": jnp.ones((5,)), "step": jnp.int32(0)}}
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), bad)


# ---------------------------- data ----------------------------

def test_data_deterministic():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=9)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_shards_disjoint():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8)
    ds = SyntheticLM(cfg)
    a = ds.batch(0, shard=0, n_shards=2)
    b = ds.batch(0, shard=1, n_shards=2)
    assert a["tokens"].shape == (4, 64)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512
