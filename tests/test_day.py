"""Fluid/request hybrid day simulation (repro.sim.hybrid +
repro.fleet.day): cross-mode agreement, fluid==exact degeneration,
autoscale planning, saturated-epoch exactness, and the schema-6
golden record pins (fig1 single-site, fleet rollup, shift policy).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.fleet.autoscale import AutoscalerConfig, plan_replicas
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.day import run_fleet_day
from repro.sim.hybrid import (DayConfig, Epoch, epoch_bounds,
                              evaluate_epoch, plan_epochs)
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig
from repro.sim.trace import StageTrace
from repro.sweep import SWEEPS, execute_scenario
from repro.sweep.scenarios import DAY_FLUID_RTOL, day_agreement
from repro.workloads import generate_stream

from _hypothesis_support import given, settings, st


def day_cfg(mode, n=3000, span=1800.0, **day_kw):
    wl = WorkloadConfig(
        n_requests=n, qps=n / span, min_len=192, max_len=192, seed=0,
        envelope="sinusoidal", envelope_amplitude=0.3,
        envelope_period_h=span / 3600.0, burst_gain=2.5,
        burst_mean_s=span / 15.0, burst_idle_mean_s=span / 2.5)
    return FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="s0", ci_trace="caiso-night",
                          scheduler=SchedulerConfig(batch_cap=64)),),
        workload=wl, router="round_robin",
        day=DayConfig(mode=mode, epoch_s=300.0, pilot_requests=128,
                      warmup_requests=32, util_threshold=0.6, **day_kw))


# ---------------------------------------------- cross-mode agreement ----

@pytest.mark.slow
def test_hybrid_agrees_with_event_loop_day():
    """The day-smoke acceptance, at test scale: identical epoch plans,
    planned-exact epochs bit-for-bit, fluid epochs and day totals
    within DAY_FLUID_RTOL — via the same ``day_agreement`` the CI job
    asserts on."""
    records = []
    for mode in ("hybrid", "event_loop"):
        m = run_fleet_day(day_cfg(mode)).summary()
        records.append({"params": {"mode": mode},
                        "metrics": m, "meta": {"elapsed_s": 1.0}})
    agree = day_agreement(records)
    assert agree["n_pairs"] == 1
    assert agree["plans_match"] == 1.0
    assert agree["exact_max_rel"] == 0.0          # bit-for-bit
    assert agree["fluid_max_rel"] < DAY_FLUID_RTOL
    assert agree["total_max_rel"] < DAY_FLUID_RTOL
    assert agree["n_exact_epochs"] >= 1           # bursts present
    assert agree["n_fluid_epochs"] >= 1


@pytest.mark.slow
def test_day_sweep_smoke_records_agree():
    """The actual day sweep scenarios (what CI runs) pair up and pass
    the agreement gate at reduced request count."""
    scenarios = [s for s in SWEEPS["day"].build(True, n_requests=4000)
                 if s.params["autoscale"] == 0]
    records = [execute_scenario(s) for s in scenarios]
    agree = day_agreement(records)
    assert agree["n_pairs"] == 1
    assert agree["plans_match"] == 1.0
    assert agree["exact_max_rel"] == 0.0
    assert agree["fluid_max_rel"] < DAY_FLUID_RTOL
    assert agree["total_max_rel"] < DAY_FLUID_RTOL


# ---------------------------------------------- fluid == exact ----

def _steady_cfg(mode, seed=0, pilot=4000):
    """A transient-free day: flat envelope, no bursts, no deferral —
    every epoch plans fluid, and a pilot budget >= the per-epoch count
    makes the fluid evaluation degenerate to the exact run."""
    wl = WorkloadConfig(n_requests=1500, qps=1.0, min_len=128,
                        max_len=128, seed=seed)
    return FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="s0", ci_trace="caiso",
                          scheduler=SchedulerConfig(batch_cap=32)),),
        workload=wl, router="round_robin",
        day=DayConfig(mode=mode, epoch_s=300.0, pilot_requests=pilot,
                      warmup_requests=0))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_fluid_equals_exact_without_transients(seed):
    """On windows with no transients and full pilot coverage the
    hybrid mode IS the event loop: summaries match bit-for-bit."""
    hyb = run_fleet_day(_steady_cfg("hybrid", seed)).summary()
    exa = run_fleet_day(_steady_cfg("event_loop", seed)).summary()
    assert hyb.keys() == exa.keys()
    for k in hyb:
        assert hyb[k] == exa[k], k


def test_fluid_equals_exact_without_transients_example():
    hyb = run_fleet_day(_steady_cfg("hybrid")).summary()
    exa = run_fleet_day(_steady_cfg("event_loop")).summary()
    assert hyb["sim_fraction"] == 1.0     # degenerate: everything ran
    for k in hyb:
        assert hyb[k] == exa[k], k


# ---------------------------------------------- epoch planning ----

def _saturated_cfg(mode):
    """Demand that saturates the roofline's actual capacity while
    staying comfortably under the autoscaler's *configured* estimate:
    batch_cap=1 crushes per-replica throughput to ~970 tok/s while the
    stream offers ~1730 tok/s — below the default 4000 tok/s estimate
    the planner used to trust, so before the model-derived floor these
    epochs were misplanned as fluid (tiling a growing queue)."""
    wl = WorkloadConfig(n_requests=450, qps=9.0, min_len=192, max_len=192,
                        seed=3)
    return FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="s0", ci_trace="caiso",
                          scheduler=SchedulerConfig(batch_cap=1)),),
        workload=wl, router="round_robin",
        day=DayConfig(mode=mode, epoch_s=25.0, pilot_requests=64,
                      warmup_requests=16, util_threshold=0.6))


def test_saturated_epochs_run_exact():
    """ROADMAP fluid-fidelity gap: queue-saturated epochs must run
    exact via util_threshold even when the configured capacity
    estimate is optimistic. The planner's saturation check uses
    min(configured, roofline) capacity; with the whole window
    saturated the hybrid day IS the event-loop day, bit-for-bit."""
    # the planner sees saturation only through the model-derived floor
    cfg = _saturated_cfg("hybrid")
    stream = generate_stream(cfg.workload).sorted_by_ready()
    bounds = epoch_bounds(float(stream.ready_s[-1]), 25.0)
    ones = np.ones(len(bounds) - 1, int)
    blind = plan_epochs(stream, bounds, cfg.day, tokens_per_s=4000.0,
                        replica_plan=ones)
    floored = plan_epochs(stream, bounds, cfg.day, tokens_per_s=4000.0,
                          replica_plan=ones, sat_tokens_per_s=967.0)
    assert not any(e.reason == "saturation" for e in blind)
    assert any(e.reason == "saturation" for e in floored)

    hyb = run_fleet_day(_saturated_cfg("hybrid")).summary()
    exa = run_fleet_day(_saturated_cfg("event_loop")).summary()
    assert hyb["n_exact_saturation"] >= 1
    assert hyb["n_fluid_epochs"] == 0.0
    assert hyb["sim_fraction"] == 1.0
    assert hyb.keys() == exa.keys()
    for k in hyb:                     # latency percentiles included
        assert hyb[k] == exa[k], k


def test_plan_epochs_marks_transients():
    """Burst/ramp/drain/saturation classification from the stream
    alone — identical plans whichever mode later evaluates them."""
    wl = WorkloadConfig(n_requests=4000, qps=4000 / 3600.0, min_len=192,
                        max_len=192, seed=0, envelope="sinusoidal",
                        envelope_amplitude=0.4, envelope_period_h=1.0,
                        burst_gain=3.0, burst_mean_s=240.0,
                        burst_idle_mean_s=1200.0)
    stream = generate_stream(wl).sorted_by_ready()
    bounds = epoch_bounds(float(stream.ready_s[-1]), 300.0)
    day = DayConfig(epoch_s=300.0, util_threshold=0.6)
    plan_a = plan_epochs(stream, bounds, day, tokens_per_s=700.0,
                         replica_plan=np.ones(len(bounds) - 1, int))
    plan_b = plan_epochs(stream, bounds, day, tokens_per_s=700.0,
                         replica_plan=np.ones(len(bounds) - 1, int))
    assert [dataclasses.asdict(e) for e in plan_a] == \
           [dataclasses.asdict(e) for e in plan_b]
    reasons = {e.reason for e in plan_a}
    assert "steady" in reasons
    assert reasons & {"burst", "ramp", "saturation"}
    # replica-plan changes mark the epoch transient
    rp = np.ones(len(bounds) - 1, int)
    rp[2:] = 2
    plan_c = plan_epochs(stream, bounds, day, tokens_per_s=700.0,
                         replica_plan=rp)
    assert plan_c[2].reason == "autoscale" and plan_c[2].planned == "exact"


def test_evaluate_epoch_extends_pilot_past_release_clump():
    """A sub-threshold deferral clump (hundreds of rows at one ready
    instant) must not silently degrade the fluid epoch to a full exact
    run — the pilot extends past the clump instead."""
    n, t0, t1 = 3000, 0.0, 600.0
    clump = 500                        # > pilot budget, < drain mass
    ready = np.concatenate([np.full(clump, 1.0),
                            np.linspace(2.0, t1 - 1.0, n - clump)])
    wl = WorkloadConfig(n_requests=n, qps=n / t1, min_len=64, max_len=64)
    from repro.workloads.stream import ArrivalStream
    stream = ArrivalStream(
        cfg=wl, rid=np.arange(n, dtype=np.int64), arrival_s=ready.copy(),
        prefill_tokens=np.full(n, 32, np.int64),
        decode_tokens=np.full(n, 32, np.int64),
        deferrable=np.zeros(n, bool), ready_s=ready)
    epoch = Epoch(index=0, t0=t0, t1=t1, i0=0, i1=n)
    day = DayConfig(pilot_requests=128, warmup_requests=32)

    calls = []

    def run_window(ep, lo, hi):
        calls.append((lo, hi))
        rows = stream.to_requests(lo, hi)
        for r in rows:
            r.t_first_token = r.ready_s + 0.01
            r.t_done = r.ready_s + 0.05
        cols = {f.name: np.zeros(hi - lo) for f in
                dataclasses.fields(StageTrace)}
        cols["start_s"] = stream.ready_s[lo:hi].astype(np.float64)
        cols["dur_s"] = np.full(hi - lo, 0.01)
        return StageTrace(**cols), rows

    ev = evaluate_epoch(epoch, stream, day, run_window)
    assert ev.executed == "fluid"
    # pilot extended past the clump, but nowhere near the full epoch
    assert clump < ev.n_simulated < n
    assert calls == [(0, ev.n_simulated)]
    assert ev.n_requests == n
    assert ev.weight > 1.0


# ---------------------------------------------- autoscale plan ----

def test_plan_replicas_scales_with_demand():
    cfg = AutoscalerConfig(enabled=True, min_replicas=1, max_replicas=4,
                           target_util=0.5, warm_spares=1,
                           tokens_per_s=1000.0, ci_scale_down_g=0.0)
    util1 = np.array([0.3, 0.3, 1.2, 1.2, 0.3, 0.3])
    ci = np.full(6, 400.0)
    active, warm, stats = plan_replicas(cfg, util1, ci, n_initial=1)
    assert active.tolist() == [1, 1, 3, 3, 2, 1]   # eager up, 1-step down
    assert stats["scale_ups"] == 2.0
    assert stats["scale_downs"] == 2.0
    assert warm.max() <= cfg.warm_spares
    # carbon-aware scale-down: clean grid hours keep spares active
    clean = np.full(6, 50.0)
    cfg2 = dataclasses.replace(cfg, ci_scale_down_g=100.0)
    active2, _, stats2 = plan_replicas(cfg2, util1, clean, n_initial=1)
    assert stats2["scale_downs"] == 0.0
    assert active2[-1] == 3                        # never shrank


def test_day_autoscaler_tracks_diurnal_swing():
    """End-to-end: the autoscaled day scales up into the peak and back
    down, and autoscale epochs run exact in hybrid mode."""
    cfg = day_cfg("hybrid")
    asc = AutoscalerConfig(
        enabled=True, min_replicas=1, max_replicas=3, target_util=0.6,
        scale_up_latency_s=60.0, warm_spares=1,
        tokens_per_s=160.0 * 3000 / 4000.0 / 0.5, ci_scale_down_g=0.0)
    site = dataclasses.replace(cfg.sites[0], autoscaler=asc)
    cfg = dataclasses.replace(cfg, sites=(site,))
    m = run_fleet_day(cfg).summary()
    assert m["scale_ups"] >= 1 and m["scale_downs"] >= 1
    assert m["replica_peak"] >= 2
    assert m["n_exact_autoscale"] >= 1


# ---------------------------------------------- golden record pins ----

#: fig1's qps=6.45 smoke scenario — the schema migrations since v4
#: (v5 day-scale config defaults, v6 saturation capacity floor) are
#: metric-preserving on non-day grids, so these values are pinned
#: bit-for-bit; any drift means cached and fresh sweep results have
#: silently diverged
GOLDEN_FIG1_QPS645 = {
    "energy_wh": 1.4322530783827812,
    "energy_kwh": 0.0014322530783827813,
    "avg_power_w": 293.5191164933444,
    "peak_power_w": 400.0,
    "avg_mfu": 0.3040923303275911,
    "duration_s": 14.638771356637594,
    "gpu_hours": 0.004066325376843776,
    "throughput_qps": 3.255520259822209,
    "n_stages": 310,
    "avg_batch": 13.716129032258065,
    "carbon_operational_g": 0.3580632695956953,
    "carbon_embodied_g": 0.01392577183850608,
    "carbon_total_g": 0.37198904143420136,
    "grid_ci_g_per_kwh": 250.0,
    "ttft_p50_s": 0.9966152897386282,
    "ttft_p99_s": 3.055099094040332,
    "e2e_p50_s": 6.7863183083521825,
    "e2e_p99_s": 11.298379396552983,
}


def test_schema6_fig1_golden_record_bitwise():
    # drift fails *through* the diff explainer: the raised error names
    # the first divergent cell (dependency order) and the report path,
    # and CI uploads results/obs/divergence/ as an artifact
    from repro.obs import assert_golden
    from repro.sweep import SCHEMA_VERSION
    assert SCHEMA_VERSION == 6
    scenario = SWEEPS["fig1"].build(True)[1]
    assert scenario.params["qps"] == 6.45
    metrics = execute_scenario(scenario)["metrics"]
    assert_golden(metrics, GOLDEN_FIG1_QPS645, "golden_fig1_qps645")


#: first fleet smoke scenario (a100+a100, hydro+coal, round_robin) —
#: pins the multi-site rollup path the single-site fig1 golden never
#: touches (per-site CI integration, router accounting)
GOLDEN_FLEET_0 = {
    'energy_wh': 1.092477023949911,
    'avg_power_w': 171.74517346211357,
    'gpu_hours': 0.005300862327633853,
    'avg_mfu': 0.09211997066701397,
    'duration_s': 10.909038240255882,
    'throughput_qps': 5.866694990932475,
    'carbon_operational_g': 2.7582991123199463,
    'carbon_active_g': 0.43435087210468903,
    'carbon_embodied_g': 0.01815363810833511,
    'carbon_total_g': 2.7764527797698975,
    'n_sites': 2.0,
    'n_requests_done': 64.0,
    'ttft_p50_s': 0.07319967537753103,
    'ttft_p99_s': 0.15043498201783967,
    'e2e_p50_s': 0.629958418846202,
    'e2e_p99_s': 1.36738208669471,
    's0-hydro_n_requests': 32.0,
    's0-hydro_energy_wh': 0.54200207712589,
    's0-hydro_carbon_g': 0.23574601113796234,
    's0-hydro_avg_ci': 69.99655973382168,
    's1-coal_n_requests': 32.0,
    's1-coal_energy_wh': 0.5504749468240211,
    's1-coal_carbon_g': 2.5225532054901123,
    's1-coal_avg_ci': 720.0170157548899,
}

#: first shift smoke scenario (immediate policy, oracle forecaster,
#: carbon_slo router) — pins the temporal-scheduling path: workload
#: classes, deferral accounting, CI-aware routing
GOLDEN_SHIFT_0 = {
    'energy_wh': 2.302418519809514,
    'avg_power_w': 140.14964174039451,
    'gpu_hours': 0.013690239061726056,
    'avg_mfu': 0.04909326371791185,
    'duration_s': 25200.0,
    'throughput_qps': 0.0038095238095238095,
    'carbon_operational_g': 702.7404174804688,
    'carbon_active_g': 0.19239934051510488,
    'carbon_embodied_g': 0.04688438034837691,
    'carbon_total_g': 702.7872924804688,
    'n_requests_done': 96.0,
    'n_interactive': 52.0,
    'n_deferrable': 44.0,
    'deferred_fraction': 0.0,
    'interactive_ttft_p50_s': 0.06128641906161647,
    'interactive_ttft_p99_s': 0.10634317996388745,
    'deferrable_e2e_p50_s': 0.4926409237589269,
    'deferrable_e2e_p99_s': 1.0048028740638801,
    'interactive_slo_violations': 0.0,
    'deadline_violations': 0.0,
    's0-hydro-evening_n_requests': 96.0,
    's0-hydro-evening_energy_wh': 2.302418519809514,
    's0-hydro-evening_carbon_g': 72.885009765625,
    's1-coal-evening_n_requests': 0.0,
    's1-coal-evening_carbon_g': 629.8554077148438,
    's1-coal-evening_avg_ci': 749.8277178943864,
}


def test_schema6_fleet_golden_record_bitwise():
    from repro.obs import assert_golden
    scenario = SWEEPS["fleet"].build(True)[0]
    assert scenario.params["devices"] == "a100+a100"
    metrics = execute_scenario(scenario)["metrics"]
    assert_golden(metrics, GOLDEN_FLEET_0, "golden_fleet_0")


def test_schema6_shift_golden_record_bitwise():
    from repro.obs import assert_golden
    scenario = SWEEPS["shift"].build(True)[0]
    assert scenario.params["policy"] == "immediate"
    metrics = execute_scenario(scenario)["metrics"]
    assert_golden(metrics, GOLDEN_SHIFT_0, "golden_shift_0")
