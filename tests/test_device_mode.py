"""Device-mode equivalence + trace-divergence soundness pins.

The device-batched runner (``--mode device``) evaluates the whole grid
as one jit+vmap program, so its contract is looser than vectorized
mode's bitwise guarantee: device-computed energy/power/carbon columns
must agree with the event loop within ``DEVICE_MODE_RTOL`` while every
host-side column (MFU, timing, throughput, latency percentiles, stage
counts) stays bit-identical. This file pins that contract on every
benchmark grid, exercises the padding/masking machinery on ragged and
empty groups, and proves the trace-divergence analysis *sound*:
whenever ``trace_shareable`` accepts a config family, the
independently event-loop-generated traces really do share one batch
composition and ``replay_result`` reproduces the full ``SimResult``
bit-for-bit.
"""
import dataclasses
import hashlib
import json

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.paper_models import PAPER_MODELS
from repro.core.power import DEVICES
from repro.sim import (PAPER_DEFAULT, SchedulerConfig, SimConfig,
                       WorkloadConfig, run_simulation)
from repro.sim.execmodel import (JAX_BACKEND_RTOL, ExecutionModel,
                                 StageBatch)
from repro.sim.trace import StageTrace
from repro.sweep import SCHEMA_VERSION, SWEEPS, SweepRunner
from repro.sweep import divergence
from repro.sweep.device import (DEVICE_MODE_RTOL, execute_device_grid,
                                records_max_rel_err)
from repro.sweep.grid import Scenario
from repro.sweep.runner import execute_scenario

# columns the device program computes on-accelerator (f32 Eq.1 power +
# reassociated reductions -> rtol-bounded); everything else is
# host-side and must stay bit-identical to the event loop
DEVICE_COLS = frozenset({
    "energy_wh", "energy_kwh", "avg_power_w", "peak_power_w",
    "duration_s", "gpu_hours", "carbon_operational_g",
    "carbon_embodied_g", "carbon_total_g",
})


def _assert_device_contract(ev, dv):
    assert len(ev) == len(dv)
    for a, b in zip(ev, dv):
        assert a["scenario"] == b["scenario"]
        assert a["params"] == b["params"]
        assert a["key"] == b["key"]
        for col, va in a["metrics"].items():
            vb = b["metrics"][col]
            if col in DEVICE_COLS:
                assert vb == pytest.approx(va, rel=DEVICE_MODE_RTOL), \
                    (col, a["scenario"])
            else:
                assert vb == va, (col, a["scenario"])
    assert records_max_rel_err(dv, ev) <= DEVICE_MODE_RTOL


# ---------------------------------------------------------------------------
# runner-mode equivalence on the pinned benchmark grids
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sweep", ["fig1", "fig3", "exp5"])
def test_device_matches_event_loop_single_site(sweep):
    scenarios = SWEEPS[sweep].build(True, n_requests=16)
    ev, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    dv, _ = SweepRunner(cache=None, mode="device").run(scenarios)
    _assert_device_contract(ev, dv)


def test_device_matches_event_loop_perf_grid():
    # the full perf smoke grid: plane A (workload x pue x grid_ci) plus
    # plane B (device x tp x pp hardware family over one isolated
    # stream) — the grid the CI perf gate times and pins
    scenarios = SWEEPS["perf"].build(True, n_requests=16)
    ev, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    dv, stats = SweepRunner(cache=None, mode="device").run(scenarios)
    _assert_device_contract(ev, dv)
    # plane B's 8 hardware configs form one shareable family (uniform
    # isolated arrivals), so only plane A's 4 workloads run the loop
    assert stats.trace_groups == 12
    assert stats.replayed == 8
    assert stats.event_loops == 4


@pytest.mark.parametrize("sweep", ["fleet", "shift"])
def test_device_fleet_passthrough_bit_identical(sweep):
    # FleetConfig scenarios bypass the device program entirely — the
    # fleet rollup runs as-is, so records stay bitwise
    scenarios = SWEEPS[sweep].build(True, n_requests=10)
    ev, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    dv, _ = SweepRunner(cache=None, mode="device").run(scenarios)
    for a, b in zip(ev, dv):
        assert a["key"] == b["key"]
        assert a["metrics"] == b["metrics"], a["scenario"]


# ---------------------------------------------------------------------------
# padding/masking: ragged, empty, and single-stage groups
# ---------------------------------------------------------------------------

def _device_vs_event_loop(scenarios):
    dv, _ = execute_device_grid(scenarios)
    ev = [execute_scenario(sc) for sc in scenarios]
    _assert_device_contract(ev, dv)


def test_padding_empty_and_single_stage_groups():
    # deterministic coverage of the mask edge cases independent of
    # hypothesis availability: an empty trace, a single-stage trace
    # (one request, one prefill + one decode), and a ragged large group
    wls = [WorkloadConfig(n_requests=0, qps=1.0, seed=0),
           WorkloadConfig(n_requests=1, qps=1.0, min_len=8, max_len=8,
                          pd_ratio=8.0, seed=1),
           WorkloadConfig(n_requests=12, qps=6.0, min_len=32,
                          max_len=128, seed=2)]
    scenarios = []
    for j, wl in enumerate(wls):
        cfg = dataclasses.replace(PAPER_DEFAULT, workload=wl)
        for i in range(j + 1):          # ragged scenario fan-out 1/2/3
            scenarios.append(Scenario(cfg=cfg, params={"g": j, "i": i},
                                      pue=1.0 + 0.15 * i,
                                      grid_ci=100.0 * (i + 1)))
    _device_vs_event_loop(scenarios)


@given(st.lists(st.tuples(st.integers(0, 6),
                          st.sampled_from([0.5, 2.0, 8.0]),
                          st.integers(1, 3)),
                min_size=1, max_size=4),
       st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_padding_and_masking_property(groups, seed):
    # arbitrary ragged group sizes (incl. empty workloads) and scenario
    # fan-outs: padded lanes must never leak into real outputs
    scenarios = []
    for j, (n, qps, k) in enumerate(groups):
        wl = WorkloadConfig(n_requests=n, qps=qps, min_len=8,
                            max_len=48, seed=seed + j)
        cfg = dataclasses.replace(PAPER_DEFAULT, workload=wl)
        for i in range(k):
            scenarios.append(Scenario(cfg=cfg, params={"g": j, "i": i},
                                      pue=1.0 + 0.1 * i,
                                      grid_ci=50.0 * (i + 1)))
    _device_vs_event_loop(scenarios)


# ---------------------------------------------------------------------------
# trace-divergence analysis: soundness of the sharing predicate
# ---------------------------------------------------------------------------

_HW = [("a100", 1, 1), ("a100", 2, 1), ("a100", 1, 2), ("a100", 2, 2),
       ("h100", 1, 1), ("h100", 2, 1), ("h100", 1, 2), ("h100", 2, 2)]

_COMPOSITION = ("n_prefill_tokens", "n_decode_tokens", "score_flops",
                "kv_rw_bytes", "batch_size")


def _assert_family_sound(cfgs):
    """trace_shareable accepted this family: prove it was right."""
    results = [run_simulation(c) for c in cfgs]
    base = results[0].stages.iteration_rows(cfgs[0].pp)
    for c, r in zip(cfgs, results):
        it = r.stages.iteration_rows(c.pp)
        for col in _COMPOSITION:
            assert np.array_equal(getattr(it, col),
                                  getattr(base, col)), (col, c.device,
                                                        c.tp, c.pp)
        # and the replay reconstructs the full result bit-for-bit
        rp = divergence.replay_result(c)
        for f in dataclasses.fields(StageTrace):
            assert np.array_equal(getattr(rp.stages, f.name),
                                  getattr(r.stages, f.name)), \
                (f.name, c.device, c.tp, c.pp)
        assert len(rp.requests) == len(r.requests)
        for a, b in zip(rp.requests, r.requests):
            assert (a.t_first_token, a.t_done, a.decoded, a.prefilled) \
                == (b.t_first_token, b.t_done, b.decoded, b.prefilled)


def test_divergence_sharing_sound_on_perf_family():
    # the exact family the perf grid shares: every plane-B hardware
    # point replays one uniform isolated stream bit-identically
    wl = WorkloadConfig(n_requests=8, qps=0.5, arrival="uniform",
                        min_len=64, max_len=256, seed=0)
    cfgs = [dataclasses.replace(PAPER_DEFAULT, workload=wl, device=d,
                                tp=tp, pp=pp) for d, tp, pp in _HW]
    ok, reason = divergence.trace_shareable(cfgs)
    assert ok, reason
    _assert_family_sound(cfgs)


@given(st.integers(1, 5), st.floats(0.05, 0.4),
       st.integers(0, 2**16),
       st.lists(st.sampled_from(_HW), min_size=2, max_size=4,
                unique=True))
@settings(max_examples=8, deadline=None)
def test_divergence_soundness_property(n, qps, seed, hw):
    # hypothesis-generated arrival streams: whenever the conservative
    # predicate declares the family shareable, the independently
    # event-loop-generated traces must be bit-equal in composition and
    # the replay bit-equal in full (a reject is always allowed — the
    # predicate is conservative, not complete)
    wl = WorkloadConfig(n_requests=n, qps=qps, arrival="uniform",
                        min_len=16, max_len=64, seed=seed)
    cfgs = [dataclasses.replace(PAPER_DEFAULT, workload=wl, device=d,
                                tp=tp, pp=pp) for d, tp, pp in hw]
    ok, _ = divergence.trace_shareable(cfgs)
    if ok:
        _assert_family_sound(cfgs)


def test_divergence_predicate_rejects_unsafe_families():
    base = dataclasses.replace(
        PAPER_DEFAULT,
        workload=WorkloadConfig(n_requests=64, qps=50.0, seed=0))
    # tight poisson arrivals: gaps under the service bound
    cfgs = [dataclasses.replace(base, device=d, tp=tp, pp=pp)
            for d, tp, pp in (("a100", 1, 1), ("h100", 2, 1))]
    ok, reason = divergence.trace_shareable(cfgs)
    assert not ok
    assert "gap" in reason
    # chunked prefill: schedules depend on timing even when isolated
    wl = WorkloadConfig(n_requests=4, qps=0.1, arrival="uniform",
                        min_len=64, max_len=128, seed=0)
    chunked = dataclasses.replace(
        PAPER_DEFAULT, workload=wl,
        scheduler=SchedulerConfig(chunk_prefill=256))
    ok, reason = divergence.trace_shareable([chunked, chunked])
    assert not ok
    assert "chunked" in reason
    # non-hardware divergence: different batch caps are not a family
    a = dataclasses.replace(PAPER_DEFAULT, workload=wl)
    b = dataclasses.replace(a, device="h100",
                            scheduler=SchedulerConfig(batch_cap=4))
    ok, reason = divergence.trace_shareable([a, b])
    assert not ok
    assert "differ beyond" in reason


# ---------------------------------------------------------------------------
# cache-key stability: the digest the device mode (and cache) keys on
# ---------------------------------------------------------------------------

def _reference_digest(cfg, extra) -> str:
    payload = {"cfg": dataclasses.asdict(cfg), "extra": extra,
               "schema": SCHEMA_VERSION}
    blob = json.dumps(payload, sort_keys=True, default=str,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def test_scenario_digests_match_reference_construction():
    sc = SWEEPS["fig1"].build(True)[0]
    assert sc.key == _reference_digest(
        sc.cfg, {"pue": sc.pue, "grid_ci": sc.grid_ci, "post": sc.post,
                 "post_params": sc.post_params})
    assert sc.trace_key == _reference_digest(sc.cfg, {})
    # trace_key deliberately ignores the fan-out knobs
    other = Scenario(cfg=sc.cfg, params=sc.params, pue=sc.pue + 0.2,
                     grid_ci=sc.grid_ci + 100.0)
    assert other.trace_key == sc.trace_key
    assert other.key != sc.key


# ---------------------------------------------------------------------------
# jax roofline backend parity across every paper model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PAPER_MODELS))
def test_jax_backend_parity_all_paper_models(name):
    # measured worst-case rel err across all models/hardware is ~2e-7
    # (f32 rounding); JAX_BACKEND_RTOL = 1e-5 keeps >50x margin
    for dev, tp, pp in (("a100", 1, 1), ("h100", 2, 2)):
        em = ExecutionModel(PAPER_MODELS[name], DEVICES[dev],
                            tp=tp, pp=pp)
        batch = StageBatch.concat([
            em.aggregate([512], [128, 4096]),
            em.aggregate([], [64] * 32),
            em.aggregate([128, 1], [], [0, 1024]),
            em.aggregate([1], [1]),
        ])
        ref = em.stage_cost_batch(batch)
        jx = em.stage_cost_batch(batch, backend="jax")
        for f in ("t_total", "t_compute", "t_memory", "t_collective",
                  "flops_mlp", "flops_attn", "mfu"):
            np.testing.assert_allclose(
                np.asarray(getattr(jx, f)), np.asarray(getattr(ref, f)),
                rtol=JAX_BACKEND_RTOL, err_msg=f"{name} {dev} {f}")


# --------------------------------------------------------------------------
# multi-device sharded dispatch + persistent compilation cache
# --------------------------------------------------------------------------

_SHARDED_DISPATCH_SCRIPT = """
import json, os
import jax
assert jax.local_device_count() == 2, jax.local_device_count()
from repro.sweep import SWEEPS, SweepRunner
from repro.sweep.device import (DEVICE_MODE_RTOL, execute_device_grid,
                                records_max_rel_err)
scenarios = SWEEPS["fig4"].build(True)
recs, dstats = execute_device_grid(scenarios)
ref, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
print(json.dumps({"devices": dstats.devices,
                  "err": records_max_rel_err(recs, ref),
                  "rtol": DEVICE_MODE_RTOL}))
"""


@pytest.mark.slow
def test_sharded_dispatch_across_two_host_devices():
    """With 2 local devices the padded group axis shards (D, G/D) via
    pmap; records stay within the same DEVICE_MODE_RTOL contract as
    the single-device program. XLA device-count forcing must precede
    jax init, hence the subprocess."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env.update({"JAX_PLATFORMS": "cpu",
                "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
                "REPRO_JAX_CACHE_DIR": "off"})
    out = subprocess.run([sys.executable, "-c", _SHARDED_DISPATCH_SCRIPT],
                         env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 2
    assert res["err"] <= res["rtol"]


_PERSIST_CACHE_SCRIPT = """
import os, sys
from repro.sweep import SWEEPS
from repro.sweep.device import execute_device_grid
execute_device_grid(SWEEPS["fig4"].build(True))
root = os.environ["REPRO_JAX_CACHE_DIR"]
n = sum(len(fs) for _, _, fs in os.walk(root))
sys.exit(0 if n > 0 else 3)
"""


@pytest.mark.slow
def test_persistent_compile_cache_populates(tmp_path):
    """REPRO_JAX_CACHE_DIR points jax's persistent compilation cache
    at an on-disk directory so repeat processes skip the device
    program's XLA compile; the dispatch must write entries there."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env.update({"JAX_PLATFORMS": "cpu",
                "REPRO_JAX_CACHE_DIR": str(tmp_path / "jax_cache")})
    out = subprocess.run([sys.executable, "-c", _PERSIST_CACHE_SCRIPT],
                         env=env, capture_output=True, text=True)
    assert out.returncode == 0, (out.returncode, out.stderr)


def test_persistent_cache_env_off_disables(monkeypatch):
    """'off' (and empty) values disable persistence without touching
    jax config — the spans tests rely on a cold compile per process."""
    from repro.sweep import device as dev

    monkeypatch.setattr(dev, "_PERSIST_CONFIGURED", False)
    monkeypatch.setenv(dev.ENV_JAX_CACHE_DIR, "off")
    import jax
    before = jax.config.jax_compilation_cache_dir
    dev._maybe_persistent_cache()
    assert jax.config.jax_compilation_cache_dir == before
