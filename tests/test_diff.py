"""First-divergence explainer pins (``repro.obs.diff``).

(a) **dependency-order localization** — the first reported cell is the
    earliest broken link in the composition → roofline → power →
    energy → carbon → latency chain, not its downstream fallout;
(b) **tolerance-contract classification** — a device-mode run of a
    tier-1 grid diffs against the event loop entirely within
    ``DEVICE_MODE_RTOL`` (no cell is a ``regression``), and goldens
    gate bit-exact;
(c) **single-cell property** (hypothesis) — perturbing exactly one
    (row, column) cell of a stage table yields exactly that cell as
    the first divergence, classified by its true relative error;
(d) **CLI + artifacts** — ``python -m repro.obs diff`` exit semantics,
    the pinned report path ``results/obs/divergence/<name>.{md,json}``
    and the report JSON schema CI consumes.
"""
import json
import math

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.obs.diff import (DIVERGENCE_DIR, REPORT_SCHEMA, _rel,
                            assert_golden, classify, column_phase,
                            diff_golden, diff_records,
                            diff_stage_tables, tolerance_contracts,
                            write_report)
from repro.sweep import SWEEPS, SweepRunner

MAIN = None  # populated lazily: repro.obs.__main__.main


def _cli(argv):
    global MAIN
    if MAIN is None:
        from repro.obs.__main__ import main as MAIN  # noqa: N806
    return MAIN(argv)


# ---------------------------------------------------------------------------
# (a) dependency order + phase mapping
# ---------------------------------------------------------------------------

def test_column_phase_mapping():
    assert column_phase("n_stages") == "composition"
    assert column_phase("avg_batch") == "composition"
    assert column_phase("duration_s") == "roofline"
    assert column_phase("throughput_qps") == "roofline"
    assert column_phase("avg_power_w") == "power"
    assert column_phase("energy_wh") == "energy"
    assert column_phase("carbon_total_g") == "carbon"
    assert column_phase("grid_ci_g_per_kwh") == "carbon"
    assert column_phase("ttft_p99_s") == "latency"
    assert column_phase("zzz") == "other"


def test_first_divergence_follows_dependency_order():
    a = {"ttft_p50_s": 1.0, "carbon_total_g": 5.0, "avg_power_w": 100.0,
         "n_stages": 10}
    b = dict(a, ttft_p50_s=9.0, carbon_total_g=50.0, n_stages=11)
    r = diff_golden(a, b)
    # composition breaks before carbon breaks before latency
    assert [c.column for c in r.cells] == \
        ["n_stages", "carbon_total_g", "ttft_p50_s"]
    assert r.first.column == "n_stages"
    assert r.first.phase == "composition"


def test_earlier_phase_wins_even_when_later_cell_diverges_more():
    a = {"avg_power_w": 100.0, "carbon_total_g": 5.0}
    b = {"avg_power_w": 101.0, "carbon_total_g": 500.0}  # 1% vs 100x
    r = diff_golden(a, b)
    assert r.first.column == "avg_power_w" and r.first.phase == "power"


# ---------------------------------------------------------------------------
# (b) classification + golden semantics
# ---------------------------------------------------------------------------

def test_tolerance_ladder_is_tightest_first():
    ladder = tolerance_contracts()
    assert [name for name, _ in ladder] == \
        ["host-bitwise", "DEVICE_MODE_RTOL", "JAX_BACKEND_RTOL",
         "DAY_FLUID_RTOL", "regression"]
    rtols = [r for _, r in ladder]
    assert rtols == sorted(rtols)
    assert rtols[0] == 0.0 and math.isinf(rtols[-1])


def test_classify_against_named_contracts():
    assert classify(0.0) == "host-bitwise"
    assert classify(1e-6) == "DEVICE_MODE_RTOL"
    assert classify(5e-6) == "DEVICE_MODE_RTOL"
    assert classify(8e-6) == "JAX_BACKEND_RTOL"
    assert classify(5e-3) == "DAY_FLUID_RTOL"
    assert classify(0.5) == "regression"
    assert classify(math.inf) == "regression"


def test_rel_handles_non_numeric_and_nan():
    assert _rel(1.0, 1.0) == 0.0
    assert _rel(float("nan"), float("nan")) == 0.0
    assert _rel("a100", "a100") == 0.0
    assert math.isinf(_rel("a100", "h100"))
    assert math.isinf(_rel(1.0, float("nan")))
    assert _rel(True, False) == math.inf     # bools compare by equality
    assert _rel(100.0, 101.0) == pytest.approx(1.0 / 101.0)


def test_device_mode_diff_all_within_device_rtol():
    scenarios = SWEEPS["fig1"].build(True, n_requests=12)
    ev, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    dv, _ = SweepRunner(cache=None, mode="device").run(scenarios)
    r = diff_records(ev, dv, label_a="event_loop", label_b="device")
    assert r.n_scenarios == len(scenarios)
    assert not r.has_regression, r.summary()
    # every divergent cell is absorbed by the device-mode contract
    assert set(r.by_contract()) <= {"DEVICE_MODE_RTOL"}, r.summary()
    assert r.worst_contract in ("host-bitwise", "DEVICE_MODE_RTOL")


def test_diff_records_aligns_by_key_and_reports_unmatched():
    ra = [{"scenario": "s0", "key": "k0", "metrics": {"energy_wh": 1.0}},
          {"scenario": "s1", "key": "k1", "metrics": {"energy_wh": 2.0}}]
    rb = [{"scenario": "s1x", "key": "k1",
           "metrics": {"energy_wh": 2.0}},
          {"scenario": "s2", "key": "k2", "metrics": {"energy_wh": 3.0}}]
    r = diff_records(ra, rb)
    assert r.n_scenarios == 1 and not r.cells
    assert r.only_a == ["s0"] and r.only_b == ["s2"]
    assert r.has_regression        # unmatched scenarios are drift


def test_diff_golden_walks_only_pinned_keys():
    metrics = {"energy_wh": 1.0, "extra_metric": 42.0,
               "avg_power_w": 10.0}
    golden = {"energy_wh": 1.0, "avg_power_w": 10.0}
    assert diff_golden(metrics, golden).identical
    # a pinned key the run no longer produces is an inf divergence
    r = diff_golden({"energy_wh": 1.0}, golden)
    assert not r.identical and r.first.column == "avg_power_w"
    assert math.isinf(r.first.rel) and r.first.contract == "regression"


def test_assert_golden_raises_through_explainer(tmp_path):
    golden = {"avg_power_w": 100.0, "carbon_total_g": 5.0}
    run = {"avg_power_w": 101.0, "carbon_total_g": 5.0}
    with pytest.raises(AssertionError) as ei:
        assert_golden(run, golden, "demo_golden", outdir=tmp_path)
    msg = str(ei.value)
    assert "golden drift in demo_golden" in msg
    assert "avg_power_w" in msg
    assert str(tmp_path / "demo_golden.md") in msg
    assert (tmp_path / "demo_golden.json").exists()
    # a clean run neither writes nor raises
    res = assert_golden(dict(golden), golden, "clean", outdir=tmp_path)
    assert res.identical and not (tmp_path / "clean.md").exists()


# ---------------------------------------------------------------------------
# (c) stage tables + the single-cell property
# ---------------------------------------------------------------------------

def _table(rows=6):
    base = np.arange(1.0, rows + 1.0)
    return {"t_s": base * 0.5, "dur_s": np.full(rows, 0.25),
            "batch_size": base + 4.0, "kv_tokens": base * 128.0}


def test_stage_table_reports_first_divergent_row_per_column():
    a, b = _table(), _table()
    b["t_s"] = b["t_s"].copy()
    b["t_s"][[2, 4]] += 1.0          # two breaks: row 2 surfaces, 4 not
    r = diff_stage_tables(a, b)
    assert len(r.cells) == 1
    assert (r.first.column, r.first.stage) == ("t_s", 2)


def test_stage_table_row_count_mismatch_is_drift():
    a, b = _table(6), _table(5)
    r = diff_stage_tables(a, b)
    assert not r.cells               # shared prefix identical
    assert r.has_regression and r.only_a == ["rows[5:6]"]


def test_stage_table_nan_rows_are_equal():
    a, b = _table(), _table()
    a["dur_s"] = a["dur_s"].copy()
    b["dur_s"] = b["dur_s"].copy()
    a["dur_s"][3] = b["dur_s"][3] = float("nan")
    assert diff_stage_tables(a, b).identical


_COLS = ("t_s", "dur_s", "batch_size", "kv_tokens")


@settings(max_examples=30, deadline=None)
@given(col=st.integers(min_value=0, max_value=len(_COLS) - 1),
       row=st.integers(min_value=0, max_value=5),
       eps=st.sampled_from([1e-7, 3e-6, 8e-6, 3e-3, 0.5]))
def test_single_perturbed_cell_is_the_first_divergence(col, row, eps):
    a, b = _table(), _table()
    name = _COLS[col]
    b[name] = b[name].copy()
    b[name][row] = a[name][row] * (1.0 + eps)
    r = diff_stage_tables(a, b)
    assert len(r.cells) == 1         # exactly the perturbed cell
    cell = r.first
    assert (cell.column, cell.stage) == (name, row)
    assert cell.phase == column_phase(name)
    expected_rel = _rel(float(a[name][row]), float(b[name][row]))
    assert cell.rel == expected_rel
    assert cell.contract == classify(expected_rel)
    assert not r.only_a and not r.only_b


# ---------------------------------------------------------------------------
# (d) CLI exit semantics, pinned artifact path + report schema
# ---------------------------------------------------------------------------

def _records_payload(scale=1.0):
    return {"records": [
        {"scenario": "s0", "key": "k0", "params": {},
         "metrics": {"energy_wh": 10.0 * scale, "avg_power_w": 100.0,
                     "n_stages": 5}}], "derived": ""}


def test_cli_diff_records_exit_semantics(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_records_payload()))
    b.write_text(json.dumps(_records_payload()))
    rd = tmp_path / "reports"
    assert _cli(["diff", str(a), str(b),
                 "--report-dir", str(rd)]) == 0
    # a divergence within a named contract still exits 0 ...
    b.write_text(json.dumps(_records_payload(scale=1.0 + 1e-6)))
    assert _cli(["diff", str(a), str(b),
                 "--report-dir", str(rd)]) == 0
    # ... a regression exits 1, and the same drift under --golden too
    b.write_text(json.dumps(_records_payload(scale=2.0)))
    assert _cli(["diff", str(a), str(b),
                 "--report-dir", str(rd)]) == 1


def test_cli_diff_golden_gate_is_bit_exact(tmp_path):
    run = tmp_path / "run.json"
    golden = tmp_path / "golden.json"
    run.write_text(json.dumps(_records_payload()))
    golden.write_text(json.dumps({"energy_wh": 10.0,
                                  "avg_power_w": 100.0}))
    rd = tmp_path / "reports"
    assert _cli(["diff", str(run), str(golden), "--golden",
                 "--report-dir", str(rd)]) == 0
    # ulp-level drift is a golden failure even though DEVICE_MODE_RTOL
    # would absorb it in a records diff
    run.write_text(json.dumps(_records_payload(scale=1.0 + 1e-6)))
    assert _cli(["diff", str(run), str(golden), "--golden",
                 "--report-dir", str(rd)]) == 1


def test_cli_diff_stage_table_csv(tmp_path):
    header = "t_s,dur_s,batch_size\n"
    rows_a = "".join(f"{i * 0.5},0.25,{i + 4}\n" for i in range(4))
    a = tmp_path / "a.csv"
    b = tmp_path / "b.csv"
    a.write_text(header + rows_a)
    b.write_text(header + rows_a.replace("1.5,0.25,7", "1.5,0.25,9"))
    rd = tmp_path / "reports"
    rc = _cli(["diff", str(a), str(b), "--name", "csvdiff",
               "--report-dir", str(rd)])
    assert rc == 1                   # 7 -> 9 is far outside every rtol
    r = json.loads((rd / "csvdiff.json").read_text())
    assert r["kind"] == "stage-table"
    assert r["first"]["column"] == "batch_size"
    assert r["first"]["stage"] == 3


def test_cli_diff_mixed_kinds_rejected(tmp_path):
    a = tmp_path / "a.csv"
    a.write_text("t_s\n1.0\n")
    b = tmp_path / "b.json"
    b.write_text(json.dumps(_records_payload()))
    assert _cli(["diff", str(a), str(b)]) == 2


def test_cli_perturbed_fixture_pins_artifact_path_and_schema(
        tmp_path, monkeypatch, capsys):
    """The CI failure artifact: a perturbed run diffed with default
    settings must land at ``results/obs/divergence/<name>.{md,json}``
    with the schema the workflow's inline checks consume."""
    monkeypatch.chdir(tmp_path)
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_records_payload()))
    b.write_text(json.dumps(_records_payload(scale=1.5)))
    rc = _cli(["diff", str(a), str(b), "--name", "pinned"])
    assert rc == 1
    assert str(DIVERGENCE_DIR) == "results/obs/divergence"
    md = tmp_path / DIVERGENCE_DIR / "pinned.md"
    js = tmp_path / DIVERGENCE_DIR / "pinned.json"
    assert md.exists() and js.exists()
    out = capsys.readouterr().out
    assert str(md.relative_to(tmp_path)) in out

    r = json.loads(js.read_text())
    assert r["schema"] == REPORT_SCHEMA == 1
    assert set(r) == {"schema", "kind", "a", "b", "identical",
                      "has_regression", "worst_contract", "n_compared",
                      "n_scenarios", "by_contract", "first", "cells",
                      "only_a", "only_b"}
    assert r["kind"] == "records" and r["has_regression"] is True
    assert r["worst_contract"] == "regression"
    assert r["first"]["column"] == "energy_wh"
    assert r["first"]["contract"] == "regression"
    assert r["by_contract"] == {"regression": 1}
    md_text = md.read_text()
    assert "# Divergence report (records)" in md_text
    assert "## Tolerance ladder" in md_text


def test_write_report_returns_both_paths(tmp_path):
    r = diff_golden({"energy_wh": 1.0}, {"energy_wh": 1.0})
    paths = write_report(r, "ok", outdir=tmp_path)
    assert paths["md"].read_text().startswith("# Divergence report")
    assert json.loads(paths["json"].read_text())["identical"] is True
