"""Distribution layer: sharding plans, compression, pipeline parallelism,
HLO analysis."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.analysis.hlo import collective_bytes, program_stats
from repro.configs import ASSIGNED, get_config
from repro.distributed.compression import (compress_tree, decompress_tree,
                                           dequantize_int8, quantize_int8)
from repro.distributed.sharding import (attention_tp_mode, kv_repeat_for,
                                        param_logical_tree)


# ---------------------------- sharding rules ----------------------------

def test_tp_modes():
    assert attention_tp_mode(get_config("stablelm-1.6b"), 16) == "head"
    assert attention_tp_mode(get_config("smollm-360m"), 16) == "head_dim"
    assert attention_tp_mode(get_config("qwen2-vl-2b"), 16) == "head_dim"
    assert attention_tp_mode(get_config("mistral-nemo-12b"), 16) == "head"


def test_kv_repeat():
    assert kv_repeat_for(get_config("mistral-nemo-12b"), 16) == 2   # kv 8
    assert kv_repeat_for(get_config("qwen3-moe-30b-a3b"), 16) == 4  # kv 4
    assert kv_repeat_for(get_config("stablelm-1.6b"), 16) == 1      # kv 32
    assert kv_repeat_for(get_config("smollm-360m"), 16) == 1        # head_dim


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_logical_axes_cover_all_params(arch):
    """Every parameter leaf gets a logical-axis tuple of matching rank."""
    from repro.configs import reduced_config
    from repro.models import build_model
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    logical = param_logical_tree(shapes)
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_l = jax.tree_util.tree_leaves(
        logical, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_s) == len(flat_l)
    for s, l in zip(flat_s, flat_l):
        assert len(l) == s.ndim, f"{arch}: {s.shape} vs {l}"


# ---------------------------- compression ----------------------------

@given(st.integers(0, 1000), st.integers(10, 2000))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, n).astype(np.float32))
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape, x.dtype)
    blockmax = float(jnp.abs(x).max())
    assert float(jnp.abs(x - y).max()) <= blockmax / 127.0 + 1e-6


def test_error_feedback_removes_bias():
    """With residual carrying, the mean compressed gradient converges to
    the true mean (compression bias vanishes)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1, 512).astype(np.float32))
    resid = None
    acc = jnp.zeros_like(g_true)
    n = 40
    for _ in range(n):
        (q, s), resid = jax.tree.map(
            lambda x: x, compress_tree(g_true, resid))
        acc = acc + dequantize_int8(q, s, g_true.shape, jnp.float32)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true),
                               atol=2e-3)


# ---------------------------- pipeline parallelism ----------------------

PIPELINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import make_pp_mesh, pipeline_forward

    S, M, D = 4, 8, 16
    mesh = make_pp_mesh(S, 1)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.5, (S, D, D)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (M, 2, D)).astype(np.float32))

    def stage_fn(params, x):
        return jnp.tanh(x @ params)

    fn = pipeline_forward(stage_fn, S, M, mesh)
    with mesh:
        y = fn(w, x)
    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential():
    import os
    r = subprocess.run(
        [sys.executable, "-c", PIPELINE_SCRIPT],
        capture_output=True, text=True, timeout=300,
        # pin the platform: an unset JAX_PLATFORMS makes jax probe for
        # TPU/GPU runtimes in the stripped env and hang on some images
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert "PIPELINE_OK" in r.stdout, r.stderr[-2000:]


# ---------------------------- HLO analysis ----------------------------

SYNTH_HLO = """
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %ar = f32[128,128]{1,0} all-reduce(%gte1), replica_groups={}, to_apply=%add
  %d = f32[128,128]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%i, %d)
}

%cond.1 (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
  %c = s32[] constant(10)
}

ENTRY %main.1 () -> f32[] {
  %init = (s32[], f32[128,128]{1,0}) tuple(%z, %w)
  %wh = (s32[], f32[128,128]{1,0}) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[] constant(0)
}
"""


def test_hlo_loop_aware_accounting():
    coll = collective_bytes(SYNTH_HLO)
    # one 64KB all-reduce x 10 loop iterations
    assert coll["all-reduce"] == pytest.approx(128 * 128 * 4 * 10)
    stats = program_stats(SYNTH_HLO)
    # dot: 2 * 128^3 flops x 10 iterations
    assert stats["dot_flops"] == pytest.approx(2 * 128 ** 3 * 10)
