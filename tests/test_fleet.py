"""Fleet subsystem: routing policies, request conservation, energy
roll-up identities, carbon-greedy-vs-round-robin ordering, and the
sweep-engine integration (fleet scenarios + post.* carbon axes)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.core.energy import operational_energy
from repro.core.power import PowerModel
from repro.fleet import (FleetConfig, SiteConfig, make_router,
                         run_fleet_simulation)
from repro.fleet.routing import RoundRobinRouter
from repro.sim import (SchedulerConfig, SimConfig, WorkloadConfig,
                       energy_report, run_simulation)
from repro.sim.simulator import kv_budget_tokens
from repro.core.power import DEVICES


def small_workload(n=48, qps=5.0, seed=0):
    return WorkloadConfig(n_requests=n, qps=qps, min_len=64, max_len=512,
                          seed=seed)


def two_region_fleet(router="round_robin", n=48, devices=("a100", "a100"),
                     traces=("hydro", "coal"), **fleet_kw):
    sites = tuple(SiteConfig(name=f"s{i}-{t}", device=d, ci_trace=t,
                             scheduler=SchedulerConfig(batch_cap=16))
                  for i, (d, t) in enumerate(zip(devices, traces)))
    return FleetConfig(model=LLAMA3_8B, sites=sites,
                       workload=small_workload(n), router=router,
                       **fleet_kw)


# ---------------------------------------------------------------------------
# routers (unit)
# ---------------------------------------------------------------------------

class _View:
    """Minimal site-view stub implementing the router protocol."""

    def __init__(self, tokens=0, ci=100.0):
        self.tokens = tokens
        self.ci = ci

    def outstanding_tokens(self):
        return self.tokens

    def outstanding_requests(self):
        return self.tokens // 100

    def ci_at(self, t):
        return self.ci


def test_round_robin_router_cycles():
    r = make_router("round_robin", 3)
    views = [_View() for _ in range(3)]
    assert [r.choose(None, 0.0, views) for _ in range(6)] == \
        [0, 1, 2, 0, 1, 2]


def test_least_loaded_router_joins_shortest_queue():
    r = make_router("least_loaded", 3)
    views = [_View(tokens=500), _View(tokens=20), _View(tokens=300)]
    assert r.choose(None, 0.0, views) == 1
    views[1].tokens = 900
    assert r.choose(None, 0.0, views) == 2


def test_carbon_greedy_migration_penalty_semantics():
    r = make_router("carbon_greedy", 2, migration_penalty_g=5.0,
                    request_kwh_est=2e-4, expected_dwell_requests=256.0)
    views = [_View(ci=500.0), _View(ci=100.0)]
    assert r.choose(None, 0.0, views) == 1      # initial pick: min CI
    # small gap does not amortize the penalty: stay at the current site
    views[0].ci = 90.0
    assert r.choose(None, 1.0, views) == 1
    assert r.stats()["switches"] == 0
    # large gap does: migrate
    views[0].ci = 10.0
    views[1].ci = 600.0
    assert r.choose(None, 2.0, views) == 0
    assert r.stats()["switches"] == 1


def test_carbon_greedy_load_cap_overflows():
    r = make_router("carbon_greedy", 2, load_cap_tokens=100)
    views = [_View(ci=100.0, tokens=500), _View(ci=700.0, tokens=0)]
    assert r.choose(None, 0.0, views) == 1      # preferred site saturated
    assert r.stats()["overflows"] == 1
    views[0].tokens = 0
    assert r.choose(None, 1.0, views) == 0      # room again: back to cur


def test_unknown_router_raises():
    with pytest.raises(KeyError):
        make_router("definitely-not-a-router", 2)


# ---------------------------------------------------------------------------
# fleet simulation invariants
# ---------------------------------------------------------------------------

def test_request_conservation_across_sites():
    """Every generated request is routed to exactly one site and
    completes there (routed == completed == generated)."""
    res = run_fleet_simulation(two_region_fleet("least_loaded"))
    n = res.cfg.workload.n_requests
    assert np.all(res.assignments >= 0)
    assert sum(len(s.requests) for s in res.sites) == n
    rids = sorted(r.rid for s in res.sites for r in s.requests)
    assert rids == list(range(n))               # no duplication, no loss
    assert all(r.t_done >= 0 for r in res.requests)
    for s in res.sites:
        done_decode = int(np.sum(s.stages.n_decode_tokens))
        assert done_decode == sum(r.decode_tokens for r in s.requests)


def test_fleet_energy_is_sum_of_site_eq23_energies():
    """Fleet-total energy == sum over sites of Eq. 2-3 operational
    energy recomputed from each site's own stage log."""
    cfg = two_region_fleet("round_robin", devices=("a100", "h100"))
    res = run_fleet_simulation(cfg)
    per_site = []
    for s in res.sites:
        rep = operational_energy(s.stages.mfu, s.stages.dur_s,
                                 PowerModel(s.site.device),
                                 n_devices=s.site.n_devices, pue=cfg.pue)
        assert rep.energy_wh == pytest.approx(s.energy.energy_wh)
        per_site.append(rep.energy_wh)
    assert res.summary()["energy_wh"] == pytest.approx(sum(per_site))


def test_carbon_greedy_beats_round_robin_on_divergent_ci():
    """Acceptance pin: on a two-region trace with divergent CI
    (hydro ~70 vs coal ~720 gCO2/kWh) the carbon-greedy geo-router
    reduces fleet operational emissions vs round-robin."""
    rr = run_fleet_simulation(two_region_fleet("round_robin")).summary()
    cg = run_fleet_simulation(two_region_fleet("carbon_greedy")).summary()
    assert cg["carbon_operational_g"] < rr["carbon_operational_g"]
    # both fleets serve the full workload
    assert cg["n_requests_done"] == rr["n_requests_done"] == 48


def test_single_site_fleet_matches_single_site_simulator():
    """One site + round-robin == the classic run_simulation (the
    single-site path is the trivial fleet)."""
    wl = small_workload()
    sched = SchedulerConfig(batch_cap=16)
    fleet = FleetConfig(model=LLAMA3_8B,
                        sites=(SiteConfig(name="only", scheduler=sched),),
                        workload=wl)
    fres = run_fleet_simulation(fleet)
    sres = run_simulation(SimConfig(model=LLAMA3_8B, workload=wl,
                                    scheduler=sched))
    log_f, log_s = fres.sites[0].stages, sres.stages
    np.testing.assert_array_equal(log_f.start_s, log_s.start_s)
    np.testing.assert_array_equal(log_f.dur_s, log_s.dur_s)
    np.testing.assert_array_equal(log_f.mfu, log_s.mfu)
    np.testing.assert_array_equal(log_f.batch_size, log_s.batch_size)
    assert fres.sites[0].energy.energy_wh == pytest.approx(
        energy_report(sres, pue=fleet.pue).energy_wh)


def test_run_simulation_accepts_injected_router():
    """Satellite: run_simulation(router=...) with a caller-built
    round-robin replica router reproduces the default path exactly."""
    wl = small_workload()
    cfg = SimConfig(model=LLAMA3_8B, workload=wl,
                    scheduler=SchedulerConfig(batch_cap=16), n_replicas=2)
    budget = kv_budget_tokens(LLAMA3_8B, DEVICES[cfg.device], 1, 1)
    sched = dataclasses.replace(cfg.scheduler, kv_budget_tokens=budget)
    default = run_simulation(cfg)
    injected = run_simulation(cfg, router=RoundRobinRouter(2, sched))
    np.testing.assert_array_equal(default.stages.start_s,
                                  injected.stages.start_s)
    np.testing.assert_array_equal(default.stages.dur_s,
                                  injected.stages.dur_s)


def test_sticky_routing_keeps_continuous_batching():
    """Regression: a sticky geo-router concentrating all load on one
    site must not serialize that site to batch-size-1 execution (the
    admission gate must ignore idle sites' stale clocks)."""
    cg = run_fleet_simulation(two_region_fleet("carbon_greedy", n=64))
    rr = run_fleet_simulation(two_region_fleet("round_robin", n=64))
    busy = max(cg.sites, key=lambda s: len(s.requests))
    assert len(busy.requests) == 64          # all load on the clean site
    assert float(np.mean(busy.stages.batch_size)) > 1.2
    s_cg, s_rr = cg.summary(), rr.summary()
    # concentrating load must not blow up latency vs round-robin by
    # orders of magnitude (it did when admission was serialized)
    assert s_cg["ttft_p50_s"] < 10 * max(s_rr["ttft_p50_s"], 1e-3)
    assert s_cg["duration_s"] < 2 * s_rr["duration_s"]


def test_blocked_site_does_not_stall_fleet():
    """Regression: a site whose replica can never admit its queued
    request (KV budget too small) must not terminate the whole fleet
    loop — the other site's work still completes."""
    tiny = SchedulerConfig(batch_cap=16, kv_budget_tokens=8)
    roomy = SchedulerConfig(batch_cap=16)
    cfg = FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="blocked", scheduler=tiny),
               SiteConfig(name="ok", scheduler=roomy)),
        workload=small_workload(n=16),       # min_len 64 > 8-token budget
        router="round_robin",
        auto_kv_budget=False)
    res = run_fleet_simulation(cfg)
    ok = next(s for s in res.sites if s.site.name == "ok")
    blocked = next(s for s in res.sites if s.site.name == "blocked")
    assert len(ok.requests) == 8
    assert all(r.t_done >= 0 for r in ok.requests)     # fully served
    assert all(r.t_done < 0 for r in blocked.requests)  # parked, not lost
    assert len(blocked.requests) == 8


def test_solar_site_offsets_emissions():
    """A site with solar+battery ends up with net emissions below its
    no-solar counterfactual (offset > 0, paper Table 2 direction)."""
    cfg = two_region_fleet("round_robin")
    solar_site = dataclasses.replace(
        cfg.sites[0], solar_capacity_w=600.0, battery_capacity_wh=100.0)
    cfg = dataclasses.replace(cfg, sites=(solar_site, cfg.sites[1]))
    res = run_fleet_simulation(cfg)
    s0 = res.sites[0].cosim
    assert s0["net_emissions_kg"] <= s0["total_emissions_nosolar_kg"]
    summary = res.summary()
    assert summary["carbon_offset_pct"] >= 0.0


# ---------------------------------------------------------------------------
# sweep-engine integration
# ---------------------------------------------------------------------------

def test_fleet_scenario_executes_and_caches(tmp_path):
    from repro.sweep import ResultCache, Scenario, SweepRunner
    cfg = two_region_fleet("carbon_greedy", n=24)
    sc = Scenario(cfg=cfg, params={"router": "carbon_greedy"},
                  tag="fleet/test", pue=cfg.pue)
    cache = ResultCache(tmp_path / "cache")
    r1, s1 = SweepRunner(cache=cache).run([sc])
    assert s1.executed == 1
    m = r1[0]["metrics"]
    # fleet-total and per-site energy/carbon columns
    for col in ("energy_wh", "carbon_operational_g", "carbon_total_g",
                "carbon_offset_pct", "ttft_p50_s",
                "s0-hydro_energy_wh", "s0-hydro_carbon_g",
                "s1-coal_energy_wh", "s1-coal_carbon_g"):
        assert col in m, col
    r2, s2 = SweepRunner(cache=cache).run([sc])
    assert s2.executed == 0 and s2.cache_hits == 1
    assert r2[0]["metrics"] == pytest.approx(m)


def test_fleet_smoke_sweep_has_required_axes():
    """Acceptance: the fleet smoke sweep covers >= 2 sites x >= 2
    router policies x >= 2 CI trace pairs."""
    from repro.sweep import SWEEPS
    scenarios = SWEEPS["fleet"].build(True)
    assert all(len(s.cfg.sites) >= 2 for s in scenarios)
    assert len({s.params["router"] for s in scenarios}) >= 2
    assert len({s.params["ci"] for s in scenarios}) >= 2


def test_post_axes_parameterize_postprocessor():
    """GridSpec axes under "post." land in post_params (carbon-aware
    co-sim axes) and key the cache, leaving the SimConfig untouched."""
    from repro.sweep import GridSpec
    from repro.sim import PAPER_DEFAULT
    spec = GridSpec(base=PAPER_DEFAULT, post="microgrid_cosim",
                    axes={"post.solar_capacity_w": [0.0, 600.0],
                          "post.ci_trace": ["hydro", "coal"]})
    scenarios = spec.expand()
    assert len(scenarios) == 4
    assert {s.post_params["solar_capacity_w"] for s in scenarios} == \
        {0.0, 600.0}
    assert all(s.cfg == PAPER_DEFAULT for s in scenarios)
    assert len({s.key for s in scenarios}) == 4
    assert scenarios[0].params == {"solar_capacity_w": 0.0,
                                   "ci_trace": "hydro"}


def test_ci_trace_registry():
    from repro.core.datasets import CI_TRACES, ci_trace_signal
    hydro = ci_trace_signal("hydro", 2.0)
    coal = ci_trace_signal("coal", 2.0)
    assert float(coal.values.mean()) > 3 * float(hydro.values.mean())
    with pytest.raises(KeyError):
        ci_trace_signal("atlantis", 2.0)
    assert set(CI_TRACES) >= {"caiso", "coal", "hydro"}
    # a region east of CAISO sees its evening ramp EARLIER in absolute
    # sim time (timezone ahead)
    west = ci_trace_signal("caiso", 24.0)
    east = ci_trace_signal("caiso-east", 24.0)
    peak = lambda s: float(s.times[np.argmax(s.values)])
    assert peak(east) < peak(west)
