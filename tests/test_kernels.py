"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_reference, flash_attention
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_reference)
from repro.kernels.gla_scan import gla_scan, gla_scan_reference


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),
    (2, 256, 4, 2, 64),
    (1, 200, 8, 1, 32),     # unpadded seq, MQA
    (2, 64, 6, 3, 80),      # odd heads / head_dim (smollm/danube families)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, S, H, KV, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = tr(attention_reference(tr(q), tr(k), tr(v), causal=causal,
                                 window=window))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,W,H,KV,D", [
    (2, 512, 8, 2, 64),
    (1, 1024, 4, 4, 128),
    (3, 300, 6, 3, 80),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, W, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, W, KV, D), dtype)
    vc = jax.random.normal(ks[2], (B, W, KV, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, W + 1)
    out = decode_attention(q, kc, vc, lengths)
    ref = decode_attention_reference(
        q.reshape(B, KV, H // KV, D), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), lengths).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_ring_window():
    """SWA ring cache: all slots valid once lengths >= window."""
    B, W, H, KV, D = 2, 256, 4, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, W, KV, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, W, KV, D), jnp.float32)
    lengths = jnp.array([W + 57, 100])  # one wrapped, one not
    out = decode_attention(q, kc, vc, lengths, window=W)
    ref = decode_attention_reference(
        q.reshape(B, KV, 1, D), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), lengths, window=W).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# gla_scan (RWKV6 + Mamba2/SSD modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,K,V", [
    (1, 64, 2, 32, 32),
    (2, 130, 2, 64, 64),    # unpadded T
    (1, 256, 4, 16, 64),    # K != V (mamba: K=d_state, V=head_dim)
])
@pytest.mark.parametrize("mode", ["ssd", "rwkv"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_scan_sweep(B, T, H, K, V, mode, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, T, H, K), dtype)
    k = jax.random.normal(ks[1], (B, T, H, K), dtype)
    v = jax.random.normal(ks[2], (B, T, H, V), dtype)
    # realistic decay range incl. strong decay (stability regression test)
    log_w = -jnp.exp(jax.random.uniform(ks[3], (B, T, H, K),
                                        minval=-6.0, maxval=2.5))
    u = 0.3 * jax.random.normal(ks[4], (H, K), dtype) if mode == "rwkv" else None
    o, s = gla_scan(q, k, v, log_w.astype(dtype), u=u, mode=mode, chunk=32)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    o_ref, s_ref = gla_scan_reference(tr(q), tr(k), tr(v),
                                      tr(log_w.astype(dtype)), u=u, mode=mode)
    o_ref = tr(o_ref)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(s_ref, np.float32), **tol)


def test_gla_chunked_xla_matches_reference():
    """The model-layer chunked XLA path must match the exact scan too."""
    from repro.models.linear_attention import gla_chunked, gla_reference
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B, T, H, K, V = 2, 100, 2, 32, 48
    q = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, V))
    log_w = -jnp.exp(jax.random.uniform(ks[3], (B, T, H, K), minval=-6.0,
                                        maxval=3.0))
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    for mode, uu in (("ssd", None), ("rwkv", u)):
        o_c, s_c = gla_chunked(q, k, v, log_w, u=uu, mode=mode, chunk=16)
        o_r, s_r = gla_reference(q, k, v, log_w, u=uu, mode=mode)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                                   rtol=1e-4, atol=1e-4)
