"""Launcher smoke tests (SPMD on forced host devices) + cosim pipeline
integration + extra property tests.

``REPRO_LAUNCH_TIMEOUT_S`` tunes the per-subprocess wall budget (default
420 s): slow CPU containers can raise it instead of eating spurious
``subprocess.TimeoutExpired`` failures from XLA compile time.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

LAUNCH_TIMEOUT_S = float(os.environ.get("REPRO_LAUNCH_TIMEOUT_S", "420"))

from repro.core import PowerModel, run_cosim, stages_to_load_signal
from repro.core.datasets import carbon_intensity_signal, solar_signal
from repro.core.signals import Signal
from repro.sim import energy_report, run_simulation
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig
from repro.sim.simulator import SimConfig
from repro.configs.paper_models import LLAMA3_8B


# the stripped subprocess env must pin the jax platform: without it,
# jax probes for TPU/GPU runtimes on images that ship them and blocks
# for minutes — the real cause of historical launcher-test "timeouts"
JAX_PLATFORMS = os.environ.get("JAX_PLATFORMS", "cpu")


def _run(cmd, timeout=None, devices=4):
    # keep the forced host-device count as small as each test allows:
    # SPMD partitioning cost scales with it, and slow CPU containers
    # pay that in XLA compile time
    return subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout or LAUNCH_TIMEOUT_S,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": JAX_PLATFORMS,
                               "XLA_FLAGS":
                               "--xla_force_host_platform_device_count="
                               f"{devices}"})


# full interpreter + XLA-compile round trips per launcher: the heavy
# tail of tier-1, so they run in the dedicated slow pass
@pytest.mark.slow
def test_train_launcher_spmd(tmp_path):
    r = _run([sys.executable, "-m", "repro.launch.train",
              "--arch", "stablelm-1.6b", "--reduced", "--steps", "2",
              "--mesh", "2x2", "--ckpt-dir", str(tmp_path / "ck")])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: step 2" in r.stdout


@pytest.mark.slow
def test_serve_launcher():
    r = _run([sys.executable, "-m", "repro.launch.serve",
              "--arch", "zamba2-1.2b", "--requests", "2",
              "--new-tokens", "3"], devices=1)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "gCO2" in r.stdout


def test_dryrun_cell_subprocess():
    """The dry-run entrypoint itself (512 forced devices, real mesh)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-1.6b", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=LAUNCH_TIMEOUT_S,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": JAX_PLATFORMS})
    assert r.returncode == 0, r.stderr[-2000:]
    assert '"compile_s"' in r.stdout


# ---------------------------------------------------------------------------
# sim -> energy -> cosim pipeline integration
# ---------------------------------------------------------------------------

def test_full_pipeline_energy_consistency():
    """Co-sim total demand == Eq.3 energy (same trace, fine bins).

    Note on Eq. 5 semantics: duration-weighted binning yields a POWER
    profile; for coarse bins that are only partially occupied this
    overestimates energy (the paper's traces occupy every 1-min bin, so
    it is exact there). At 1 s resolution the discrepancy vanishes."""
    cfg = SimConfig(model=LLAMA3_8B,
                    workload=WorkloadConfig(n_requests=64, qps=4.0),
                    scheduler=SchedulerConfig(batch_cap=16))
    res = run_simulation(cfg)
    rep = energy_report(res, pue=1.0)
    pm = PowerModel("a100")
    load = stages_to_load_signal(res.stages.start_s, res.stages.dur_s,
                                 res.stages.mfu, pm, n_devices=1, pue=1.0,
                                 resolution_s=1.0)
    # pure-grid cosim (no solar) so demand == load integral
    T_h = len(load.values) / 3600.0
    solar = solar_signal(max(T_h, 0.02), capacity_w=0.0)
    ci = carbon_intensity_signal(max(T_h, 0.02))
    import dataclasses as _dc
    from repro.core.microgrid import MicrogridConfig
    out = run_cosim(load, solar, ci, _dc.replace(MicrogridConfig(),
                                                 step_s=1.0))
    assert out.metrics["total_energy_kwh"] * 1000 == pytest.approx(
        rep.energy_wh, rel=0.10)


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_energy_report_identity(seed):
    """Eq. 3: energy == sum_i P(mfu_i) * dt_i / 3600 (vectorized check)."""
    rng = np.random.default_rng(seed)
    n = rng.integers(1, 50)
    mfu = rng.uniform(0, 1, n)
    dt = rng.uniform(0.001, 10.0, n)
    pm = PowerModel("h100")
    from repro.core.energy import operational_energy
    rep = operational_energy(mfu, dt, pm, n_devices=3, pue=1.5)
    expected = float(np.sum(np.asarray(pm.power(mfu)) * dt) / 3600 * 3 * 1.5)
    assert rep.energy_wh == pytest.approx(expected, rel=1e-6)


def test_signal_resample_previous():
    s = Signal(np.array([0.0, 60.0, 120.0]), np.array([1.0, 2.0, 3.0]))
    r = s.resample(30.0)
    np.testing.assert_allclose(r.values, [1, 1, 2, 2, 3])
