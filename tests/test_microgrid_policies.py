"""Microgrid co-simulation + carbon-aware policy tests (incl. hypothesis
energy-conservation properties)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.microgrid import BatteryConfig, MicrogridConfig, simulate, summarize
from repro.core.policies import multi_region, solar_following, threshold_deferral
from repro.core.datasets import carbon_intensity_signal, solar_signal


def _cfg(cap=100.0):
    return MicrogridConfig(battery=BatteryConfig(capacity_wh=cap))


@given(st.integers(0, 2 ** 31 - 1), st.floats(50, 2000), st.floats(0, 1500))
@settings(max_examples=30, deadline=None)
def test_power_balance_every_step(seed, load_scale, solar_scale):
    """Conservation: load + charge + export == solar + discharge + import."""
    rng = np.random.default_rng(seed)
    T = 100
    load = jnp.asarray(rng.uniform(0, load_scale, T))
    solar = jnp.asarray(rng.uniform(0, solar_scale, T))
    ci = jnp.asarray(rng.uniform(50, 800, T))
    cfg = _cfg()
    tr = simulate(load, solar, ci, cfg)
    lhs = np.asarray(load) + np.asarray(tr["charge_w"]) + \
        np.asarray(tr["grid_export_w"])
    rhs = np.asarray(solar) + np.asarray(tr["discharge_w"]) + \
        np.asarray(tr["grid_import_w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-3)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_soc_within_bounds(seed):
    rng = np.random.default_rng(seed)
    T = 200
    load = jnp.asarray(rng.uniform(0, 500, T))
    solar = jnp.asarray(rng.uniform(0, 800, T))
    ci = jnp.ones(T) * 300.0
    cfg = _cfg()
    tr = simulate(load, solar, ci, cfg)
    soc = np.asarray(tr["soc"])
    b = cfg.battery
    assert np.all(soc >= b.soc_min - 1e-5)
    assert np.all(soc <= b.soc_max + 1e-5)


def test_battery_absorbs_midday_surplus():
    load = jnp.ones(24 * 60) * 50.0
    solar = jnp.asarray(solar_signal(24, capacity_w=400, seed=0,
                                     cloudiness=0.0).values)
    ci = jnp.ones(24 * 60) * 300.0
    tr = simulate(load, solar, ci, _cfg())
    m = summarize(load, solar, ci,
                  {k: np.asarray(v) for k, v in tr.items()}, _cfg())
    assert m["battery_full_cycles"] > 0.3
    assert m["renewable_share_pct"] > 30.0


def test_no_solar_means_full_grid():
    # battery pinned at SoC-min so it cannot serve the load
    cfg = MicrogridConfig(battery=BatteryConfig(capacity_wh=100.0,
                                                soc_init=0.2))
    T = 60
    load = jnp.ones(T) * 100.0
    tr = simulate(load, jnp.zeros(T), jnp.ones(T) * 200.0, cfg)
    m = summarize(load, jnp.zeros(T), jnp.ones(T) * 200.0,
                  {k: np.asarray(v) for k, v in tr.items()}, cfg)
    assert m["grid_dependency_pct"] > 99.0
    # 100 W for 1 h at 200 g/kWh => 20 g
    assert m["net_emissions_kg"] * 1000 == pytest.approx(20.0, rel=0.05)


# ---------------------------- policies ----------------------------

def test_threshold_deferral_conserves_energy():
    rng = np.random.default_rng(0)
    T = 500
    load = rng.uniform(100, 400, T)
    ci = np.concatenate([np.full(T // 2, 300.0), np.full(T - T // 2, 50.0)])
    new, stats = threshold_deferral(load, ci, ci_high=200, ci_low=100,
                                    deferrable_frac=0.5)
    # served + unserved backlog == original demand
    dt_h = 60 / 3600
    total_in = load.sum() * dt_h
    total_out = new.sum() * dt_h + stats["unserved_backlog_wh"]
    assert total_out == pytest.approx(total_in, rel=1e-6)
    assert stats["deferred_steps"] > 0
    assert stats["catchup_steps"] > 0


def test_threshold_deferral_cuts_emissions():
    T = 1440
    ci = np.asarray(carbon_intensity_signal(24, seed=1).values)
    load = np.full(T, 300.0)
    new, _ = threshold_deferral(load, ci, ci_high=float(np.percentile(ci, 70)),
                                ci_low=float(np.percentile(ci, 30)))
    base = float(np.sum(load * ci))
    opt = float(np.sum(new * ci))
    assert opt < base  # shifting toward low-CI steps must help


def test_solar_following_conserves_total():
    rng = np.random.default_rng(2)
    load = rng.uniform(50, 300, 1440)
    solar = np.asarray(solar_signal(24, capacity_w=600, seed=2).values)
    new = solar_following(load, solar, min_frac=0.4)
    assert new.sum() == pytest.approx(load.sum(), rel=1e-6)
    # load should correlate with solar afterwards
    c_new = np.corrcoef(new, solar)[0, 1]
    c_old = np.corrcoef(load, solar)[0, 1]
    assert c_new > c_old


def test_multi_region_routing_lowers_ci():
    T = 1440
    ci0 = np.asarray(carbon_intensity_signal(24, seed=3).values)
    ci1 = np.asarray(carbon_intensity_signal(24, seed=4,
                                             day_offset_h=12).values)
    load = np.full(T, 200.0)
    assign, stats = multi_region(load, np.stack([ci0, ci1]))
    assert stats["avg_ci_routed"] <= stats["avg_ci_region0"] + 1e-9
    assert 0 < stats["switches"] < 200


def test_multi_region_migration_penalty_amortization():
    """The switch condition gap * load/1000 * dwell_h > penalty must
    gate exactly: just-too-small CI gaps never migrate, amortizing
    gaps always do, and an infinite penalty pins the initial region."""
    T = 120
    # region 0 starts cheapest, region 1 becomes cheaper by `gap` at t=60
    gap = 50.0
    ci0 = np.full(T, 300.0)
    ci1 = np.concatenate([np.full(60, 400.0), np.full(60, 300.0 - gap)])
    regions = np.stack([ci0, ci1])
    load = np.full(T, 200.0)
    # amortized benefit per switch: gap * 0.2 kW * dwell_h
    dwell_steps = 60
    dwell_h = dwell_steps * 60.0 / 3600.0
    benefit = gap * 200.0 / 1000.0 * dwell_h
    _, stats_hi = multi_region(load, regions,
                               migration_penalty_g=benefit * 1.01,
                               expected_dwell_steps=dwell_steps)
    assert stats_hi["switches"] == 0          # penalty not amortized
    assign, stats_lo = multi_region(load, regions,
                                    migration_penalty_g=benefit * 0.99,
                                    expected_dwell_steps=dwell_steps)
    assert stats_lo["switches"] == 1          # penalty amortized
    assert np.all(assign[:60] == 0) and np.all(assign[60:] == 1)
    _, stats_inf = multi_region(load, regions,
                                migration_penalty_g=np.inf)
    assert stats_inf["switches"] == 0


def test_multi_region_zero_penalty_always_tracks_argmin():
    rng = np.random.default_rng(5)
    regions = rng.uniform(50, 800, size=(3, 200))
    load = np.full(200, 100.0)
    assign, _ = multi_region(load, regions, migration_penalty_g=0.0)
    np.testing.assert_array_equal(assign, np.argmin(regions, axis=0))


def test_solar_following_min_frac_floor_and_degenerate_solar():
    """The QoS floor: capacity never scales below min_frac of full,
    and with no solar at all the renormalized load is unchanged."""
    rng = np.random.default_rng(7)
    load = rng.uniform(50, 300, 500)
    solar = np.asarray(solar_signal(500 / 60, capacity_w=600,
                                    seed=7).values)[:500]
    out = solar_following(load, solar, min_frac=0.4)
    # pre-renormalization floor: out >= 0.4 * load * (total_in/total_out)
    scale = load.sum() / (load * np.clip(
        solar / solar.max(), 0.4, 1.0)).sum()
    assert np.all(out >= 0.4 * load * scale - 1e-9)
    # zero solar everywhere: cap is min_frac flat -> renormalization
    # restores the input exactly
    np.testing.assert_allclose(
        solar_following(load, np.zeros_like(load), min_frac=0.4), load)


def test_threshold_deferral_backlog_bound_and_conservation():
    """served + unserved backlog == input even when the bounded backlog
    saturates, and the backlog never exceeds its bound by more than a
    single step's deferral."""
    T = 600
    step_s = 60.0
    dt_h = step_s / 3600.0
    load = np.full(T, 400.0)
    ci = np.full(T, 500.0)          # always high: defer-only regime
    cap_wh = 50.0
    new, stats = threshold_deferral(load, ci, ci_high=300.0, ci_low=100.0,
                                    deferrable_frac=0.5,
                                    max_backlog_wh=cap_wh, step_s=step_s)
    max_step_wh = 400.0 * 0.5 * dt_h
    assert stats["peak_backlog_wh"] <= cap_wh + max_step_wh
    total_in = load.sum() * dt_h
    total_out = new.sum() * dt_h + stats["unserved_backlog_wh"]
    assert total_out == pytest.approx(total_in, rel=1e-9)
    # once the backlog cap binds, the remaining steps pass through
    assert np.any(new == load)
