"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced_config
from repro.models import build_model

ARCHS = sorted(ASSIGNED)


def make_batch(cfg, rng, B=2, S=16):
    ks = jax.random.split(rng, 4)
    batch = {}
    if cfg.embed_stub:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    if cfg.attention is not None and cfg.attention.rope == "mrope":
        p = jnp.arange(S)[None, :, None]
        batch["positions3"] = jnp.broadcast_to(p, (B, S, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    # one grad step exercises the backward pass
    g, _ = jax.grad(model.loss_fn, has_aux=True)(params, batch)
    flat = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in flat), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_smoke(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=64))(
        params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder_only])
def test_decode_smoke(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    _, cache = model.prefill(params, batch, max_len=64)
    dec = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.embed_stub:
        dec = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
               "tokens": jnp.zeros((B, 1), jnp.int32)}
        if "embeds" in dec and not get_config(arch).is_encoder_only:
            # VLM decode continues with text tokens -> use token path
            dec = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2 = jax.jit(model.decode_step)(params, dec, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    assert int(cache2["lengths"][0]) == S + 1


def test_decode_matches_prefill_dense():
    """Decode must be mathematically consistent with prefill: running a
    sequence via prefill(S) then decoding token S must equal prefill(S+1)."""
    cfg = reduced_config(get_config("stablelm-1.6b")).replace(dtype="float32")
    model = build_model(cfg, attn_impl="einsum")
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    B, S = 1, 8
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    logits_full, _ = model.prefill(params, {"tokens": toks}, max_len=32)
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, max_len=32)
    logits_dec, _ = model.decode_step(params, {"tokens": toks[:, S:]}, cache)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_prefill_rwkv():
    cfg = reduced_config(get_config("rwkv6-1.6b")).replace(dtype="float32")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(4)
    params = model.init(rng)
    B, S = 1, 8
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    logits_full, _ = model.prefill(params, {"tokens": toks}, max_len=32)
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, max_len=32)
    logits_dec, _ = model.decode_step(params, {"tokens": toks[:, S:]}, cache)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-2, atol=2e-2)
