"""Observability pins: probe neutrality, trace schema, dual clocks.

The ``repro.obs`` contract this file pins:

(a) **probe neutrality** — attaching a ``FlightRecorder`` to the sweep
    runner or the day driver produces records/summaries bit-identical
    to probe-off runs (fig1 single-site, fleet/shift multi-site, and a
    day-smoke hybrid window);
(b) **Chrome trace schema** — the export is valid JSON, metadata
    events lead, timestamps are monotonic, and wall-clock ``B``/``E``
    duration events pair and nest;
(c) the wall-clock ``SpanProfiler`` (nesting, aggregation, cross-
    process merge, disabled no-op) and the stderr logger;
(d) cache-effectiveness counters in the sweep summary line.
"""
import json
import logging

import numpy as np
import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.fleet.config import FleetConfig, SiteConfig
from repro.fleet.day import run_fleet_day
from repro.obs.chrometrace import (ADMISSION_PID, WALL_PID,
                                   chrome_trace_events,
                                   write_chrome_trace, write_csvs)
from repro.obs.log import configure, get_logger
from repro.obs.probe import NULL_PROBE, Probe, SiteIndexProbe
from repro.obs.recorder import (STAGE_FIELDS, ColumnBuilder,
                                FlightRecorder)
from repro.obs.spans import PROFILER, SpanProfiler
from repro.sim.hybrid import DayConfig
from repro.sim.requests import WorkloadConfig
from repro.sim.scheduler import SchedulerConfig
from repro.sweep import SWEEPS, ResultCache, SweepRunner
from repro.sweep.runner import execute_scenario


@pytest.fixture(autouse=True)
def _profiler_clean():
    """The module-level PROFILER is process-wide state: leave it
    disabled and empty regardless of what a test does."""
    yield
    PROFILER.disable()
    PROFILER.reset()


# ---------------------------------------------------------------------------
# (a) probe neutrality: probe-attached == probe-off, bitwise
# ---------------------------------------------------------------------------

def _assert_records_bit_identical(ev, ve):
    assert len(ev) == len(ve)
    for a, b in zip(ev, ve):
        assert a["scenario"] == b["scenario"]
        assert a["params"] == b["params"]
        assert a["key"] == b["key"]
        assert a["metrics"] == b["metrics"], a["scenario"]


@pytest.mark.parametrize("sweep,n_req", [("fig1", 16), ("fleet", 10),
                                         ("shift", 10)])
def test_probe_attached_records_bit_identical(sweep, n_req):
    scenarios = SWEEPS[sweep].build(True, n_requests=n_req)
    rec = FlightRecorder(resolution_s=30.0)
    off, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    on, _ = SweepRunner(cache=None, mode="event_loop",
                        probe=rec).run(scenarios)
    _assert_records_bit_identical(off, on)
    # the probe did observe the runs it rode along
    assert rec.n_stage_events > 0
    assert rec.timelines
    tl = next(iter(rec.timelines.values()))
    assert float(np.max(tl["power_w"])) > 0.0


def day_cfg(n=1200, span=900.0):
    wl = WorkloadConfig(
        n_requests=n, qps=n / span, min_len=192, max_len=192, seed=0,
        envelope="sinusoidal", envelope_amplitude=0.3,
        envelope_period_h=span / 3600.0, burst_gain=2.5,
        burst_mean_s=span / 15.0, burst_idle_mean_s=span / 2.5)
    return FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="s0", ci_trace="caiso-night",
                          scheduler=SchedulerConfig(batch_cap=64)),),
        workload=wl, router="round_robin",
        day=DayConfig(mode="hybrid", epoch_s=300.0, pilot_requests=128,
                      warmup_requests=32, util_threshold=0.6))


def test_probe_attached_day_summary_bit_identical():
    cfg = day_cfg()
    rec = FlightRecorder(resolution_s=60.0)
    off = run_fleet_day(cfg).summary()
    on = run_fleet_day(cfg, probe=rec).summary()
    assert off == on
    # epoch evals + the site rollup timeline came through site-tagged
    assert rec.epochs and all(e["site"] == 0 for e in rec.epochs)
    assert 0 in rec.timelines
    assert rec.n_stage_events > 0


def test_null_probe_run_bit_identical():
    scenarios = SWEEPS["fig1"].build(True, n_requests=16)
    off, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    on, _ = SweepRunner(cache=None, mode="event_loop",
                        probe=NULL_PROBE).run(scenarios)
    _assert_records_bit_identical(off, on)


def test_probe_rejected_in_device_mode():
    with pytest.raises(ValueError, match="device"):
        SweepRunner(cache=None, mode="device", probe=NULL_PROBE)


def test_site_index_probe_retags_every_hook():
    rec = FlightRecorder()
    wrapped = SiteIndexProbe(rec, site=3)

    class _Sched:
        waiting, running, kv_tokens = (), (1, 2), 64

    wrapped.on_stage(1.0, 0.5, 0, 0, _Sched(), 10, 2, 2)
    wrapped.on_route(1.0, 7, 0)
    wrapped.on_scale(2.0, 0, 2, 1, "up")
    wrapped.on_requests(np.array([0.0]), np.array([5.0]))
    stages = rec.stage_table()
    assert int(stages["site"][0]) == 3
    assert int(rec.route_table()["site"][0]) == 3
    assert rec.scales[0]["site"] == 3
    assert rec._requests[0][0] == 3


def test_backlog_series_counts_held_requests():
    rec = FlightRecorder()
    rec.on_requests(np.array([0.0, 1.0, 2.0]),
                    np.array([10.0, 1.0, 12.0]))  # 2 of 3 deferred
    t, depth = rec.backlog_series()
    assert list(t) == [0.0, 2.0, 10.0, 12.0]
    assert list(depth) == [1, 2, 1, 0]


def test_column_builder_grows_and_casts():
    cb = ColumnBuilder(("a", "b"), int_fields=("b",), capacity=2)
    for i in range(9):  # forces two doublings
        cb.append(i * 0.5, i)
    out = cb.build()
    assert len(cb) == 9
    assert out["a"].dtype == np.float64 and out["b"].dtype == np.int64
    assert list(out["b"]) == list(range(9))


# ---------------------------------------------------------------------------
# (b) Chrome trace schema
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def recorded_fleet():
    """One fleet scenario recorded with both clocks."""
    sc = SWEEPS["fleet"].build(True, n_requests=10)[0]
    rec = FlightRecorder(resolution_s=30.0)
    PROFILER.enable(reset=True)
    try:
        with PROFILER.span("execute_scenario"):
            execute_scenario(sc, probe=rec)
    finally:
        PROFILER.disable()
    events = chrome_trace_events(rec, PROFILER)
    yield rec, events
    PROFILER.reset()


def test_trace_is_valid_json_with_leading_metadata(recorded_fleet):
    _, events = recorded_fleet
    json.loads(json.dumps(events))  # round-trips
    phs = [e["ph"] for e in events]
    n_meta = phs.count("M")
    assert n_meta > 0 and all(p == "M" for p in phs[:n_meta])
    assert "M" not in phs[n_meta:]


def test_trace_timestamps_monotonic(recorded_fleet):
    _, events = recorded_fleet
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_trace_wall_spans_pair_and_nest(recorded_fleet):
    _, events = recorded_fleet
    stack = []
    for e in events:
        if e.get("pid") != WALL_PID or e["ph"] not in ("B", "E"):
            continue
        if e["ph"] == "B":
            stack.append(e["name"])
        else:
            assert stack and stack.pop() == e["name"]
    assert not stack  # every B closed


def test_trace_carries_sim_counters_and_stages(recorded_fleet):
    rec, events = recorded_fleet
    counter_names = {e["name"] for e in events if e["ph"] == "C"}
    assert "power_w" in counter_names and "devices" in counter_names
    assert any(n.startswith("queue r") for n in counter_names)
    n_stage_x = sum(1 for e in events
                    if e["ph"] == "X" and e["name"] == "stage")
    assert n_stage_x == rec.n_stage_events
    # routing instants live on the admission track
    assert any(e.get("pid") == ADMISSION_PID for e in events)


def test_trace_and_csv_files(tmp_path, recorded_fleet):
    rec, _ = recorded_fleet
    info = write_chrome_trace(tmp_path / "t.json", rec, PROFILER)
    payload = json.loads((tmp_path / "t.json").read_text())
    assert len(payload["traceEvents"]) == info["n_events"] > 0
    paths = write_csvs(tmp_path / "csv", rec, PROFILER)
    names = {p.name for p in paths}
    assert {"stages.csv", "routes.csv", "spans.csv"} <= names
    header = (tmp_path / "csv" / "stages.csv").read_text() \
        .splitlines()[0]
    assert tuple(header.split(",")) == STAGE_FIELDS


# ---------------------------------------------------------------------------
# (c) wall-clock profiler + logger
# ---------------------------------------------------------------------------

def test_span_profiler_nesting_and_aggregate():
    prof = SpanProfiler()
    prof.enable()
    with prof.span("outer"):
        with prof.span("inner"):
            pass
        with prof.span("inner"):
            pass
    prof.disable()
    spans = prof.spans()
    assert [(n, d) for n, _, _, d in spans] == \
        [("outer", 0), ("inner", 1), ("inner", 1)]
    agg = prof.aggregate()
    assert agg["inner"]["count"] == 2 and agg["outer"]["count"] == 1
    assert agg["outer"]["total_s"] >= agg["inner"]["total_s"]
    assert "outer" in prof.format_aggregate()


def test_span_profiler_disabled_records_nothing():
    prof = SpanProfiler()
    with prof.span("phase"):
        pass
    assert prof.spans() == [] and prof.aggregate() == {}


def test_span_profiler_merge_folds_worker_aggregates():
    prof = SpanProfiler()
    prof.enable()
    with prof.span("p"):
        pass
    prof.disable()
    prof.merge({"p": {"count": 2, "total_s": 1.5},
                "q": {"count": 1, "total_s": 0.25}})
    agg = prof.aggregate()
    assert agg["p"]["count"] == 3 and agg["q"]["count"] == 1
    # merged phases carry no span events of their own
    assert [n for n, *_ in prof.spans()] == ["p"]


def test_logger_namespacing_and_verbosity():
    assert get_logger("sweep").name == "repro.sweep"
    assert get_logger("repro.sweep").name == "repro.sweep"
    root = configure(verbosity=-1)
    try:
        assert root.level == logging.WARNING
        assert configure(verbosity=0).level == logging.INFO
        assert configure(verbosity=2).level == logging.DEBUG
        # idempotent: reconfiguring replaces rather than stacks
        configure(verbosity=0)
        assert len(root.handlers) == 1
    finally:
        for h in list(root.handlers):
            root.removeHandler(h)


def test_logger_color_follows_no_color_and_tty(monkeypatch):
    import io

    from repro.obs.log import _ColorFormatter, _use_color

    plain = io.StringIO()                       # not a tty
    monkeypatch.delenv("NO_COLOR", raising=False)
    assert not _use_color(plain)

    class _Tty(io.StringIO):
        def isatty(self):
            return True

    assert _use_color(_Tty())
    monkeypatch.setenv("NO_COLOR", "1")         # NO_COLOR beats tty
    assert not _use_color(_Tty())
    monkeypatch.delenv("NO_COLOR", raising=False)

    # redirected streams get a plain formatter end to end
    root = configure(verbosity=0, stream=plain)
    try:
        get_logger("sweep").warning("beware")
        assert "beware" in plain.getvalue()
        assert "\x1b[" not in plain.getvalue()
    finally:
        for h in list(root.handlers):
            root.removeHandler(h)

    # the color formatter wraps WARNING+ and leaves INFO bare
    fmt = _ColorFormatter("%(message)s")
    rec = logging.LogRecord("repro", logging.WARNING, __file__, 0,
                            "boom", None, None)
    assert fmt.format(rec) == "\x1b[33mboom\x1b[0m"
    rec.levelno = logging.INFO
    assert fmt.format(rec) == "boom"


def test_sweep_summary_reports_peak_rss():
    scenarios = SWEEPS["fig1"].build(True, n_requests=8)
    _, stats = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    assert stats.peak_rss_mb > 0.0              # Linux: ru_maxrss in KB
    assert "peak RSS" in stats.summary()
    assert f"{stats.peak_rss_mb:.0f} MB" in stats.summary()


def test_probe_base_hooks_are_noops():
    p = Probe()
    p.on_run_begin("tag")
    p.on_stage(0.0, 0.1, 0, 0, None, 0, 0, 0)
    p.on_complete(0.0, 0, 0, [])
    p.on_route(0.0, 0, 0)
    p.on_scale(0.0, 0, 1, 0, "up")
    p.on_requests([], [])
    p.on_epoch_eval(0, None)


# ---------------------------------------------------------------------------
# (d) cache effectiveness counters
# ---------------------------------------------------------------------------

def test_sweep_stats_report_cache_effectiveness(tmp_path):
    scenarios = SWEEPS["fig1"].build(True, n_requests=16)
    cache = ResultCache(tmp_path / "cache")
    _, cold = SweepRunner(cache=cache, mode="event_loop").run(scenarios)
    assert cold.cache_attached
    assert cold.cache_miss == len(scenarios) and cold.cache_memo == 0
    _, warm = SweepRunner(cache=cache, mode="event_loop").run(scenarios)
    assert warm.cache_memo == len(scenarios) and warm.cache_miss == 0
    assert f"cache {len(scenarios)} memo / 0 disk / 0 miss" \
        in warm.summary()
    # a fresh process-equivalent (empty memo) serves off disk
    disk_cache = ResultCache(tmp_path / "cache")
    _, disk = SweepRunner(cache=disk_cache,
                          mode="event_loop").run(scenarios)
    assert disk.cache_disk == len(scenarios) and disk.cache_miss == 0
    _, bare = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    assert not bare.cache_attached and "memo" not in bare.summary()


# ---------------------------------------------------------------------------
# flight-recorder CLI
# ---------------------------------------------------------------------------

def test_obs_cli_list_and_record(tmp_path, capsys):
    from repro.obs.__main__ import main

    assert main(["list", "--smoke"]) == 0
    assert "fig1" in capsys.readouterr().out

    out = tmp_path / "fig1.trace.json"
    rc = main(["--quiet", "record", "fig1", "--smoke",
               "--n-requests", "8", "--resolution", "30",
               "--out", str(out), "--csv-dir", str(tmp_path / "csv")])
    assert rc == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["stage_events"] > 0
    assert summary["trace_events"] > 0 and out.exists()
    assert (tmp_path / "csv" / "stages.csv").exists()


def test_obs_cli_unknown_sweep_fails(capsys):
    from repro.obs.__main__ import main

    assert main(["--quiet", "record", "nope"]) == 2
    assert "unknown sweep" in capsys.readouterr().err
