"""Paper-core unit + property tests: Eqs. 1-5."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core import (DEVICES, PowerModel, Signal, aggregate_power,
                        emissions, operational_energy, power, stage_mfu)
from repro.core.power import A100_SXM, H100_SXM, TPU_V5E


# ---------------------------- Eq. 1 ----------------------------

def test_power_calibration_points():
    """Idle and saturation anchor points from the paper's calibration."""
    assert float(power(0.0, A100_SXM)) == pytest.approx(100.0)
    assert float(power(0.45, A100_SXM)) == pytest.approx(400.0)
    assert float(power(1.0, A100_SXM)) == pytest.approx(400.0)  # clamped
    assert float(power(0.0, H100_SXM)) == pytest.approx(60.0)
    assert float(power(0.45, H100_SXM)) == pytest.approx(700.0)


def test_power_sublinear():
    """gamma < 1: half the MFU costs MORE than half the dynamic power."""
    p_half = float(power(0.225, A100_SXM)) - 100.0
    p_full = float(power(0.45, A100_SXM)) - 100.0
    assert p_half > 0.5 * p_full


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_power_monotone_bounded(m1, m2):
    for dev in (A100_SXM, H100_SXM, TPU_V5E):
        p1, p2 = float(power(m1, dev)), float(power(m2, dev))
        assert dev.p_idle <= p1 <= dev.p_max_inst + 1e-6
        if m1 <= m2:
            assert p1 <= p2 + 1e-6


# ---------------------------- Eqs. 2-3 ----------------------------

def test_stage_mfu():
    dev = A100_SXM
    # 312 TFLOPs in 1s at peak => MFU 1.0
    mfu = stage_mfu(np.array([dev.peak_flops / 2]),
                    np.array([dev.peak_flops / 2]), np.array([1.0]), dev)
    assert mfu[0] == pytest.approx(1.0)


def test_operational_energy_pue():
    pm = PowerModel("a100")
    rep1 = operational_energy(np.array([0.45]), np.array([3600.0]), pm,
                              n_devices=1, pue=1.0)
    rep2 = operational_energy(np.array([0.45]), np.array([3600.0]), pm,
                              n_devices=2, pue=1.2)
    assert rep1.energy_wh == pytest.approx(400.0)       # 400 W for 1 h
    assert rep2.energy_wh == pytest.approx(400.0 * 2 * 1.2)
    assert rep2.gpu_hours == pytest.approx(2.0)


# ---------------------------- Eq. 4 ----------------------------

def test_emissions_static_ci():
    rep = emissions(energy_wh=1000.0, gpu_hours=10.0, device=A100_SXM,
                    ci=400.0)
    assert rep.operational_g == pytest.approx(400.0)
    assert rep.embodied_g == pytest.approx(
        10.0 * A100_SXM.embodied_kg_per_hour * 1000.0)


def test_emissions_time_varying_ci():
    t = np.arange(0, 3600, 60.0)
    load = Signal(t, np.full_like(t, 1000.0))         # 1 kW constant
    ci = Signal(t, np.where(t < 1800, 100.0, 300.0))  # step change
    rep = emissions(0, 0, A100_SXM, ci, power_signal=load)
    assert rep.operational_g == pytest.approx(200.0, rel=0.05)


# ---------------------------- Eq. 5 ----------------------------

def test_aggregate_power_weighted():
    """Two stages in one bin: duration-weighted average."""
    sig = aggregate_power(np.array([0.0, 10.0]), np.array([10.0, 30.0]),
                          np.array([100.0, 300.0]), resolution_s=60.0)
    assert sig.values[0] == pytest.approx((100 * 10 + 300 * 30) / 40)


def test_aggregate_power_straddle():
    """A stage straddling a bin edge contributes per-overlap."""
    sig = aggregate_power(np.array([30.0]), np.array([60.0]),
                          np.array([200.0]), resolution_s=60.0)
    assert len(sig.values) == 2
    assert sig.values[0] == pytest.approx(200.0)
    assert sig.values[1] == pytest.approx(200.0)


@given(st.lists(st.tuples(st.floats(0, 1000), st.floats(0.1, 100),
                          st.floats(0, 500)), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_aggregate_power_bounds(stages):
    """Binned power is bounded by the min/max stage power (weighted avg)."""
    start = np.array([s[0] for s in stages])
    dur = np.array([s[1] for s in stages])
    p = np.array([s[2] for s in stages])
    sig = aggregate_power(start, dur, p, resolution_s=60.0)
    nz = sig.values[sig.values > 0]
    if len(nz):
        assert nz.max() <= p.max() + 1e-6
        assert nz.min() >= p.min() - 1e-6
