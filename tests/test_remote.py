"""Remote sweep backend: shard packing, lease semantics, end-to-end
coordinator/worker runs bit-identical to serial execution, and the
injected-crash retry path (a killed worker never loses or duplicates a
scenario record)."""
import json
import threading
import time

import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.sim import SchedulerConfig, SimConfig, WorkloadConfig
from repro.sweep import GridSpec, ResultCache, SweepRunner, pack_shards
from repro.sweep import remote
from repro.sweep.remote import (ENV_CRASH_AFTER_GROUPS, RemoteOptions,
                                claim_shard, parse_shard_name,
                                publish_shard, reclaim_expired,
                                release_shard, shard_file_name,
                                spawn_worker, wait_for_workers)
from repro.sweep.worker import choose_mode

from _hypothesis_support import given, settings, st


def tiny_base(n_requests=10):
    return SimConfig(
        model=LLAMA3_8B,
        workload=WorkloadConfig(n_requests=n_requests, qps=4.0,
                                min_len=64, max_len=256, seed=0),
        scheduler=SchedulerConfig(batch_cap=8))


def tiny_grid(n_configs=3, n_report=4):
    """n_configs trace groups x n_report shared-trace scenarios."""
    return GridSpec(base=tiny_base(),
                    axes={"workload.qps": [2.0 + i for i in range(n_configs)],
                          "pue": [1.0 + 0.1 * k for k in range(n_report)]}
                    ).expand()


# --------------------------------------------------------------------------
# shard packing
# --------------------------------------------------------------------------

@given(costs=st.lists(st.floats(min_value=0.0, max_value=1e6,
                                allow_nan=False), min_size=1,
                      max_size=64),
       n_shards=st.integers(min_value=1, max_value=16))
@settings(max_examples=200, deadline=None)
def test_pack_shards_preserves_multiset_and_lpt_bound(costs, n_shards):
    shards = pack_shards(costs, n_shards)
    # the exact index multiset is preserved: nothing lost, duplicated
    # or invented
    flat = sorted(i for s in shards for i in s)
    assert flat == list(range(len(costs)))
    assert all(s for s in shards)            # no empty shards
    # greedy LPT guarantee: makespan <= total/k + max item
    k = max(1, min(n_shards, len(costs)))
    loads = [sum(costs[i] for i in s) for s in shards]
    assert max(loads) <= sum(costs) / k + max(costs) + 1e-6


def test_pack_shards_deterministic_and_balanced():
    costs = [100.0, 1.0, 1.0, 1.0, 50.0, 49.0]
    a = pack_shards(costs, 2)
    assert a == pack_shards(costs, 2)
    loads = sorted(sum(costs[i] for i in s) for s in a)
    # LPT splits this 202-cost instance exactly evenly (100+1 / 50+49+1+1)
    assert loads == [101.0, 101.0]


def test_pack_shards_more_shards_than_items():
    shards = pack_shards([3.0, 1.0], 8)
    assert sorted(i for s in shards for i in s) == [0, 1]
    assert len(shards) == 2


# --------------------------------------------------------------------------
# queue protocol: claims, leases, retries, quarantine
# --------------------------------------------------------------------------

def _job_dir(tmp_path):
    job = tmp_path / "job-t"
    for state in (remote.PENDING, remote.RUNNING, remote.DONE,
                  remote.FAILED):
        (job / state).mkdir(parents=True)
    return job


def test_shard_name_roundtrip():
    assert parse_shard_name(shard_file_name(7, 2)) == (7, 2, None)
    assert parse_shard_name(shard_file_name(7, 2, "w0")) == (7, 2, "w0")


def test_claim_is_exclusive(tmp_path):
    job = _job_dir(tmp_path)
    name = publish_shard(job, 0, {"shard": 0, "groups": []}).name
    first = claim_shard(job, name, "w0")
    assert first is not None
    assert claim_shard(job, name, "w1") is None   # lost the rename race
    payload, running = first
    assert payload["shard"] == 0
    assert running.exists()
    assert parse_shard_name(running.name) == (0, 0, "w0")


def test_lease_expiry_bumps_attempt_then_quarantines(tmp_path):
    job = _job_dir(tmp_path)
    name = publish_shard(job, 3, {"shard": 3, "groups": []}).name
    for expected_attempt in (1, 2):
        _, running = claim_shard(job, name, "w0")
        # age the lease past expiry without waiting
        import os
        old = time.time() - 3600
        os.utime(running, (old, old))
        exp, ret, quar = reclaim_expired(job, lease_s=5.0, max_attempts=3)
        assert (exp, ret, quar) == (1, 1, 0)
        pend = list((job / remote.PENDING).glob("shard-*.pkl"))
        assert len(pend) == 1
        name = pend[0].name
        assert parse_shard_name(name)[1] == expected_attempt
    # third failure exhausts max_attempts => quarantine
    _, running = claim_shard(job, name, "w0")
    outcome = release_shard(job, running, max_attempts=3, error="boom")
    assert outcome == "quarantined"
    assert not list((job / remote.PENDING).glob("shard-*.pkl"))
    manifest = json.loads(
        (job / remote.FAILED / "shard-0003.json").read_text())
    assert manifest["error"] == "boom" and manifest["attempts"] == 3


def test_heartbeat_refreshes_lease(tmp_path):
    job = _job_dir(tmp_path)
    name = publish_shard(job, 0, {"shard": 0, "groups": []}).name
    _, running = claim_shard(job, name, "w0")
    import os
    old = time.time() - 3600
    os.utime(running, (old, old))
    assert remote.heartbeat(running)
    assert reclaim_expired(job, lease_s=5.0, max_attempts=3) == (0, 0, 0)
    running.unlink()
    assert not remote.heartbeat(running)   # reclaimed/completed: False


def test_unreadable_payload_is_quarantined(tmp_path):
    job = _job_dir(tmp_path)
    path = job / remote.PENDING / shard_file_name(4, 0)
    path.write_bytes(b"not a pickle")
    assert claim_shard(job, path.name, "w0") is None
    assert (job / remote.FAILED / "shard-0004.json").exists()


def test_choose_mode():
    payload = {"mode": "vectorized",
               "groups": [[type("S", (), {"cfg": tiny_base()})()]]}
    assert choose_mode("inherit", payload) == "vectorized"
    assert choose_mode("device", payload) == "device"
    assert choose_mode("auto", payload) == "device"  # single-site shard


# --------------------------------------------------------------------------
# runner integration + validation
# --------------------------------------------------------------------------

def test_remote_backend_requires_cache_and_rejects_probe():
    with pytest.raises(ValueError, match="requires a ResultCache"):
        SweepRunner(cache=None, backend="remote")
    with pytest.raises(ValueError, match="unknown backend"):
        SweepRunner(backend="carrier-pigeon")
    cache = ResultCache.__new__(ResultCache)   # placeholder, not used
    with pytest.raises(ValueError, match="trace groups"):
        SweepRunner(cache=cache, backend="remote", mode="event_loop")
    from repro.obs.probe import NULL_PROBE
    with pytest.raises(ValueError, match="probe"):
        SweepRunner(cache=cache, backend="remote", probe=NULL_PROBE)


@pytest.mark.slow
def test_remote_run_matches_serial_bitwise(tmp_path):
    """Happy path: coordinator + 2 spawned workers over a real queue,
    records bit-identical to in-process execution, zero expired
    leases, and the follow-up run is all cache hits."""
    scenarios = tiny_grid(n_configs=4, n_report=3)
    cache = ResultCache(tmp_path / "cache")
    opts = RemoteOptions(queue_dir=tmp_path / "q", spawn_workers=2,
                         lease_s=15.0, verify_groups=1, timeout_s=180)
    records, stats = SweepRunner(cache=cache, backend="remote",
                                 remote=opts).run(scenarios)
    assert stats.executed == len(scenarios)
    assert stats.shards >= 1 and stats.remote_workers >= 1
    assert stats.lease_expired == 0 and stats.quarantined == 0

    ref, _ = SweepRunner(cache=None, mode="vectorized").run(scenarios)
    assert [r["metrics"] for r in records] == [r["metrics"] for r in ref]
    assert all(r["meta"]["cache_hit"] is False for r in records)

    again, stats2 = SweepRunner(cache=cache, backend="remote",
                                remote=opts).run(scenarios)
    assert stats2.executed == 0
    assert stats2.cache_hits == len(scenarios)
    assert [r["metrics"] for r in again] == [r["metrics"] for r in ref]


@pytest.mark.slow
def test_injected_crash_converges_bit_identical(tmp_path):
    """A worker killed mid-shard (after persisting one group) loses its
    lease; the shard is re-pended and a second worker re-executes it.
    The final records are bit-identical to serial execution — the
    partially-written cache entries are simply overwritten with
    identical bytes, never torn or duplicated."""
    scenarios = tiny_grid(n_configs=4, n_report=3)
    cache = ResultCache(tmp_path / "cache")
    q = tmp_path / "q"
    opts = RemoteOptions(queue_dir=q, spawn_workers=0, n_shards=2,
                         lease_s=1.0, poll_s=0.05, timeout_s=180)

    out = {}
    def coordinate():
        out["res"] = SweepRunner(cache=cache, backend="remote",
                                 remote=opts).run(scenarios)
    t = threading.Thread(target=coordinate)
    t.start()
    try:
        # worker A crashes (os._exit) after finishing exactly 1 group
        pa = spawn_worker(q, "crashy",
                          env={ENV_CRASH_AFTER_GROUPS: "1"},
                          log_path=tmp_path / "a.log")
        assert pa.wait(timeout=120) == 17
        # worker B drains the rest, including the reclaimed shard
        pb = spawn_worker(q, "steady", log_path=tmp_path / "b.log")
        t.join(timeout=150)
        pb.terminate()
        pb.wait(timeout=10)
    finally:
        (q / "stop").touch()
        t.join(timeout=30)
    assert not t.is_alive()
    records, stats = out["res"]

    assert stats.lease_expired >= 1 and stats.retried >= 1
    assert stats.quarantined == 0

    ref, _ = SweepRunner(cache=None, mode="vectorized").run(scenarios)
    assert [r["metrics"] for r in records] == [r["metrics"] for r in ref]

    # no torn or duplicated cache entries: exactly one valid JSON per
    # unique scenario key, each round-tripping its own digest
    keys = list(cache.iter_keys())
    assert sorted(keys) == sorted({sc.key for sc in scenarios})
    for key in keys:
        on_disk = json.loads(cache.path_for(key).read_text())
        assert on_disk["key"] == key


@pytest.mark.slow
def test_worker_skips_schema_mismatched_jobs(tmp_path):
    """Version skew: a worker whose checkout disagrees on the record
    schema must never execute the job (records under a stale digest
    would poison the shared cache)."""
    scenarios = tiny_grid(n_configs=1, n_report=2)
    q = tmp_path / "q"
    job = q / "job-skew"
    for state in (remote.PENDING, remote.RUNNING, remote.DONE,
                  remote.FAILED):
        (job / state).mkdir(parents=True)
    remote.atomic_write_json(job / "job.json", {
        "job": "skew", "status": "open", "schema": -1,
        "mode": "vectorized", "n_shards": 1, "lease_s": 30.0,
        "max_attempts": 3, "cache_root": str(tmp_path / "cache")})
    publish_shard(job, 0, {"job": "skew", "shard": 0, "schema": -1,
                           "mode": "vectorized",
                           "groups": [list(scenarios)]})
    proc = spawn_worker(q, "w0", log_path=tmp_path / "w.log")
    try:
        # wait until the worker is registered (warm) and has had time
        # to scan the queue, then check the shard is still pending
        wait_for_workers(q, 1, timeout_s=120)
        time.sleep(1.0)
        assert list((job / remote.PENDING).glob("shard-*.pkl"))
        assert not list((job / remote.RUNNING).glob("shard-*.pkl"))
        assert not list((job / remote.DONE).glob("*.json"))
    finally:
        proc.terminate()
        proc.wait(timeout=10)


def test_coordinator_rejects_fully_quarantined_job(tmp_path):
    """A poison shard that exhausts its attempts fails the job loudly
    instead of returning partial records."""
    scenarios = tiny_grid(n_configs=1, n_report=2)
    cache = ResultCache(tmp_path / "cache")
    opts = RemoteOptions(queue_dir=tmp_path / "q", spawn_workers=0,
                         n_shards=1, lease_s=0.2, poll_s=0.05,
                         max_attempts=1, timeout_s=60)
    out = {}
    def coordinate():
        try:
            SweepRunner(cache=cache, backend="remote",
                        remote=opts).run(scenarios)
        except RuntimeError as exc:
            out["err"] = exc
    t = threading.Thread(target=coordinate)
    t.start()
    # claim the only shard and let the lease lapse without heartbeat:
    # with max_attempts=1 the reclaim quarantines it immediately
    deadline = time.monotonic() + 30
    claimed = None
    while claimed is None and time.monotonic() < deadline:
        jobs = sorted((tmp_path / "q").glob("job-*"))
        for job in jobs:
            for p in (job / remote.PENDING).glob("shard-*.pkl"):
                claimed = claim_shard(job, p.name, "dead-worker")
                if claimed:
                    break
        time.sleep(0.05)
    assert claimed is not None
    t.join(timeout=60)
    assert not t.is_alive()
    assert "quarantined" in str(out["err"])


def test_shard_payload_roundtrips_scenarios(tmp_path):
    """Scenarios pickle losslessly through a shard file — the lazily
    cached digest fields don't leak stale state across the boundary."""
    scenarios = tiny_grid(n_configs=2, n_report=2)
    job = _job_dir(tmp_path)
    publish_shard(job, 0, {"shard": 0, "groups": [list(scenarios)]})
    name = shard_file_name(0, 0)
    payload, _ = claim_shard(job, name, "w0")
    thawed = payload["groups"][0]
    assert [sc.key for sc in thawed] == [sc.key for sc in scenarios]
    assert [sc.trace_key for sc in thawed] == \
        [sc.trace_key for sc in scenarios]
    assert thawed[0].cfg.workload.qps == scenarios[0].cfg.workload.qps
