"""repro.schedule subsystem: workload classes, CI forecasters,
SLO-bounded admission policies, the carbon_slo router, the real-trace
CSV loader, and the shift sweep's temporal-shifting acceptance pins."""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs.paper_models import LLAMA3_8B
from repro.core.datasets import (CI_TRACE_FILES, ci_trace_signal,
                                 load_ci_csv)
from repro.core.signals import Signal
from repro.fleet import FleetConfig, SiteConfig, make_router, \
    run_fleet_simulation
from repro.schedule import (ScheduleConfig, apply_admission, class_stats,
                            fleet_ci_forecast, make_admission,
                            make_forecaster)
from repro.sim.requests import (DEFERRABLE, INTERACTIVE, Request,
                                WorkloadConfig, generate)
from repro.sim.scheduler import SchedulerConfig


# ---------------------------------------------------------------------------
# workload classes
# ---------------------------------------------------------------------------

def test_class_tagging_preserves_arrival_and_length_streams():
    """Class tags draw after the arrival/length streams: frac=0 and
    frac=0.5 workloads share identical arrivals and token counts."""
    base = WorkloadConfig(n_requests=64, seed=3)
    tagged = dataclasses.replace(base, deferrable_frac=0.5,
                                 deferrable_deadline_s=600.0)
    a, b = generate(base), generate(tagged)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert [r.prefill_tokens for r in a] == [r.prefill_tokens for r in b]
    assert [r.decode_tokens for r in a] == [r.decode_tokens for r in b]
    assert all(r.klass == INTERACTIVE for r in a)
    classes = {r.klass for r in b}
    assert classes == {INTERACTIVE, DEFERRABLE}


def test_class_tagging_sets_deadlines_and_slos():
    wl = WorkloadConfig(n_requests=200, seed=1, deferrable_frac=0.4,
                        deferrable_deadline_s=900.0,
                        interactive_slo_s=15.0)
    reqs = generate(wl)
    defer = [r for r in reqs if r.klass == DEFERRABLE]
    inter = [r for r in reqs if r.klass == INTERACTIVE]
    assert 0.2 < len(defer) / len(reqs) < 0.6
    for r in defer:
        assert r.deadline_s == pytest.approx(r.arrival_s + 900.0)
    for r in inter:
        assert r.slo_s == 15.0 and math.isinf(r.deadline_s)
    # ready time defaults to arrival until an admission policy parks
    assert all(r.ready_s == r.arrival_s for r in reqs)


# ---------------------------------------------------------------------------
# forecasters
# ---------------------------------------------------------------------------

def _sig(vals, step_s=60.0):
    vals = np.asarray(vals, np.float64)
    return Signal(np.arange(len(vals)) * step_s, vals, interp="linear")


def test_oracle_forecaster_is_the_trace():
    sig = ci_trace_signal("caiso", 4.0)
    f = make_forecaster("oracle")
    ts = np.array([0.0, 1800.0, 7200.0])
    np.testing.assert_allclose(f.predict(sig, 0.0, ts), sig.at(ts))


def test_persistence_forecaster_is_flat():
    sig = _sig([100.0, 200.0, 300.0, 400.0])
    f = make_forecaster("persistence")
    pred = f.predict(sig, 60.0, np.array([60.0, 120.0, 180.0]))
    np.testing.assert_allclose(pred, 200.0)


def test_diurnal_forecaster_follows_duck_shape():
    """From a 9am observation the template must predict the midday dip
    below and the evening ramp above the current level."""
    sig = _sig([300.0] * 2)
    f = make_forecaster("diurnal", swing_frac=0.3)
    t9 = 9 * 3600.0
    pred = f.predict(sig, t9, np.array([13 * 3600.0, 19.5 * 3600.0]))
    now = float(f.predict(sig, t9, np.array([t9]))[0])
    assert pred[0] < now < pred[1]


def test_unknown_forecaster_and_policy_raise():
    with pytest.raises(KeyError):
        make_forecaster("crystal-ball")
    with pytest.raises(KeyError):
        make_admission("vibes")


# ---------------------------------------------------------------------------
# admission policies (unit, synthetic step forecast)
# ---------------------------------------------------------------------------

def _step_forecast(t_low_s, hi=500.0, lo=100.0):
    """CI stays hi until t_low_s, then drops to lo."""
    def fn(ts):
        ts = np.asarray(ts, np.float64)
        return np.where(ts < t_low_s, hi, lo)
    return fn


def _deferrable(arrival=0.0, deadline=7200.0):
    return Request(rid=0, arrival_s=arrival, prefill_tokens=100,
                   decode_tokens=10, klass=DEFERRABLE,
                   deadline_s=arrival + deadline)


def test_immediate_admission_is_noop():
    pol = make_admission("immediate")
    req = _deferrable()
    assert pol.release_time(req, 0.0, _step_forecast(3600.0), 0) == 0.0


def test_threshold_defer_parks_until_low_window():
    pol = make_admission("threshold_defer", ci_high=300.0, ci_low=150.0,
                         step_s=300.0)
    rel = pol.release_time(_deferrable(), 0.0, _step_forecast(3600.0), 0)
    assert 3600.0 <= rel <= 3900.0          # first below-low grid point
    # already-low CI admits immediately
    assert pol.release_time(_deferrable(), 0.0,
                            _step_forecast(0.0), 0) == 0.0


def test_threshold_defer_respects_deadline_and_backlog():
    pol = make_admission("threshold_defer", ci_high=300.0, ci_low=150.0,
                         step_s=300.0, service_margin_s=120.0,
                         max_backlog=1)
    # low window exists only past the deadline: release at the forecast
    # argmin within the feasible window, never past deadline - margin
    req = _deferrable(deadline=1800.0)
    rel = pol.release_time(req, 0.0, _step_forecast(999_999.0), 0)
    assert 0.0 <= rel <= 1800.0 - 120.0
    # full backlog forces immediate admission
    assert pol.release_time(_deferrable(), 0.0,
                            _step_forecast(3600.0), 1) == 0.0
    # interactive requests are never parked
    inter = Request(rid=1, arrival_s=0.0, prefill_tokens=1,
                    decode_tokens=1, klass=INTERACTIVE)
    assert pol.release_time(inter, 0.0, _step_forecast(3600.0), 0) == 0.0


def test_forecast_window_picks_cheapest_window():
    pol = make_admission("forecast_window", service_est_s=300.0,
                         step_s=300.0)
    # V-shaped forecast: min at 3600 s
    def vee(ts):
        ts = np.asarray(ts, np.float64)
        return 100.0 + np.abs(ts - 3600.0) / 36.0
    rel = pol.release_time(_deferrable(), 0.0, vee, 0)
    assert rel == pytest.approx(3600.0, abs=300.0)
    # flat forecast: no gain anywhere -> immediate
    assert pol.release_time(_deferrable(), 0.0,
                            lambda ts: np.full(np.shape(ts), 42.0),
                            0) == 0.0


def test_apply_admission_sets_releases_and_stats():
    wl = WorkloadConfig(n_requests=40, qps=1.0, seed=0,
                        deferrable_frac=0.5,
                        deferrable_deadline_s=7200.0)
    reqs = generate(wl)
    pol = make_admission("threshold_defer", ci_high=300.0, ci_low=150.0,
                         step_s=300.0)
    stats = apply_admission(reqs, pol,
                            lambda t, ts: _step_forecast(3600.0)(ts))
    defer = [r for r in reqs if r.klass == DEFERRABLE]
    assert stats["n_deferred"] == len(defer) > 0
    assert all(r.release_s > r.arrival_s for r in defer)
    assert all(r.release_s <= r.deadline_s for r in defer)
    assert all(r.release_s < 0 for r in reqs if r.klass == INTERACTIVE)
    assert stats["backlog_peak"] == len(defer)  # all park toward 3600 s
    # deferral delays are reported by class_stats (single source), from
    # the release times apply_admission wrote
    assert class_stats(reqs)["mean_deferral_delay_s"] > 0


def test_fleet_ci_forecast_combines_sites():
    f = make_forecaster("oracle")
    sigs = [_sig([100.0] * 5), _sig([300.0] * 5)]
    ts = np.array([0.0, 60.0])
    np.testing.assert_allclose(
        fleet_ci_forecast(f, sigs, "mean")(0.0, ts), 200.0)
    np.testing.assert_allclose(
        fleet_ci_forecast(f, sigs, "min")(0.0, ts), 100.0)
    with pytest.raises(ValueError):
        ScheduleConfig(ci_stat="median")


# ---------------------------------------------------------------------------
# carbon_slo router
# ---------------------------------------------------------------------------

class _View:
    def __init__(self, tokens=0, ci=100.0):
        self.tokens = tokens
        self.ci = ci

    def outstanding_tokens(self):
        return self.tokens

    def ci_at(self, t):
        return self.ci


def test_carbon_slo_routes_min_ci_under_slo():
    r = make_router("carbon_slo", 3, default_slo_s=10.0,
                    tokens_per_s=100.0)
    # site 0: cleanest but overloaded (delay 50 s > SLO); site 2 is the
    # cleanest site whose predicted queue delay fits the SLO
    views = [_View(tokens=5000, ci=50.0), _View(tokens=0, ci=400.0),
             _View(tokens=500, ci=120.0)]
    assert r.choose(None, 0.0, views) == 2
    # per-request SLO wins over the default
    tight = Request(rid=0, arrival_s=0.0, prefill_tokens=1,
                    decode_tokens=1, slo_s=1.0)
    assert r.choose(tight, 0.0, views) == 1     # only site 1 fits 1 s
    assert r.stats()["slo_fallbacks"] == 0


def test_carbon_slo_falls_back_to_least_loaded():
    r = make_router("carbon_slo", 2, default_slo_s=1.0,
                    tokens_per_s=100.0)
    views = [_View(tokens=900, ci=50.0), _View(tokens=500, ci=800.0)]
    assert r.choose(None, 0.0, views) == 1      # nothing fits: JSQ
    assert r.stats()["slo_fallbacks"] == 1


def test_carbon_slo_in_fleet_beats_round_robin_on_divergent_ci():
    """With light load everything fits the SLO, so carbon_slo behaves
    carbon-greedily and must emit less than round-robin."""
    def fleet(router):
        sites = tuple(SiteConfig(name=f"s{i}-{t}", ci_trace=t,
                                 scheduler=SchedulerConfig(batch_cap=16))
                      for i, t in enumerate(("hydro", "coal")))
        return FleetConfig(model=LLAMA3_8B, sites=sites,
                           workload=WorkloadConfig(n_requests=48, qps=5.0,
                                                   min_len=64, max_len=512,
                                                   seed=0),
                           router=router)
    slo = run_fleet_simulation(fleet("carbon_slo")).summary()
    rr = run_fleet_simulation(fleet("round_robin")).summary()
    assert slo["carbon_operational_g"] < rr["carbon_operational_g"]
    assert slo["n_requests_done"] == rr["n_requests_done"] == 48
    assert slo["interactive_ttft_p99_s"] <= 30.0


# ---------------------------------------------------------------------------
# real-trace CSV loader
# ---------------------------------------------------------------------------

def test_load_ci_csv_parses_iso_timestamps(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("datetime,zone,carbon_intensity\n"
                 "2024-04-02T01:00:00+00:00,X,210.5\n"
                 "2024-04-02T00:00:00Z,X,200.0\n"           # out of order
                 "2024-04-02T02:00:00+00:00,X,,\n"          # malformed
                 "2024-04-02T02:30:00+00:00,X,NaN\n"        # missing reading
                 "2024-04-02T02:45:00+00:00,X,null\n"       # placeholder
                 "2024-04-02T03:00:00,X,230.0\n")           # naive -> UTC
    sig = load_ci_csv(p)
    np.testing.assert_allclose(sig.times, [0.0, 3600.0, 3 * 3600.0])
    np.testing.assert_allclose(sig.values, [200.0, 210.5, 230.0])


def test_load_ci_csv_rejects_unknown_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("a,b\n1,2\n3,4\n")
    with pytest.raises(ValueError):
        load_ci_csv(p)


def test_bundled_electricitymaps_trace_registered():
    assert "caiso-em" in CI_TRACE_FILES
    sig = ci_trace_signal("caiso-em", 48.0)
    assert float(sig.values.min()) > 50.0
    assert float(sig.values.max()) < 600.0
    # duck curve: midday (13h) below the evening ramp (19-20h)
    assert sig.at(13 * 3600.0) < sig.at(19.5 * 3600.0)


def test_register_ci_trace_file_rejects_name_collisions(tmp_path):
    from repro.core.datasets import register_ci_trace_file
    p = tmp_path / "t.csv"
    p.write_text("time_s,value\n0,100\n3600,200\n")
    with pytest.raises(ValueError):
        register_ci_trace_file("caiso", p)       # synthetic name
    with pytest.raises(ValueError):
        register_ci_trace_file("caiso-em", p)    # bundled file trace
    register_ci_trace_file("my-zone", p)
    try:
        sig = ci_trace_signal("my-zone", 1.0)
        np.testing.assert_allclose(sig.values, [100.0, 200.0])
    finally:
        del CI_TRACE_FILES["my-zone"]


def test_endpoint_exclusive_trace_tiles_without_phase_drift(tmp_path):
    """A 24-row hourly export (t=0..23h) must tile with a 24 h period,
    not its 23 h span — the diurnal phase may not drift per repeat."""
    from repro.core.datasets import _tile_signal, load_ci_csv
    p = tmp_path / "day.csv"
    rows = "\n".join(f"{h * 3600},{100 + h}" for h in range(24))
    p.write_text("time_s,value\n" + rows + "\n")
    tiled = _tile_signal(load_ci_csv(p), 24 * 5.0)
    ts = np.arange(0, 23 * 3600.0, 1800.0)
    for day in (1, 4):
        np.testing.assert_allclose(tiled.at(ts + day * 86400.0),
                                   tiled.at(ts))


def test_file_trace_tiles_prefix_stably_past_its_span():
    short = ci_trace_signal("caiso-em", 2.0)
    long = ci_trace_signal("caiso-em", 120.0)   # > 48 h: tiled
    ts = np.arange(0, 2 * 3600.0, 600.0)
    np.testing.assert_allclose(long.at(ts), short.at(ts))
    # tiled region repeats the trace with period = the file's span
    # (away from the seam's first interpolation segment: the raw trace
    # isn't exactly periodic, so that one segment blends the endpoints)
    span = 48 * 3600.0
    ts = np.arange(3600.0, 40 * 3600.0, 600.0)
    np.testing.assert_allclose(long.at(ts + span), long.at(ts))


# ---------------------------------------------------------------------------
# integration: the temporal gate inside the fleet loop
# ---------------------------------------------------------------------------

def _shift_cfg(policy, traces=("hydro-evening", "coal-evening"),
               router="carbon_slo", forecaster="oracle", n=64):
    """The shift experiment shape: arrivals spanning the evening CI
    ramp, half the requests deferrable, fixed co-sim horizon. With the
    carbon_slo router the site assignment is invariant to release
    order (light load all fits the SLO on the clean site), so the
    policy axis isolates the temporal gate."""
    wl = WorkloadConfig(n_requests=n, qps=n / (4 * 3600.0), min_len=128,
                        max_len=1024, seed=0, deferrable_frac=0.5,
                        deferrable_deadline_s=7200.0,
                        interactive_slo_s=30.0)
    sites = tuple(SiteConfig(name=f"s{i}-{t}", ci_trace=t,
                             scheduler=SchedulerConfig(batch_cap=64))
                  for i, t in enumerate(traces))
    return FleetConfig(model=LLAMA3_8B, sites=sites, workload=wl,
                       router=router,
                       schedule=ScheduleConfig(
                           policy=policy, forecaster=forecaster,
                           ci_stat=("min" if router == "carbon_slo"
                                    else "mean")),
                       horizon_s=4 * 3600.0 + 7200.0 + 3600.0)


def test_immediate_policy_is_bit_identical_to_no_schedule():
    """Acceptance: policy="immediate" (and a threshold policy over a
    workload with no deferrable class) reproduce the scheduling-free
    event loop bit for bit."""
    plain = _shift_cfg("immediate", router="round_robin")
    gated = dataclasses.replace(
        plain, schedule=ScheduleConfig(policy="threshold_defer"),
        workload=dataclasses.replace(plain.workload, deferrable_frac=0.0))
    plain = dataclasses.replace(
        plain, workload=dataclasses.replace(plain.workload,
                                            deferrable_frac=0.0))
    a = run_fleet_simulation(plain)
    b = run_fleet_simulation(gated)
    for sa, sb in zip(a.sites, b.sites):
        np.testing.assert_array_equal(sa.stages.start_s, sb.stages.start_s)
        np.testing.assert_array_equal(sa.stages.dur_s, sb.stages.dur_s)
        np.testing.assert_array_equal(sa.stages.mfu, sb.stages.mfu)
    assert a.summary() == pytest.approx(b.summary())


def test_deferral_cuts_active_carbon_on_divergent_pair():
    """THE acceptance pin (mirrored by the shift-smoke CI job): on the
    divergent evening-ramp pair composed with SLO-bounded carbon
    routing, oracle-forecast deferral cuts request-attributable
    operational carbon vs immediate admission, every request completes
    within its deadline, and the interactive class's p99 TTFT is
    untouched and within SLO."""
    res = {p: run_fleet_simulation(_shift_cfg(p)).summary()
           for p in ("immediate", "threshold_defer", "forecast_window")}
    imm, td, fw = (res["immediate"], res["threshold_defer"],
                   res["forecast_window"])
    assert td["carbon_active_g"] < imm["carbon_active_g"]
    assert fw["carbon_active_g"] < imm["carbon_active_g"]
    # the co-sim net must not worsen under the hysteresis policy (the
    # greedy window policy can touch extra Eq. 5 bins whose idle-
    # attribution quantization exceeds the active saving at this scale)
    assert td["carbon_operational_g"] <= \
        imm["carbon_operational_g"] * (1 + 1e-9)
    for r in (td, fw):
        assert r["n_requests_done"] == imm["n_requests_done"] == 64
        assert r["deadline_violations"] == 0
        assert r["deferred_fraction"] > 0.2
        assert r["mean_deferral_delay_s"] > 0
        assert r["interactive_ttft_p99_s"] == pytest.approx(
            imm["interactive_ttft_p99_s"], rel=0.25, abs=0.5)
        assert r["interactive_ttft_p99_s"] <= 30.0
        assert r["interactive_slo_violations"] == 0


def test_deferral_cuts_active_carbon_single_site():
    """Temporal gate in isolation: one diurnal site, so routing cannot
    move anything and the whole effect is admission timing."""
    res = {p: run_fleet_simulation(
        _shift_cfg(p, traces=("caiso-evening",),
                   router="round_robin")).summary()
        for p in ("immediate", "threshold_defer", "forecast_window")}
    assert res["threshold_defer"]["carbon_active_g"] < \
        res["immediate"]["carbon_active_g"]
    assert res["forecast_window"]["carbon_active_g"] < \
        res["immediate"]["carbon_active_g"]


def test_persistence_forecaster_defers_less_than_oracle():
    """Persistence sees a flat future, so threshold/window policies
    find nothing to shift toward — the no-skill floor."""
    cfg = _shift_cfg("forecast_window")
    pers = dataclasses.replace(
        cfg, schedule=dataclasses.replace(cfg.schedule,
                                          forecaster="persistence"))
    s_or = run_fleet_simulation(cfg).summary()
    s_pe = run_fleet_simulation(pers).summary()
    assert s_pe["deferred_fraction"] <= s_or["deferred_fraction"]
    assert s_pe["n_deferred"] == 0.0    # flat forecast: nothing to gain


def test_class_stats_counts_violations():
    reqs = [Request(rid=0, arrival_s=0.0, prefill_tokens=1,
                    decode_tokens=1, klass=INTERACTIVE, slo_s=1.0,
                    t_first_token=5.0, t_done=6.0),
            Request(rid=1, arrival_s=0.0, prefill_tokens=1,
                    decode_tokens=1, klass=DEFERRABLE, deadline_s=10.0,
                    release_s=4.0, t_first_token=5.0, t_done=20.0)]
    s = class_stats(reqs)
    assert s["interactive_slo_violations"] == 1
    assert s["deadline_violations"] == 1
    assert s["deferred_fraction"] == 1.0
    assert s["mean_deferral_delay_s"] == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------

def test_shift_smoke_sweep_axes_and_fixed_horizon():
    from repro.sweep import SWEEPS
    scenarios = SWEEPS["shift"].build(True)
    assert len({s.params["policy"] for s in scenarios}) == 3
    assert len({s.params["forecaster"] for s in scenarios}) >= 2
    assert any(s.params["ci"] == "hydro-evening+coal-evening"
               for s in scenarios)
    # one fixed co-sim horizon across the whole sweep: idle carbon
    # cancels along the policy axis
    assert len({s.cfg.horizon_s for s in scenarios}) == 1
    assert all(s.cfg.workload.deferrable_frac > 0 for s in scenarios)
    # distinct cache keys (schedule config digests into the scenario key)
    assert len({s.key for s in scenarios}) == len(scenarios)


def test_schedule_columns_grouped_in_reports():
    from repro.sweep.report import SCHEDULE_COLUMNS, _columns
    rows = [{"scenario": "x", "policy": "immediate", "energy_wh": 1.0,
             "deferred_fraction": 0.0, "carbon_active_g": 0.5,
             "n_interactive": 3.0, "cache_hit": False}]
    cols = _columns(rows)
    assert cols[-1] == "cache_hit"
    sched = [c for c in cols if c in SCHEDULE_COLUMNS]
    lo = cols.index(sched[0])
    assert cols[lo:lo + len(sched)] == sched    # contiguous group
