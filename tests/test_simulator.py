"""Simulator + scheduler invariants (incl. hypothesis property tests)."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.paper_models import LLAMA3_8B
from repro.sim import (PAPER_DEFAULT, SchedulerConfig, SimConfig,
                       WorkloadConfig, energy_report, run_simulation)
from repro.sim.requests import generate
from repro.sim.simulator import kv_budget_tokens
from repro.core.power import DEVICES


def small_sim(**kw):
    wl = WorkloadConfig(n_requests=kw.pop("n_requests", 64),
                        qps=kw.pop("qps", 5.0),
                        seed=kw.pop("seed", 0),
                        min_len=kw.pop("min_len", 64),
                        max_len=kw.pop("max_len", 512))
    sched = SchedulerConfig(batch_cap=kw.pop("batch_cap", 16))
    return SimConfig(model=LLAMA3_8B, workload=wl, scheduler=sched, **kw)


def test_all_requests_complete():
    res = run_simulation(small_sim())
    assert all(r.t_done >= 0 for r in res.requests)
    assert all(r.t_first_token >= r.arrival_s for r in res.requests)
    assert all(r.t_done >= r.t_first_token for r in res.requests)


def test_stage_log_consistency():
    res = run_simulation(small_sim())
    s = res.stages
    assert np.all(s.dur_s > 0)
    assert np.all(s.mfu >= 0) and np.all(s.mfu <= 1.0 + 1e-6)
    assert np.all((s.n_prefill_tokens > 0) ^ (s.n_decode_tokens > 0))


@given(st.integers(0, 10_000), st.floats(0.5, 30.0),
       st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_scheduler_batch_cap_respected(seed, qps, cap):
    res = run_simulation(small_sim(seed=seed, qps=qps, batch_cap=cap,
                                   n_requests=48))
    assert np.max(res.stages.batch_size) <= cap
    done = [r for r in res.requests if r.t_done >= 0]
    assert len(done) == 48  # everything eventually served


def test_decode_tokens_counted_exactly():
    cfg = small_sim(n_requests=32)
    res = run_simulation(cfg)
    expected = sum(r.decode_tokens for r in res.requests)
    # decode stages emit one token per running sequence
    emitted = int(np.sum(res.stages.n_decode_tokens))
    assert emitted == expected


def test_energy_scales_linearly_with_requests():
    e = []
    for n in (64, 128, 256):
        res = run_simulation(small_sim(n_requests=n, qps=4.0))
        e.append(energy_report(res).energy_wh)
    r1 = e[1] / e[0]
    r2 = e[2] / e[1]
    assert 1.6 < r1 < 2.4 and 1.6 < r2 < 2.4  # ~2x per doubling


def test_higher_qps_higher_power_lower_energy():
    lo = energy_report(run_simulation(small_sim(qps=0.5, n_requests=96)))
    hi = energy_report(run_simulation(small_sim(qps=8.0, n_requests=96)))
    assert hi.avg_power_w > lo.avg_power_w      # paper Fig. 5A
    assert hi.energy_wh < lo.energy_wh          # paper Fig. 5B


def test_kv_budget_large_model_small():
    from repro.configs.paper_models import CODELLAMA_34B
    b34 = kv_budget_tokens(CODELLAMA_34B, DEVICES["a100"], 1, 1)
    b8 = kv_budget_tokens(LLAMA3_8B, DEVICES["a100"], 1, 1)
    assert 0 < b34 < 40_000          # 34B barely fits A100-80GB
    assert b8 > 100_000
    assert kv_budget_tokens(CODELLAMA_34B, DEVICES["a100"], 2, 1) > 2 * b34


def test_tp_reduces_stage_time():
    from repro.sim.execmodel import ExecutionModel
    m1 = ExecutionModel(LLAMA3_8B, DEVICES["a100"], tp=1)
    m2 = ExecutionModel(LLAMA3_8B, DEVICES["a100"], tp=2)
    c1 = m1.stage_cost([2048], [])
    c2 = m2.stage_cost([2048], [])
    assert c2.t_total < c1.t_total
    assert c2.t_collective > 0 and c1.t_collective == 0


def test_workload_pd_ratio():
    wl = WorkloadConfig(n_requests=200, pd_ratio=20.0, min_len=1024,
                        max_len=1024, length_dist="fixed")
    reqs = generate(wl)
    ratios = [r.prefill_tokens / r.decode_tokens for r in reqs]
    assert np.median(ratios) == pytest.approx(20.0, rel=0.1)


def test_zipf_lengths_skewed():
    wl = WorkloadConfig(n_requests=2000, zipf_theta=0.9, min_len=100,
                        max_len=4000, seed=1)
    reqs = generate(wl)
    lens = np.array([r.prefill_tokens + r.decode_tokens for r in reqs])
    assert np.median(lens) < np.mean(lens)  # right-skew
    assert lens.min() >= 100 and lens.max() <= 4000


def _chunk_sim(chunk):
    wl = WorkloadConfig(n_requests=4, qps=1.0, min_len=1024, max_len=1024,
                        length_dist="fixed", seed=0)
    sched = SchedulerConfig(batch_cap=8, chunk_prefill=chunk)
    return run_simulation(SimConfig(model=LLAMA3_8B, workload=wl,
                                    scheduler=sched))


def test_chunked_prefill_stage_count():
    """chunk_prefill=256 splits each 975-token prompt into 4 chunk
    stages (Sarathi), vs one whole-prompt prefill stage unchunked."""
    base = _chunk_sim(None)
    chunked = _chunk_sim(256)
    n_base = int(np.sum(base.stages.n_prefill_tokens > 0))
    n_chunked = int(np.sum(chunked.stages.n_prefill_tokens > 0))
    total_prefill = sum(r.prefill_tokens for r in chunked.requests)
    assert n_base <= 4                       # one stage per prompt
    assert n_chunked >= -(-total_prefill // 256)   # >= ceil(3900/256)=16
    assert n_chunked > n_base
    # every chunk stage respects the token budget
    chunk_stages = chunked.stages.n_prefill_tokens
    assert np.all(chunk_stages[chunk_stages > 0] <= 256)
    # no prefill work is lost or duplicated
    assert int(np.sum(base.stages.n_prefill_tokens)) == total_prefill
    assert int(np.sum(chunked.stages.n_prefill_tokens)) == total_prefill
    # the workload still completes, decode accounting intact
    assert all(r.t_done >= 0 for r in chunked.requests)
    assert int(np.sum(chunked.stages.n_decode_tokens)) == \
        sum(r.decode_tokens for r in chunked.requests)


def test_chunked_prefill_coalesces_decodes():
    """Sarathi-style iterations mix prefill chunks with ongoing decodes
    once earlier requests finish their prompts."""
    res = _chunk_sim(256)
    mixed = np.sum((res.stages.n_prefill_tokens > 0)
                   & (res.stages.n_decode_tokens > 0))
    assert mixed > 0


def test_chunk_prefill_validation():
    with pytest.raises(ValueError):
        SchedulerConfig(chunk_prefill=0)
    with pytest.raises(ValueError):
        SchedulerConfig(chunk_prefill=-5)
