"""Sweep engine: grid expansion, cache memoization, parallel==serial."""
import numpy as np

from repro.configs.paper_models import LLAMA3_8B
from repro.sim import SchedulerConfig, SimConfig, WorkloadConfig
from repro.sweep import (GridSpec, ResultCache, Scenario, SweepRunner,
                         config_digest, execute_scenario, flatten, to_csv,
                         with_overrides)


def tiny_base(n_requests=12):
    return SimConfig(
        model=LLAMA3_8B,
        workload=WorkloadConfig(n_requests=n_requests, qps=4.0,
                                min_len=64, max_len=256, seed=0),
        scheduler=SchedulerConfig(batch_cap=8))


def test_grid_cardinality_and_expansion():
    spec = GridSpec(base=tiny_base(),
                    axes={"workload.qps": [1.0, 2.0, 4.0],
                          "scheduler.batch_cap": [4, 8]})
    assert spec.cardinality == 6
    scenarios = spec.expand()
    assert len(scenarios) == 6
    combos = {(s.cfg.workload.qps, s.cfg.scheduler.batch_cap)
              for s in scenarios}
    assert combos == {(q, c) for q in (1.0, 2.0, 4.0) for c in (4, 8)}
    assert scenarios[0].params == {"qps": 1.0, "batch_cap": 4}


def test_joint_axis_moves_fields_in_lockstep():
    spec = GridSpec(base=tiny_base(), axes={"tp+pp": [(1, 1), (2, 2)]})
    assert spec.cardinality == 2
    scenarios = spec.expand()
    assert scenarios[1].cfg.tp == 2 and scenarios[1].cfg.pp == 2
    assert scenarios[1].params == {"tp": 2, "pp": 2}


def test_model_axis_resolves_registry():
    spec = GridSpec(base=tiny_base(),
                    axes={"model": ["llama3-8b", "phi2-2.7b"]})
    scenarios = spec.expand()
    assert scenarios[1].cfg.model.name == "phi2-2.7b"
    assert scenarios[0].params["model"] == "llama3-8b"


def test_digest_stable_and_config_sensitive():
    assert config_digest(tiny_base()) == config_digest(tiny_base())
    bumped = with_overrides(tiny_base(), {"workload.qps": 9.0})
    assert config_digest(bumped) != config_digest(tiny_base())
    # runner knobs key the cache too
    plain = Scenario(cfg=tiny_base(), params={})
    posted = Scenario(cfg=tiny_base(), params={}, post="microgrid_cosim")
    assert plain.key != posted.key


def test_cache_second_run_executes_zero(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    scenarios = GridSpec(base=tiny_base(),
                         axes={"workload.qps": [2.0, 6.0]}).expand()
    r1, s1 = SweepRunner(cache=cache).run(scenarios)
    assert s1.executed == 2 and s1.cache_hits == 0
    r2, s2 = SweepRunner(cache=cache).run(scenarios)
    assert s2.executed == 0 and s2.cache_hits == 2
    assert [r["metrics"] for r in r1] == [r["metrics"] for r in r2]
    assert all(r["meta"]["cache_hit"] for r in r2)


def test_cross_sweep_hit_rebinds_params(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    first = GridSpec(base=tiny_base(), tag="a",
                     axes={"workload.qps": [3.0]}).expand()
    SweepRunner(cache=cache).run(first)
    # same config reached through a different axis spelling
    second = GridSpec(base=with_overrides(tiny_base(),
                                          {"workload.qps": 3.0}),
                      tag="b", axes={"scheduler.batch_cap": [8]}).expand()
    records, stats = SweepRunner(cache=cache).run(second)
    assert stats.cache_hits == 1
    assert records[0]["params"] == {"batch_cap": 8}
    assert records[0]["scenario"].startswith("b/")


def test_parallel_matches_serial_at_fixed_seeds():
    scenarios = GridSpec(base=tiny_base(8),
                         axes={"workload.qps": [2.0, 5.0]}).expand()
    serial, _ = SweepRunner(cache=None, workers=1).run(scenarios)
    parallel, _ = SweepRunner(cache=None, workers=2).run(scenarios)
    assert [r["metrics"] for r in serial] == \
           [r["metrics"] for r in parallel]


def test_record_has_energy_carbon_columns_and_csv(tmp_path):
    record = execute_scenario(Scenario(cfg=tiny_base(6), params={"x": 1}))
    for col in ("energy_wh", "energy_kwh", "avg_power_w", "gpu_hours",
                "carbon_operational_g", "carbon_embodied_g",
                "carbon_total_g", "ttft_p50_s", "e2e_p99_s"):
        assert col in record["metrics"], col
    assert record["metrics"]["energy_wh"] > 0
    row = flatten([record])[0]
    assert row["x"] == 1
    path = to_csv([record], tmp_path / "out.csv")
    header = path.read_text().splitlines()[0].split(",")
    assert "x" in header and "energy_wh" in header


def test_smoke_sweeps_expand_for_every_figure():
    from repro.sweep import SWEEPS
    assert set(SWEEPS) == {"fig1", "fig2", "fig3", "fig4", "fig5",
                           "exp5", "table2", "carbon", "fleet", "shift",
                           "perf", "day"}
    # perf is the runner-throughput grid: deliberately ~1k scenarios
    # (1024 stacked-axis points + a 32-scenario hardware family for
    # device-mode divergence sharing), but they collapse to a handful
    # of unique traces; day's smoke is
    # four whole-day hybrid/event_loop runs over an array-native
    # stream, so its request count is epoch-planned, not event-stepped
    smoke_caps = {"shift": 18, "perf": 1056}
    request_caps = {"day": 10_000}
    for name, sweep in SWEEPS.items():
        scenarios = sweep.build(True)
        assert scenarios, name
        # smoke grids stay tiny so CI can afford every figure per push
        # (shift's policy x forecaster x trace-set grid is wider but
        # each scenario is a ~100-request fleet sim, seconds apiece)
        assert len(scenarios) <= smoke_caps.get(name, 8), name
        cap = request_caps.get(name, 2000)
        assert all(s.cfg.workload.n_requests <= cap
                   for s in scenarios), name


def test_scenario_knob_axes_route_correctly():
    import pytest

    from repro.configs.paper_models import LLAMA3_8B
    from repro.fleet.config import FleetConfig, SiteConfig
    from repro.sim import WorkloadConfig

    # SimConfig bases: pue/grid_ci land on the Scenario, not the config
    a, b = GridSpec(base=tiny_base(8), axes={"pue": [1.0, 1.5]}).expand()
    assert (a.pue, b.pue) == (1.0, 1.5)
    assert a.trace_key == b.trace_key          # shared simulation trace
    assert a.key != b.key                      # distinct cache entries

    # FleetConfig bases: the fleet rollup reads cfg.pue — a pue axis
    # must reach it (and grid_ci, which fleets ignore, must refuse)
    fleet = FleetConfig(
        model=LLAMA3_8B, sites=(SiteConfig(name="s0", ci_trace="hydro"),),
        workload=WorkloadConfig(n_requests=8, qps=4.0, min_len=64,
                                max_len=128, seed=0))
    fa, fb = GridSpec(base=fleet, axes={"pue": [1.0, 1.5]}).expand()
    assert (fa.cfg.pue, fb.cfg.pue) == (1.0, 1.5)
    with pytest.raises(ValueError):
        GridSpec(base=fleet, axes={"grid_ci": [100.0]}).expand()


def test_derived_seeds_ignore_report_knobs():
    spec = GridSpec(base=tiny_base(8),
                    axes={"workload.qps": [2.0], "pue": [1.0, 1.3]},
                    seed_per_scenario=True)
    a, b = spec.expand()
    # report knobs must not confound the workload draw: same seed,
    # same trace group across the pue axis
    assert a.cfg.workload.seed == b.cfg.workload.seed
    assert a.trace_key == b.trace_key


def test_seed_lives_in_config_not_execution_order():
    spec = GridSpec(base=tiny_base(), axes={"workload.qps": [1.0, 2.0]},
                    seed_per_scenario=True)
    a, b = spec.expand()
    assert a.cfg.workload.seed != b.cfg.workload.seed
    # re-expansion reproduces the same derived seeds
    a2, b2 = spec.expand()
    assert (a.cfg.workload.seed, b.cfg.workload.seed) == \
           (a2.cfg.workload.seed, b2.cfg.workload.seed)


def test_memo_eviction_is_lru(tmp_path):
    """The in-process memo evicts least-recently-used, so a long-lived
    worker keeps hot keys resident past the cap instead of freezing
    the first insertions (the old behavior dropped everything)."""
    cache = ResultCache(tmp_path / "cache")
    cache._MEMO_CAP = 3
    for k in ("k0", "k1", "k2"):
        cache.put(k, {"key": k, "metrics": {}})
    assert list(cache._memo) == ["k0", "k1", "k2"]
    cache.get("k0")                       # touch: k0 becomes most recent
    cache.put("k3", {"key": "k3", "metrics": {}})   # evicts k1, not k0
    assert list(cache._memo) == ["k2", "k0", "k3"]
    # k1 still serves from disk (authoritative) and re-enters the memo
    c0 = dict(cache.counters)
    assert cache.get("k1")["key"] == "k1"
    assert cache.counters["disk"] == c0["disk"] + 1
    assert "k1" in cache._memo and "k2" not in cache._memo


def test_memo_cap_holds_under_churn(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    cache._MEMO_CAP = 4
    for i in range(20):
        cache.put(f"key{i:02d}", {"key": f"key{i:02d}", "metrics": {}})
    assert len(cache._memo) == 4
    assert list(cache._memo) == ["key16", "key17", "key18", "key19"]


def test_peak_rss_includes_pool_children():
    """The summary's peak-RSS figure must reflect the process *tree*:
    a child that allocates far more than the parent shows up via
    RUSAGE_CHILDREN once reaped."""
    import subprocess
    import sys

    from repro.sweep.runner import _peak_rss_mb

    before = _peak_rss_mb()
    # ~300 MB in a child; bytearray keeps it resident, touch every page
    subprocess.run(
        [sys.executable, "-c",
         "b = bytearray(300 * 1024 * 1024)\n"
         "b[::4096] = b'x' * len(b[::4096])"],
        check=True)
    after = _peak_rss_mb()
    assert after >= before
    assert after >= 250.0     # the child's footprint, not the parent's
